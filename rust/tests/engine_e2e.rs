//! End-to-end engine tests through the public API: every policy behind
//! the `SchedulingPolicy` seam serves real traces, failures lose
//! nothing, the autoscaler grows and shrinks the fleet, and runs are
//! deterministic. (Moved out of `sim/engine.rs` when the engine was
//! decomposed — these never needed private access.)

use qlm::backend::{GpuKind, InstanceId, ModelCatalog, ModelId};
use qlm::baselines::Policy;
use qlm::capacity::{AdmissionConfig, AutoscaleConfig};
use qlm::metrics::{Metric, RunMetrics};
use qlm::sim::{fleet_a100, SimConfig, Simulation};
use qlm::workload::{Scenario, ScenarioKnobs, SloClass, Trace, WorkloadSpec};

fn small_trace(rate: f64, n: usize) -> Trace {
    let spec = WorkloadSpec::w_a(ModelId(0), rate, n);
    Trace::generate(&spec, 42)
}

fn run_policy(policy: Policy, rate: f64, n: usize, fleet: u32) -> RunMetrics {
    let trace = small_trace(rate, n);
    let cfg = SimConfig::new(fleet_a100(fleet), ModelCatalog::paper(), policy);
    Simulation::new(cfg, &trace).run(&trace)
}

#[test]
fn qlm_completes_all_requests_light_load() {
    let m = run_policy(Policy::qlm(), 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
    assert!(m.slo_attainment() > 0.9, "{}", m.summary());
}

#[test]
fn vllm_completes_all_requests_light_load() {
    let m = run_policy(Policy::VllmFcfs, 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
}

#[test]
fn edf_completes_all_requests_light_load() {
    let m = run_policy(Policy::Edf, 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
}

#[test]
fn sjf_completes_all_requests_light_load() {
    let m = run_policy(Policy::Sjf, 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
}

#[test]
fn wfq_completes_all_requests_light_load() {
    let m = run_policy(Policy::Wfq, 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
}

#[test]
fn edf_swap_completes_all_requests_light_load() {
    let m = run_policy(Policy::EdfSwap, 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
}

#[test]
fn shepherd_completes_all_requests_light_load() {
    let m = run_policy(Policy::Shepherd, 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
}

#[test]
fn chunked_completes_all_requests_light_load() {
    let m = run_policy(Policy::Chunked, 5.0, 200, 2);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
}

/// Mega-prompt scenario run shared by the chunked-vs-whole-request
/// comparatives below.
fn run_mega(policy: Policy) -> RunMetrics {
    let knobs = ScenarioKnobs {
        rate: 10.0,
        requests: 400,
        fleet: 2,
        seed: 42,
    };
    let run = Scenario::Mega.build(&knobs);
    let trace = Trace::generate(&run.spec, knobs.seed);
    let mut cfg = run.sim_config(policy);
    cfg.seed = knobs.seed;
    Simulation::new(cfg, &trace).run(&trace)
}

#[test]
fn chunked_beats_whole_request_on_interactive_ttft_tail() {
    // The point of token-granular scheduling: on a mega-prompt-heavy
    // trace, SLO-aware chunked prefill keeps interactive first tokens
    // from stalling behind multi-second batch prefills, without giving
    // up batch decode throughput.
    let chunked = run_mega(Policy::Chunked);
    let qlm = run_mega(Policy::qlm());
    let vllm = run_mega(Policy::VllmFcfs);
    assert_eq!(chunked.completed_count(), 400, "{}", chunked.summary());

    let p99 = |m: &RunMetrics| m.percentile_class(Metric::Ttft, 99.0, SloClass::Interactive);
    assert!(
        p99(&chunked) < p99(&qlm),
        "chunked interactive p99 TTFT {:.3}s must beat whole-request qlm {:.3}s",
        p99(&chunked),
        p99(&qlm)
    );
    assert!(
        p99(&chunked) < p99(&vllm),
        "chunked interactive p99 TTFT {:.3}s must beat vllm-fcfs {:.3}s",
        p99(&chunked),
        p99(&vllm)
    );
    // Batch TPOT attainment stays within 5 points of whole-request QLM.
    for class in [SloClass::Batch1, SloClass::Batch2] {
        assert!(
            chunked.tpot_attainment_class(class) >= qlm.tpot_attainment_class(class) - 0.05,
            "{:?} TPOT attainment: chunked {:.3} vs qlm {:.3}",
            class,
            chunked.tpot_attainment_class(class),
            qlm.tpot_attainment_class(class)
        );
    }
}

#[test]
fn deterministic_across_runs() {
    let a = run_policy(Policy::qlm(), 10.0, 150, 2);
    let b = run_policy(Policy::qlm(), 10.0, 150, 2);
    assert_eq!(a.completed_count(), b.completed_count());
    assert!((a.slo_attainment() - b.slo_attainment()).abs() < 1e-12);
    assert!((a.mean_ttft() - b.mean_ttft()).abs() < 1e-9);
}

#[test]
fn qlm_beats_vllm_under_pressure() {
    // Overloaded single instance: QLM should prioritize interactive
    // requests and win on SLO attainment.
    let qlm = run_policy(Policy::qlm(), 40.0, 400, 1);
    let vllm = run_policy(Policy::VllmFcfs, 40.0, 400, 1);
    assert!(
        qlm.slo_attainment() >= vllm.slo_attainment(),
        "qlm {} vs vllm {}",
        qlm.summary(),
        vllm.summary()
    );
}

#[test]
fn multi_model_swapping_occurs() {
    let b1 = vec![ModelId(0), ModelId(1)];
    let b2 = vec![ModelId(2), ModelId(1)];
    let spec = WorkloadSpec::w_b(b1, b2, 20.0, 300);
    let trace = Trace::generate(&spec, 7);
    let cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert!(m.total_model_swaps() >= 2, "{}", m.summary());
    assert!(m.completed_count() > 250, "{}", m.summary());
}

#[test]
fn horizon_caps_runtime() {
    let trace = small_trace(50.0, 500);
    let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
    cfg.horizon_s = 5.0;
    let m = Simulation::new(cfg, &trace).run(&trace);
    // Not all done, but the run terminates and records everyone.
    assert_eq!(m.records.len(), 500);
}

#[test]
fn instance_failure_loses_no_requests() {
    // §4 fault tolerance, end to end: kill one of two instances
    // mid-run; every request still completes on the survivor.
    let trace = small_trace(8.0, 200);
    let mut cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
    cfg.failures = vec![(5.0, InstanceId(1))];
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert_eq!(m.completed_count(), 200, "{}", m.summary());
    // The dead instance did no work after t=5.
    let healthy = run_policy(Policy::qlm(), 8.0, 200, 2);
    assert!(
        m.duration_s >= healthy.duration_s,
        "losing capacity cannot speed the run up"
    );
}

#[test]
fn failover_is_deterministic() {
    let trace = small_trace(10.0, 150);
    let run = || {
        let mut cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
        cfg.failures = vec![(3.0, InstanceId(0))];
        Simulation::new(cfg, &trace).run(&trace)
    };
    let a = run();
    let b = run();
    assert_eq!(a.completed_count(), b.completed_count());
    assert!((a.mean_ttft() - b.mean_ttft()).abs() < 1e-9);
}

/// Vicuna-13B W_A trace: heavy enough per token that overload forms
/// a real *waiting* backlog (Mistral's KV capacity absorbs small
/// bursts straight into the running batch, which never pressures
/// the autoscaler).
fn vicuna_trace(rate: f64, n: usize) -> Trace {
    Trace::generate(&WorkloadSpec::w_a(ModelId(1), rate, n), 42)
}

#[test]
fn autoscaler_grows_fleet_under_pressure_and_completes() {
    let trace = vicuna_trace(40.0, 600);
    let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
    let mut auto = AutoscaleConfig::bounded(1, 4, GpuKind::A100);
    auto.breach_passes = 2;
    auto.cooldown_s = 5.0;
    // Short bench-scale trace: trip on a couple of seconds of
    // predicted backlog rather than the production half-SLO.
    auto.up_frac = 0.1;
    cfg.autoscale = Some(auto);
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert_eq!(m.completed_count(), 600, "{}", m.summary());
    assert!(m.scale_ups >= 1, "overload must trigger provisioning");
    // The ledger bills provisioned capacity only from commission on.
    assert!(
        m.device_seconds <= 4.0 * m.duration_s + 1e-6,
        "{} vs {}",
        m.device_seconds,
        m.duration_s
    );
    // Extra capacity must not slow the run down vs the fixed fleet.
    let fixed = {
        let cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        Simulation::new(cfg, &trace).run(&trace)
    };
    assert!(
        m.duration_s <= fixed.duration_s * 1.05,
        "auto {} vs fixed {}",
        m.duration_s,
        fixed.duration_s
    );
}

#[test]
fn autoscaling_is_deterministic() {
    let trace = vicuna_trace(40.0, 300);
    let run = || {
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        let mut auto = AutoscaleConfig::bounded(1, 3, GpuKind::A100);
        auto.breach_passes = 2;
        auto.cooldown_s = 5.0;
        auto.up_frac = 0.1;
        cfg.autoscale = Some(auto);
        Simulation::new(cfg, &trace).run(&trace)
    };
    let a = run();
    let b = run();
    assert_eq!(a.completed_count(), b.completed_count());
    assert_eq!(a.scale_ups, b.scale_ups);
    assert_eq!(a.scale_downs, b.scale_downs);
    assert!((a.device_seconds - b.device_seconds).abs() < 1e-9);
    assert!((a.mean_ttft() - b.mean_ttft()).abs() < 1e-9);
}

#[test]
fn admission_sheds_hopeless_batch_classes_only() {
    // One instance under a crushing W_A overload with an aggressive
    // shed gate: batch classes are refused at the door once their
    // predicted drain blows through the gate; interactive never is.
    let trace = small_trace(60.0, 600);
    let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
    cfg.admission = AdmissionConfig {
        enabled: true,
        shed_frac: 0.05,
        resume_frac: 0.01,
    };
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert_eq!(m.records.len(), 600, "every request recorded exactly once");
    let shed = m.shed_count();
    assert!(shed > 0, "hopeless batch backlog must shed: {}", m.summary());
    assert!(
        m.records
            .iter()
            .filter(|r| r.shed)
            .all(|r| r.class != SloClass::Interactive),
        "interactive traffic must never be shed"
    );
    assert_eq!(
        m.completed_count() + shed,
        600,
        "shed + completed must conserve the trace"
    );
}

#[test]
fn incremental_and_full_sched_paths_both_serve_everything() {
    let trace = small_trace(5.0, 200);
    let run_mode = |inc: bool| {
        let mut cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
        cfg.sched_incremental = inc;
        Simulation::new(cfg, &trace).run(&trace)
    };
    let a = run_mode(true);
    let b = run_mode(false);
    assert_eq!(a.completed_count(), 200, "{}", a.summary());
    assert_eq!(b.completed_count(), 200, "{}", b.summary());
    assert!(a.slo_attainment() > 0.9, "{}", a.summary());
    assert!(b.slo_attainment() > 0.9, "{}", b.summary());
}
