pub fn read_first(xs: &[u32]) -> u32 {
    // audit:allow(safety-comment): fixture demonstrating a waived missing comment
    unsafe { *xs.as_ptr() }
}
