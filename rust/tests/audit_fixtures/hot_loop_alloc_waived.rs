//! Fixture: a judged-acceptable allocation inside a marked hot loop,
//! waived in place with its justification.

pub fn walk(xs: &[Vec<u64>]) -> usize {
    let mut total = 0;
    // audit:hot-loop
    for x in xs {
        // audit:allow(hot-loop-alloc): one small copy per group, amortized
        // away by the per-group service-time estimate that follows it.
        let copy = x.to_vec();
        total += copy.len();
    }
    total
}
