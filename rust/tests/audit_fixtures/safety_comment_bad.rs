pub fn read_first(xs: &[u32]) -> u32 {
    unsafe { *xs.as_ptr() }
}
