pub fn noop() {} // audit:allow(hash-collections)

pub fn still_noop() {} // audit:allow(made-up-rule): a reason cannot save an unknown id
