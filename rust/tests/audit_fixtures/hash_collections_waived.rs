// audit:allow(hash-collections): lookup-only map; iteration order never observed
use std::collections::HashMap;

pub fn touch(h: &mut std::collections::BTreeMap<u32, u32>) {
    h.insert(1, 2);
}
