// audit:allow(wall-clock): diagnostic pass timing only, never simulated time
use std::time::Instant;

pub fn stamp_nanos() -> u128 {
    // audit:allow(wall-clock): diagnostic pass timing only, never simulated time
    let t = Instant::now();
    t.elapsed().as_nanos()
}
