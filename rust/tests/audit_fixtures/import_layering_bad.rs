//! Fixture: the workload layer reaching down into the coordinator it
//! feeds — the exact back-edge the import-layering rule forbids.
//! Scanned under the pretend path `src/workload/fixture.rs`.

use crate::coordinator::GlobalQueue;

pub fn peek(q: &GlobalQueue) -> usize {
    q.len_waiting()
}
