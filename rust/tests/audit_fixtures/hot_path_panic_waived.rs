pub fn head(xs: &[u32]) -> u32 {
    // audit:allow(hot-path-panic): fixture; callers guarantee a non-empty slice
    xs.first().copied().unwrap()
}
