pub fn histogram(xs: &[u32]) -> std::collections::HashMap<u32, u32> {
    let mut h = std::collections::HashMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0) += 1;
    }
    h
}
