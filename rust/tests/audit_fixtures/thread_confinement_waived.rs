pub fn fan_out() {
    // audit:allow(thread-confinement): fixture; real code routes through util::pool
    std::thread::spawn(|| {});
}
