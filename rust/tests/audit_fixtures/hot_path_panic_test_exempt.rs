pub fn head(xs: &[u32]) -> Option<u32> {
    xs.first().copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn head_works() {
        assert_eq!(super::head(&[7]).unwrap(), 7);
    }
}
