// Scanned under a pretend src/metrics/ path: the reporting layers are
// inside the hash-collections scope (their tables must iterate in a
// stable order), so this fires exactly like sim/ code would.
pub fn ttft_histogram(xs: &[u32]) -> std::collections::HashMap<u32, u32> {
    let mut h = std::collections::HashMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0) += 1;
    }
    h
}
