pub fn read_first(xs: &[u32]) -> u32 {
    // SAFETY: fixture; the slice is non-empty by contract.
    unsafe { *xs.as_ptr() }
}
