//! Fixture: the same forbidden edge carrying a reasoned waiver, so
//! nothing fires. Scanned under the pretend path
//! `src/workload/fixture.rs`.

// audit:allow(import-layering): transitional shim while the scenario builder migrates off the queue type
use crate::coordinator::GlobalQueue;

pub fn peek(q: &GlobalQueue) -> usize {
    q.len_waiting()
}
