pub fn read_first(xs: &[u32]) -> u32 {
    // audit:allow(unsafe-confinement): fixture demonstrating a documented waiver
    // SAFETY: fixture; the slice is non-empty by contract.
    unsafe { *xs.as_ptr() }
}
