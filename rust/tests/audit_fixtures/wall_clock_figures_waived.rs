// Scanned under a pretend src/figures/ path: figures are inside the
// wall-clock scope, and the sanctioned host-latency stopwatches carry
// waivers arguing the read never feeds a plan or the sim clock.
// audit:allow(wall-clock): measures real solver latency for a figure row only
use std::time::Instant;

pub fn pass_millis() -> f64 {
    // audit:allow(wall-clock): measures real solver latency for a figure row only
    let t0 = Instant::now();
    1000.0 * t0.elapsed().as_secs_f64()
}
