pub fn shortcut(pen: f64) -> f64 {
    // audit:allow(pricing-seam): fixture; real scoring goes through sched::pricing
    let score = append_score(pen);
    score
}
