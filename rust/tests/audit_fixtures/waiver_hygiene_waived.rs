pub fn head(xs: &[u32]) -> u32 {
    // audit:allow(hot-path-panic): fixture; a well-formed waiver is the only fix
    xs.first().copied().unwrap()
}
