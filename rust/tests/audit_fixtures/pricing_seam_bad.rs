pub fn shortcut(pen: f64) -> f64 {
    let score = append_score(pen);
    score
}
