//! Fixture: per-iteration allocation churn inside a marked hot loop.
//! Scanned as src/sim/fixture.rs, where `audit:hot-loop` extents are
//! honored — the `.to_vec()` inside the loop must fire hot-loop-alloc.

pub fn walk(xs: &[Vec<u64>]) -> usize {
    let mut total = 0;
    // audit:hot-loop
    for x in xs {
        let copy = x.to_vec();
        total += copy.len();
    }
    total
}
