//! Integration surface of `qlm audit` (tier-2).
//!
//! Two halves: the shipped tree must be clean (the same check CI runs
//! via the CLI), and every fixture under `tests/audit_fixtures/` must
//! fire exactly the rule it demonstrates — bad variants fire only their
//! own rule, waived variants fire nothing. The fixtures are scanned
//! with *pretend* paths so path-scoped rules apply; `qlm audit` itself
//! never walks the fixture directory.

use std::collections::BTreeSet;
use std::path::Path;

use qlm::audit::{self, Rule, RULES};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/audit_fixtures")
        .join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// (fixture file, pretend path, rule the bad variant fires — `None`
/// means the snippet must be clean).
const FIXTURES: &[(&str, &str, Option<Rule>)] = &[
    ("hash_collections_bad.rs", "src/sim/fixture.rs", Some(Rule::HashCollections)),
    ("hash_collections_waived.rs", "src/sim/fixture.rs", None),
    ("wall_clock_bad.rs", "src/sim/fixture.rs", Some(Rule::WallClock)),
    ("wall_clock_waived.rs", "src/sim/fixture.rs", None),
    // The determinism rules extend to the reporting layers (metrics/,
    // figures/, obs/) — same fixtures, scanned under the new paths.
    ("hash_collections_metrics_bad.rs", "src/metrics/fixture.rs", Some(Rule::HashCollections)),
    ("hash_collections_bad.rs", "src/obs/fixture.rs", Some(Rule::HashCollections)),
    ("wall_clock_bad.rs", "src/figures/fixture.rs", Some(Rule::WallClock)),
    ("wall_clock_figures_waived.rs", "src/figures/fixture.rs", None),
    ("thread_confinement_bad.rs", "src/sim/fixture.rs", Some(Rule::ThreadConfinement)),
    ("thread_confinement_waived.rs", "src/sim/fixture.rs", None),
    // Carries a SAFETY: comment so only the confinement rule fires.
    ("unsafe_confinement_bad.rs", "src/sim/fixture.rs", Some(Rule::UnsafeConfinement)),
    ("unsafe_confinement_waived.rs", "src/sim/fixture.rs", None),
    // Scanned as util/pool.rs, where unsafe is allowed but must be documented.
    ("safety_comment_bad.rs", "src/util/pool.rs", Some(Rule::SafetyComment)),
    ("safety_comment_waived.rs", "src/util/pool.rs", None),
    ("hot_path_panic_bad.rs", "src/coordinator/fixture.rs", Some(Rule::HotPathPanic)),
    ("hot_path_panic_waived.rs", "src/coordinator/fixture.rs", None),
    ("hot_path_panic_test_exempt.rs", "src/coordinator/fixture.rs", None),
    ("hot_loop_alloc_bad.rs", "src/sim/fixture.rs", Some(Rule::HotLoopAlloc)),
    ("hot_loop_alloc_waived.rs", "src/coordinator/sched/fixture.rs", None),
    ("pricing_seam_bad.rs", "src/sim/fixture.rs", Some(Rule::PricingSeam)),
    ("pricing_seam_waived.rs", "src/sim/fixture.rs", None),
    ("import_layering_bad.rs", "src/workload/fixture.rs", Some(Rule::ImportLayering)),
    ("import_layering_waived.rs", "src/workload/fixture.rs", None),
    ("waiver_hygiene_bad.rs", "src/sim/fixture.rs", Some(Rule::WaiverHygiene)),
    // The hygiene rule is unwaivable; its clean counterpart is simply a
    // well-formed waiver.
    ("waiver_hygiene_waived.rs", "src/coordinator/fixture.rs", None),
];

#[test]
fn shipped_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = audit::run_report(root).expect("walk src/ + tests/");
    assert!(report.files_scanned > 0, "audit walked no files");
    let rendered: Vec<String> = report.violations.iter().map(|v| v.to_string()).collect();
    assert!(
        report.violations.is_empty(),
        "shipped tree has audit violations:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn fixtures_fire_exactly_their_rule() {
    for &(file, pretend, expected) in FIXTURES {
        let src = fixture(file);
        let fired: BTreeSet<Rule> =
            audit::scan_source(pretend, &src).into_iter().map(|v| v.rule).collect();
        match expected {
            Some(rule) => assert_eq!(
                fired,
                BTreeSet::from([rule]),
                "{file} (as {pretend}) must fire exactly `{}`",
                rule.id()
            ),
            None => assert!(
                fired.is_empty(),
                "{file} (as {pretend}) must be clean, fired: {fired:?}"
            ),
        }
    }
}

#[test]
fn every_rule_has_a_bad_fixture() {
    let covered: BTreeSet<Rule> = FIXTURES.iter().filter_map(|&(_, _, r)| r).collect();
    for info in &RULES {
        assert!(covered.contains(&info.rule), "no bad fixture for `{}`", info.id);
    }
}

#[test]
fn waived_fixtures_record_their_waivers() {
    for &(file, pretend, expected) in FIXTURES {
        if expected.is_some() || file == "hot_path_panic_test_exempt.rs" {
            continue;
        }
        let (_, waivers) = audit::scan_source_report(pretend, &fixture(file));
        assert!(!waivers.is_empty(), "{file} should carry at least one waiver");
    }
}

#[test]
fn reasonless_waiver_is_itself_a_violation() {
    let src = "pub fn f() {} // audit:allow(wall-clock)\n";
    let fired: Vec<Rule> =
        audit::scan_source("src/metrics/x.rs", src).into_iter().map(|v| v.rule).collect();
    assert_eq!(fired, vec![Rule::WaiverHygiene]);
}

#[test]
fn malformed_waiver_suppresses_nothing() {
    // A reasonless waiver over a real violation reports both: the
    // hygiene failure and the violation it failed to cover.
    let src = "// audit:allow(hash-collections)\nuse std::collections::HashMap;\n";
    let fired: BTreeSet<Rule> =
        audit::scan_source("src/sim/x.rs", src).into_iter().map(|v| v.rule).collect();
    assert_eq!(fired, BTreeSet::from([Rule::WaiverHygiene, Rule::HashCollections]));
}
