//! Property-based tests over coordinator invariants (routing, batching,
//! state). The offline build has no proptest crate, so properties are
//! exercised with seeded random-case sweeps over the crate's own RNG —
//! each test runs dozens of randomized trials and asserts invariants on
//! every one.

use std::collections::BTreeMap;
use std::collections::HashSet;

use qlm::backend::{
    GpuKind, Instance, InstanceConfig, InstanceId, KvCache, ModelCatalog, ModelId, PerfModel,
    RunningSeq,
};
use qlm::coordinator::request::{Request, RequestState};
use qlm::coordinator::request_group::{GroupId, Grouper, RequestGroup};
use qlm::coordinator::rwt::{ProfileTable, RwtEstimator};
use qlm::coordinator::scheduler::{GlobalScheduler, InstanceView, SchedulerConfig};
use qlm::coordinator::GlobalQueue;
use qlm::util::Rng;
use qlm::workload::{SloClass, SloTarget, TraceRequest};

fn rand_request(rng: &mut Rng, id: u64, n_models: u32) -> Request {
    let class = *rng.choose(&[SloClass::Interactive, SloClass::Batch1, SloClass::Batch2]);
    let mut r = Request::from_trace(
        id,
        &TraceRequest {
            arrival_s: rng.range(0.0, 100.0),
            model: ModelId(rng.usize(n_models as usize) as u32),
            class,
            slo: class.target(),
            input_tokens: 1 + rng.usize(2000) as u32,
            output_tokens: 1 + rng.usize(1500) as u32,
            mega: rng.f64() < 0.1,
        },
    );
    r.id = id;
    r
}

/// Property: regrouping partitions the request set — every request in
/// exactly one group; groups are model- and class-homogeneous; sizes
/// respect δ × avg_batch.
#[test]
fn prop_grouping_partitions_requests() {
    for seed in 0..30 {
        let mut rng = Rng::new(seed);
        let n = 50 + rng.usize(300);
        let reqs: Vec<Request> = (0..n as u64)
            .map(|i| rand_request(&mut rng, i, 3))
            .collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let mut grouper = Grouper::new(4.0, 16, seed);
        let groups = grouper.regroup(&refs);

        let mut seen: HashSet<u64> = HashSet::new();
        for g in &groups {
            assert!(g.len() <= grouper.max_group_size(), "seed {seed}: oversize");
            for &m in &g.members {
                assert!(seen.insert(m), "seed {seed}: request {m} in two groups");
                assert_eq!(reqs[m as usize].model, g.model, "seed {seed}");
            }
        }
        assert_eq!(seen.len(), n, "seed {seed}: lost requests");
    }
}

/// Property: incremental classification never exceeds group capacity and
/// always lands a request in a compatible group.
#[test]
fn prop_incremental_classify_compatible() {
    for seed in 100..130 {
        let mut rng = Rng::new(seed);
        let mut grouper = Grouper::new(2.0, 8, seed);
        let mut groups: Vec<RequestGroup> = Vec::new();
        for i in 0..200u64 {
            let r = rand_request(&mut rng, i, 4);
            let gid = grouper.classify(&r, &mut groups);
            let g = groups.iter().find(|g| g.id == gid).unwrap();
            assert_eq!(g.model, r.model);
            assert_eq!(g.class, r.class);
            assert!(g.len() <= grouper.max_group_size());
        }
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 200);
    }
}

/// Property: the scheduler's assignment is a partition of schedulable
/// groups — no group appears on two queues, none is dropped, and every
/// group lands on an instance that can serve its model when one exists.
#[test]
fn prop_scheduler_assignment_is_partition() {
    let catalog = ModelCatalog::paper_multi_model();
    let est = RwtEstimator::new(ProfileTable::default());
    let sched = GlobalScheduler::new(SchedulerConfig::default(), est);
    for seed in 200..230 {
        let mut rng = Rng::new(seed);
        let n_groups = 2 + rng.usize(30);
        let groups: Vec<RequestGroup> = (0..n_groups as u64)
            .map(|g| RequestGroup {
                id: GroupId(g),
                model: ModelId(rng.usize(4) as u32),
                class: SloClass::Batch1,
                slo: SloTarget::new(30.0 + rng.f64() * 3600.0, 1.0),
                earliest_arrival_s: rng.f64() * 50.0,
                members: (0..(1 + rng.usize(64)) as u64).collect(),
                mega: false,
            })
            .collect();
        let n_inst = 1 + rng.usize(5) as u32;
        let views: Vec<InstanceView> = (0..n_inst)
            .map(|i| {
                let mut perf_for = BTreeMap::new();
                let mut swap_time = BTreeMap::new();
                for m in catalog.ids() {
                    // Random serve capability, but instance 0 serves all.
                    if i == 0 || rng.f64() < 0.7 {
                        if let Some(p) =
                            PerfModel::try_profile(catalog.get(m), GpuKind::A100, 161.0)
                        {
                            swap_time.insert(m, p.swap_cpu_gpu_s);
                            perf_for.insert(m, p);
                        }
                    }
                }
                InstanceView {
                    id: InstanceId(i),
                    active_model: None,
                    perf_for,
                    swap_time,
                    executing: None,
                }
            })
            .collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let a = sched.schedule(&refs, &views, 0.0);
        let mut seen: HashSet<GroupId> = HashSet::new();
        for (inst, order) in &a.orders {
            for gid in order {
                assert!(seen.insert(*gid), "seed {seed}: group {gid:?} duplicated");
                let g = groups.iter().find(|g| g.id == *gid).unwrap();
                let v = views.iter().find(|v| v.id == *inst).unwrap();
                // Instance 0 serves everything, so a capable instance
                // always exists ⇒ placement must be servable.
                assert!(
                    v.can_serve(g.model),
                    "seed {seed}: group on incapable instance"
                );
            }
        }
        assert_eq!(seen.len(), groups.len(), "seed {seed}: groups dropped");
    }
}

/// Property: KV cache never leaks blocks and never double-frees across a
/// random operation schedule (alloc / append / evict / restore / free /
/// flush).
#[test]
fn prop_kv_cache_conservation() {
    for seed in 300..340 {
        let mut rng = Rng::new(seed);
        let total_tokens = 4096 + rng.usize(100_000) as u64;
        let mut kv = KvCache::new(total_tokens, 50_000);
        let total_blocks = kv.total_blocks();
        let mut gpu_live: Vec<u64> = Vec::new();
        let mut cpu_live: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..1500 {
            match rng.usize(6) {
                0 => {
                    if kv.alloc_seq(next, 1 + rng.usize(900) as u64).is_ok() {
                        gpu_live.push(next);
                    }
                    next += 1;
                }
                1 if !gpu_live.is_empty() => {
                    let s = *rng.choose(&gpu_live);
                    let _ = kv.append_token(s);
                }
                2 if !gpu_live.is_empty() => {
                    let i = rng.usize(gpu_live.len());
                    let s = gpu_live.swap_remove(i);
                    kv.free_seq(s).unwrap();
                }
                3 if !gpu_live.is_empty() => {
                    let i = rng.usize(gpu_live.len());
                    let s = gpu_live[i];
                    if kv.evict_to_cpu(s).is_ok() {
                        gpu_live.swap_remove(i);
                        cpu_live.push(s);
                    }
                }
                4 if !cpu_live.is_empty() => {
                    let i = rng.usize(cpu_live.len());
                    let s = cpu_live[i];
                    if kv.restore_from_cpu(s).is_ok() {
                        cpu_live.swap_remove(i);
                        gpu_live.push(s);
                    }
                }
                5 if rng.f64() < 0.02 => {
                    kv.flush();
                    gpu_live.clear();
                    cpu_live.clear();
                }
                _ => {}
            }
            // Invariant: used + free == total, always.
            assert_eq!(
                kv.used_blocks() + kv.free_blocks(),
                total_blocks,
                "seed {seed}"
            );
        }
        for s in gpu_live {
            kv.free_seq(s).unwrap();
        }
        assert_eq!(kv.free_blocks(), total_blocks, "seed {seed}: leak");
    }
}

/// Property: instance state machine — running + swapped + completed
/// accounts for every admitted sequence; token accounting is exact.
#[test]
fn prop_instance_accounting() {
    for seed in 400..420 {
        let mut rng = Rng::new(seed);
        let mut inst = Instance::new(InstanceConfig::new(0, GpuKind::A100), ModelCatalog::paper());
        inst.swap_model(ModelId(0), 0.0);
        let mut now = inst.busy_until();
        let mut admitted = 0u64;
        let mut completed = 0u64;
        let n = 20 + rng.usize(60) as u64;
        let mut next = 0u64;
        for _ in 0..400 {
            // Random admissions.
            if next < n && rng.f64() < 0.4 {
                let seq = RunningSeq {
                    req_id: next,
                    model: ModelId(0),
                    prompt_tokens: 1 + rng.usize(500) as u32,
                    target_output: 1 + rng.usize(200) as u32,
                    generated: 0,
                    first_token_at: None,
                    arrival_s: now,
                    prefilled: 0,
                    slice_left: 0,
                };
                if inst.try_admit(seq, now).is_ok() {
                    admitted += 1;
                    next += 1;
                }
            }
            let out = inst.step(now);
            completed += out.completed.len() as u64;
            for c in &out.completed {
                assert_eq!(c.generated, c.target_output, "seed {seed}");
            }
            if out.dt <= 0.0 && inst.is_idle() && next >= n {
                break;
            }
            now += out.dt.max(1e-3);
        }
        assert_eq!(
            completed + inst.running_len() as u64 + inst.swapped_len() as u64,
            admitted,
            "seed {seed}: sequences lost"
        );
        assert_eq!(inst.stats.requests_completed, completed, "seed {seed}");
    }
}

/// Property: the slab-backed `GlobalQueue` agrees with a shadow state
/// machine across random submit / pull / requeue / ack / fail schedules:
/// counts match, waiting ids stay ascending (FCFS base ordering), and no
/// request is ever lost or duplicated.
#[test]
fn prop_global_queue_state_machine() {
    for seed in 700..740 {
        let mut rng = Rng::new(seed);
        let mut q = GlobalQueue::new();
        // Shadow model: id → (live, waiting).
        let mut live: BTreeMap<u64, bool> = BTreeMap::new(); // id → waiting?
        let mut submitted = 0u64;
        let mut completed = 0u64;
        for _ in 0..1200 {
            match rng.usize(5) {
                0 => {
                    let id = q.submit(rand_request(&mut rng, 0, 3));
                    live.insert(id, true);
                    submitted += 1;
                }
                1 => {
                    // Pull the head of the waiting set.
                    let head = q.waiting_ids().next();
                    if let Some(id) = head {
                        q.mark_running(id);
                        live.insert(id, false);
                    }
                }
                2 => {
                    // Requeue a random running request.
                    let running: Vec<u64> = live
                        .iter()
                        .filter(|(_, &w)| !w)
                        .map(|(&id, _)| id)
                        .collect();
                    let mut running = running;
                    running.sort_unstable();
                    if !running.is_empty() {
                        let id = *rng.choose(&running);
                        q.requeue_evicted(id, 5, InstanceId(0));
                        live.insert(id, true);
                    }
                }
                3 => {
                    // Ack a random running request.
                    let running: Vec<u64> = live
                        .iter()
                        .filter(|(_, &w)| !w)
                        .map(|(&id, _)| id)
                        .collect();
                    let mut running = running;
                    running.sort_unstable();
                    if !running.is_empty() {
                        let id = *rng.choose(&running);
                        q.complete(id, Some(1.0), 2.0, 5);
                        live.remove(&id);
                        completed += 1;
                    }
                }
                4 if rng.f64() < 0.05 => {
                    // Fail an instance holding every running request.
                    let mut running: Vec<u64> = live
                        .iter()
                        .filter(|(_, &w)| !w)
                        .map(|(&id, _)| id)
                        .collect();
                    running.sort_unstable();
                    let affected = q.fail_instance(InstanceId(1), &running);
                    assert_eq!(affected.len(), running.len(), "seed {seed}");
                    for id in running {
                        live.insert(id, true);
                    }
                }
                _ => {}
            }
            // Invariants after every op.
            let expect_waiting = live.values().filter(|&&w| w).count();
            assert_eq!(q.len_waiting(), expect_waiting, "seed {seed}");
            assert_eq!(q.len_total(), live.len(), "seed {seed}");
            let ids: Vec<u64> = q.waiting_ids().collect();
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "seed {seed}: order");
            for id in ids {
                assert!(live[&id], "seed {seed}: ghost waiting id {id}");
            }
        }
        assert_eq!(
            completed + q.len_total() as u64,
            submitted,
            "seed {seed}: conservation"
        );
        assert_eq!(q.completed.len() as u64, completed, "seed {seed}");
    }
}

/// Ids in `oracle` whose state satisfies `pred`, ascending (global
/// submit order — what a single unsharded FCFS queue serves).
fn oracle_ids(
    oracle: &BTreeMap<u64, (ModelId, RequestState)>,
    pred: fn(RequestState) -> bool,
) -> Vec<u64> {
    oracle
        .iter()
        .filter(|(_, &(_, s))| pred(s))
        .map(|(&id, _)| id)
        .collect()
}

/// Property (tentpole: sharded routing ≡ unified queue): the
/// per-model-sharded broker must be observationally identical to one
/// unified FCFS queue. The oracle is a flat map keyed by global submit
/// id — exactly the pre-sharding single-slab state — and after every
/// randomized multi-model op the waiting set (full sequence, not just
/// order), the id→model routing, and every counter must agree with it.
#[test]
fn prop_sharded_routing_equals_unified_queue() {
    let is_waiting =
        |s: RequestState| matches!(s, RequestState::Waiting | RequestState::Evicted);
    let is_running = |s: RequestState| matches!(s, RequestState::Running);
    for seed in 1000..1040 {
        let mut rng = Rng::new(seed);
        let mut q = GlobalQueue::new();
        // 1..=8 models: from the degenerate single-shard case up to a
        // catalog wide enough that every op crosses shard boundaries.
        let n_models = 1 + rng.usize(8) as u32;
        let mut oracle: BTreeMap<u64, (ModelId, RequestState)> = BTreeMap::new();
        let mut on_inst: BTreeMap<u64, u32> = BTreeMap::new();
        let mut next_id = 0u64;
        let mut completed = 0usize;
        let mut shed = 0usize;
        for _ in 0..1200 {
            match rng.usize(7) {
                0 | 1 => {
                    let r = rand_request(&mut rng, next_id, n_models);
                    let model = r.model;
                    let id = q.submit(r);
                    assert_eq!(id, next_id, "seed {seed}: ids not dense and global");
                    oracle.insert(id, (model, RequestState::Waiting));
                    next_id += 1;
                }
                2 => {
                    // Pull an arbitrary waiting request (not just the head).
                    let waiting = oracle_ids(&oracle, is_waiting);
                    if !waiting.is_empty() {
                        let id = *rng.choose(&waiting);
                        assert!(q.mark_running(id).is_some(), "seed {seed}");
                        oracle.get_mut(&id).unwrap().1 = RequestState::Running;
                        on_inst.insert(id, rng.usize(3) as u32);
                    }
                }
                3 => {
                    let running = oracle_ids(&oracle, is_running);
                    if !running.is_empty() {
                        let id = *rng.choose(&running);
                        let inst = on_inst.remove(&id).unwrap();
                        q.requeue_evicted(id, 4, InstanceId(inst));
                        oracle.get_mut(&id).unwrap().1 = RequestState::Evicted;
                    }
                }
                4 => {
                    let running = oracle_ids(&oracle, is_running);
                    if !running.is_empty() {
                        let id = *rng.choose(&running);
                        q.complete(id, Some(1.0), 2.0, 7);
                        oracle.remove(&id);
                        on_inst.remove(&id);
                        completed += 1;
                    }
                }
                5 if rng.f64() < 0.2 => {
                    let waiting = oracle_ids(&oracle, is_waiting);
                    if !waiting.is_empty() {
                        let id = *rng.choose(&waiting);
                        assert!(q.shed(id), "seed {seed}: shed refused a waiting id");
                        oracle.get_mut(&id).unwrap().1 = RequestState::Shed;
                        shed += 1;
                    }
                }
                6 if rng.f64() < 0.1 => {
                    // Down one instance: its running requests — spread
                    // across many model shards — all revert to Waiting.
                    let dead = rng.usize(3) as u32;
                    let downed: Vec<u64> = on_inst
                        .iter()
                        .filter(|(_, &i)| i == dead)
                        .map(|(&id, _)| id)
                        .collect();
                    let affected = q.fail_instance(InstanceId(dead), &downed);
                    assert_eq!(affected, downed, "seed {seed}: fail missed requests");
                    for id in downed {
                        on_inst.remove(&id);
                        oracle.get_mut(&id).unwrap().1 = RequestState::Waiting;
                    }
                }
                _ => {}
            }
            // The sharded broker must present the unified view.
            let want = oracle_ids(&oracle, is_waiting);
            let got: Vec<u64> = q.waiting_ids().collect();
            assert_eq!(got, want, "seed {seed}: waiting set diverged from oracle");
            assert_eq!(q.len_waiting(), want.len(), "seed {seed}");
            assert_eq!(q.len_total(), oracle.len(), "seed {seed}");
            assert_eq!(q.len_completed(), completed, "seed {seed}");
            assert_eq!(q.len_shed(), shed, "seed {seed}");
            for &id in &want {
                assert_eq!(
                    q.get(id).map(|r| r.model),
                    Some(oracle[&id].0),
                    "seed {seed}: id {id} routed to the wrong shard"
                );
            }
        }
        // Route-table retirement: every live id resolves, every
        // completed id is gone for good.
        for id in 0..next_id {
            assert_eq!(
                q.get(id).is_some(),
                oracle.contains_key(&id),
                "seed {seed}: stale route for id {id}"
            );
        }
    }
}

/// Property (scheduler-pass skipping): per-shard dirt tracks exactly
/// the models that mutated since the last pass — `begin_pass` reports
/// clean shards as provably skippable and resets the flags.
#[test]
fn prop_shard_dirt_skips_clean_models() {
    let mut rng = Rng::new(77);
    let mut q = GlobalQueue::new();
    let k = 6usize;
    let mut head: Vec<u64> = Vec::new();
    for m in 0..k {
        let mut r = rand_request(&mut rng, m as u64, 1);
        r.model = ModelId(m as u32);
        head.push(q.submit(r));
    }
    assert_eq!(q.shard_count(), k, "one shard per model");
    assert_eq!(q.begin_pass(), (k, 0), "submits dirtied every shard");
    assert_eq!(q.begin_pass(), (0, k), "an idle pass scans nothing");
    // One model mutates → exactly one shard rescans.
    assert!(q.mark_running(head[3]).is_some());
    assert_eq!(q.begin_pass(), (1, k - 1));
    // Mutation-free group dirt (drain re-dirty) goes through touch_model.
    q.touch_model(ModelId(1));
    assert_eq!(q.begin_pass(), (1, k - 1));
    // A completion shrinks its group, so its shard must rescan too
    // (the engine marks the shrunk group dirty).
    q.complete(head[3], Some(1.0), 2.0, 3);
    assert_eq!(q.begin_pass(), (1, k - 1));
    // Cumulative stats cover every pass above.
    let (scanned, skipped) = q.shard_stats();
    assert_eq!(scanned + skipped, 5 * k as u64);
    assert_eq!(scanned, k as u64 + 3);
}

/// A100 view serving every paper-catalog model.
fn a100_view(i: u32) -> InstanceView {
    let catalog = ModelCatalog::paper();
    let mut perf_for = BTreeMap::new();
    let mut swap_time = BTreeMap::new();
    for m in catalog.ids() {
        if let Some(p) = PerfModel::try_profile(catalog.get(m), GpuKind::A100, 161.0) {
            swap_time.insert(m, p.swap_cpu_gpu_s);
            perf_for.insert(m, p);
        }
    }
    InstanceView {
        id: InstanceId(i),
        active_model: None,
        perf_for,
        swap_time,
        executing: None,
    }
}

/// Property (§4 Fault Tolerance): after an instance failure, the
/// surviving virtual queues are a pure function of the global queue —
/// two independent rebuilds (fresh grouper, fresh scheduler) produce
/// identical per-instance orderings, and no waiting request is dropped.
#[test]
fn prop_virtual_queues_rebuild_identically_after_failure() {
    for seed in 800..820 {
        let mut rng = Rng::new(seed);
        let mut q = GlobalQueue::new();
        let n = 40 + rng.usize(160);
        let ids: Vec<u64> = (0..n as u64)
            .map(|i| q.submit(rand_request(&mut rng, i, 3)))
            .collect();
        // Spread some requests across 3 instances' running batches.
        let mut per_inst: Vec<Vec<u64>> = vec![Vec::new(); 3];
        for &id in &ids {
            if rng.f64() < 0.4 {
                let v = rng.usize(3);
                q.mark_running(id);
                per_inst[v].push(id);
            }
        }
        let dead = rng.usize(3);
        q.fail_instance(InstanceId(dead as u32), &per_inst[dead]);

        let rebuild = |q: &GlobalQueue| {
            let reqs: Vec<&Request> = q.waiting_ids().filter_map(|id| q.get(id)).collect();
            let mut grouper = Grouper::new(4.0, 16, seed ^ 0xABCD);
            let groups = grouper.regroup(&reqs);
            let member_count: usize = groups.iter().map(|g| g.len()).sum();
            let refs: Vec<&RequestGroup> = groups.iter().collect();
            let views: Vec<InstanceView> = (0..3u32)
                .filter(|&i| i as usize != dead)
                .map(a100_view)
                .collect();
            let sched = GlobalScheduler::new(
                SchedulerConfig::default(),
                RwtEstimator::new(ProfileTable::default()),
            );
            let a = sched.schedule(&refs, &views, 0.0);
            let mut orders: Vec<(u32, Vec<GroupId>)> = a
                .orders
                .into_iter()
                .map(|(k, v)| (k.0, v))
                .collect();
            orders.sort();
            (orders, member_count)
        };

        let (orders_a, members_a) = rebuild(&q);
        let (orders_b, members_b) = rebuild(&q);
        assert_eq!(orders_a, orders_b, "seed {seed}: rebuild not deterministic");
        assert_eq!(members_a, members_b, "seed {seed}");
        assert_eq!(
            members_a,
            q.len_waiting(),
            "seed {seed}: rebuild dropped waiting requests"
        );
        // The dead instance's requests are all waiting again.
        for &id in &per_inst[dead] {
            assert_eq!(
                q.get(id).unwrap().state,
                RequestState::Waiting,
                "seed {seed}"
            );
        }
    }
}

/// Property (million-request hot path): the timer wheel pops in exactly
/// the `BinaryHeap` `(t, seq)` order under adversarial workloads —
/// coarse-quantized times (duplicate timestamps are common), far-future
/// pushes (level-1 cascade and overflow re-base), pushes *behind* the
/// drain cursor (late events spliced into the live drain buffer), and
/// the wake-coalescing / stale-`take_due_wake` paths the engine leans
/// on. Every pop and every wake decision must agree bit-for-bit.
#[test]
fn prop_timer_wheel_matches_heap_order() {
    use qlm::sim::event::{EventCore, EventKind};
    for seed in 900..940 {
        let mut rng = Rng::new(seed);
        let mut wheel = EventCore::new(4);
        let mut heap = EventCore::new_heap_baseline(4);
        let compare = |a: Option<qlm::sim::event::Event>, b: Option<qlm::sim::event::Event>| {
            let key = |e: &qlm::sim::event::Event| (e.t.to_bits(), e.seq);
            assert_eq!(a.as_ref().map(key), b.as_ref().map(key), "seed {seed}: pop diverged");
            assert_eq!(a.map(|e| e.kind), b.map(|e| e.kind), "seed {seed}: kind diverged");
            a
        };
        let mut last_t = 0.0f64;
        let n_ops = 200 + rng.usize(600);
        for i in 0..n_ops {
            let roll = rng.f64();
            if roll < 0.55 {
                let t = if rng.f64() < 0.1 {
                    // Far future: level-1 cascade / overflow re-base.
                    rng.range(1.0e4, 3.0e6)
                } else if rng.f64() < 0.2 {
                    // Behind the cursor: a late push into the drain.
                    (last_t - rng.f64() * 5.0).max(0.0)
                } else {
                    // Quantized: duplicate timestamps are common.
                    last_t + rng.usize(400) as f64 * 0.05
                };
                let kind = if rng.f64() < 0.5 {
                    EventKind::Arrival(i)
                } else {
                    EventKind::Fail(InstanceId(rng.usize(4) as u32))
                };
                wheel.push(t, kind);
                heap.push(t, kind);
            } else if roll < 0.8 {
                if let Some(e) = compare(wheel.pop(), heap.pop()) {
                    last_t = e.t;
                }
            } else {
                // Wake coalescing and stale-wake takes must agree too.
                let id = InstanceId(rng.usize(4) as u32);
                if rng.f64() < 0.6 {
                    let t = last_t + rng.f64() * 2.0;
                    wheel.wake(id, t);
                    heap.wake(id, t);
                } else {
                    let t = last_t + rng.range(-1.0, 1.0);
                    assert_eq!(
                        wheel.take_due_wake(id, t),
                        heap.take_due_wake(id, t),
                        "seed {seed}: stale-wake decision diverged"
                    );
                }
            }
            assert_eq!(wheel.queue_len(), heap.queue_len(), "seed {seed}: len diverged");
        }
        // Drain both to empty: the tails must match event for event.
        while compare(wheel.pop(), heap.pop()).is_some() {}
        assert_eq!(wheel.queue_len(), 0, "seed {seed}");
    }
}

/// Property: RWT estimates are monotone — adding a group ahead never
/// decreases a group's waiting time; swap charges only at model changes.
#[test]
fn prop_rwt_monotone_in_queue_prefix() {
    let catalog = ModelCatalog::paper();
    let est = RwtEstimator::new(ProfileTable::default());
    let perf = PerfModel::profile(catalog.get(ModelId(0)), GpuKind::A100, 161.0);
    for seed in 500..530 {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.usize(20);
        let groups: Vec<RequestGroup> = (0..n as u64)
            .map(|g| RequestGroup {
                id: GroupId(g),
                model: ModelId(rng.usize(3) as u32),
                class: SloClass::Batch1,
                slo: SloTarget::new(60.0, 1.0),
                earliest_arrival_s: 0.0,
                members: (0..(1 + rng.usize(128)) as u64).collect(),
                mega: false,
            })
            .collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let full = est.estimate_queue(&refs, &perf, Some(ModelId(0)), |_| 3.0);
        // Wait times are non-decreasing along the queue when service is
        // non-negative (they are cumulative sums of non-negative terms).
        for w in full.windows(2) {
            assert!(
                w[1].wait_mean_s >= w[0].wait_mean_s - 1e-9,
                "seed {seed}: waits not monotone"
            );
        }
        // Dropping the head group never increases anyone's wait.
        if refs.len() > 1 {
            let tail = est.estimate_queue(&refs[1..], &perf, Some(ModelId(0)), |_| 3.0);
            for (a, b) in tail.iter().zip(full[1..].iter()) {
                assert!(
                    a.wait_mean_s <= b.wait_mean_s + 3.0 + 1e-9,
                    "seed {seed}: removing head increased wait"
                );
            }
        }
    }
}
