//! Capacity-subsystem integration tests: planner-vs-simulator agreement,
//! planner monotonicity, autoscale end-to-end economics, and
//! drain-correctness (no request lost during scale-down).

use qlm::backend::{GpuKind, ModelCatalog, ModelId};
use qlm::baselines::Policy;
use qlm::capacity::{AutoscaleConfig, CapacityPlanner, PlannerConfig, TierSpec};
use qlm::sim::{fleet_a100, SimConfig, Simulation};
use qlm::workload::{
    ArrivalProcess, RequestClassSpec, Scenario, ScenarioKnobs, ShareGptSampler, SloClass, Trace,
    WorkloadSpec,
};

fn a100_tier(max: u32) -> PlannerConfig {
    PlannerConfig {
        tiers: vec![TierSpec {
            gpu: GpuKind::A100,
            max,
        }],
        ..Default::default()
    }
}

/// Property (satellite): more load ⇒ never fewer devices. Swept over
/// the mixed-slo scenario's own spec so the planner sees exactly what
/// `qlm plan --scenario mixed-slo` sees.
#[test]
fn planner_monotone_more_load_never_fewer_devices() {
    let mut last = 0;
    for rate in [3.0, 5.0, 8.0, 12.0, 20.0, 30.0] {
        let k = ScenarioKnobs {
            rate,
            requests: 2000,
            fleet: 4,
            seed: 5,
        };
        let run = Scenario::MixedSlo.build(&k);
        let planner =
            CapacityPlanner::from_spec(&run.spec, run.catalog, a100_tier(64), k.seed);
        let n = planner.plan().total_devices();
        assert!(n >= last, "rate {rate}: planned {n} < {last} at lower load");
        last = n;
    }
    assert!(last >= 3, "30 req/s of W_A must need several devices");
}

/// Acceptance: `qlm plan` on the mixed-slo scenario recommends a fleet
/// within 1 device of the simulation-validated minimum — the smallest
/// static fleet whose *every* SLO class attains ≥ 95% in a full run of
/// the same spec.
#[test]
fn planner_matches_simulated_minimum_within_one_device() {
    let k = ScenarioKnobs {
        rate: 10.0,
        // Long enough (≈300 s of arrivals) that an under-provisioned
        // fleet's backlog visibly blows through the 60 s batch-1 SLO —
        // short traces make any fleet look sufficient.
        requests: 6000,
        fleet: 4,
        seed: 42,
    };
    let run = Scenario::MixedSlo.build(&k);
    let trace = Trace::generate(&run.spec, k.seed);
    let attained = |n: u32| -> bool {
        let cfg = SimConfig::new(fleet_a100(n), run.catalog.clone(), Policy::qlm());
        let m = Simulation::new(cfg, &trace).run(&trace);
        SloClass::ALL
            .iter()
            .all(|&c| m.slo_attainment_class(c) >= 0.95)
    };
    let mut sim_min = None;
    for n in 1..=8u32 {
        if attained(n) {
            sim_min = Some(n);
            break;
        }
    }
    let sim_min = sim_min.expect("8 A100s must suffice for 10 req/s of W_A");
    let planner = CapacityPlanner::from_spec(&run.spec, run.catalog.clone(), a100_tier(8), k.seed);
    let plan = planner.plan();
    assert!(plan.feasible, "{plan:?}");
    let planned = plan.count(GpuKind::A100);
    assert!(
        (planned as i64 - sim_min as i64).abs() <= 1,
        "planner recommends {planned}, simulation-validated minimum is {sim_min}"
    );
}

/// Burst-then-trickle workload: scale up for the burst, drain back down
/// for the tail. The shape that makes a fixed fleet either too small
/// (trough-sized) or wasteful (peak-sized) — Fig. 1's dichotomy.
/// Vicuna-13B so the burst forms a real *waiting* backlog (Mistral's KV
/// headroom would swallow it into the running batch).
fn burst_then_trickle(seed: u64) -> Trace {
    let spec = WorkloadSpec {
        name: "burst-then-trickle".into(),
        streams: vec![
            RequestClassSpec {
                class: SloClass::Interactive,
                models: vec![ModelId(1)],
                arrivals: ArrivalProcess::Poisson { rate: 40.0 },
                count: 1000,
                mega_fraction: 0.0,
            },
            RequestClassSpec {
                class: SloClass::Batch1,
                models: vec![ModelId(1)],
                arrivals: ArrivalProcess::Poisson { rate: 0.5 },
                count: 150,
                mega_fraction: 0.0,
            },
        ],
        sampler: ShareGptSampler::default(),
    };
    Trace::generate(&spec, seed)
}

fn autoscale_cfg() -> AutoscaleConfig {
    let mut a = AutoscaleConfig::bounded(1, 4, GpuKind::A100);
    // Test-scale hysteresis: seconds, not production SLO fractions.
    a.up_frac = 0.1;
    a.breach_passes = 2;
    a.cooldown_s = 5.0;
    a.calm_passes = 10;
    a
}

/// Acceptance (satellite e2e): the autoscaled run attains at least the
/// trough-sized static fleet's SLO rate while consuming fewer
/// device-hours than the peak-sized static fleet.
#[test]
fn autoscale_beats_trough_attainment_with_fewer_device_hours_than_peak() {
    let trace = burst_then_trickle(3);
    let total = trace.len();
    let run_static = |n: u32| {
        let cfg = SimConfig::new(fleet_a100(n), ModelCatalog::paper(), Policy::qlm());
        Simulation::new(cfg, &trace).run(&trace)
    };
    let trough = run_static(1);
    let peak = run_static(4);
    let auto = {
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        cfg.autoscale = Some(autoscale_cfg());
        Simulation::new(cfg, &trace).run(&trace)
    };
    assert_eq!(auto.records.len(), total);
    assert_eq!(auto.completed_count(), total, "{}", auto.summary());
    assert!(auto.scale_ups >= 1, "the burst must provision capacity");
    assert!(
        auto.slo_attainment() >= trough.slo_attainment() - 1e-9,
        "auto {} vs trough-static {}",
        auto.slo_attainment(),
        trough.slo_attainment()
    );
    assert!(
        auto.device_seconds < peak.device_seconds,
        "auto {:.0} device-seconds vs peak-static {:.0}",
        auto.device_seconds,
        peak.device_seconds
    );
}

/// Acceptance (satellite): drain correctness — scale-down happens while
/// the trickle is still arriving, and not a single request is lost.
#[test]
fn scale_down_drains_without_losing_requests() {
    let trace = burst_then_trickle(11);
    let total = trace.len();
    let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
    cfg.autoscale = Some(autoscale_cfg());
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert_eq!(m.completed_count(), total, "{}", m.summary());
    assert!(m.scale_ups >= 1, "burst must scale up first");
    assert!(
        m.scale_downs >= 1,
        "the 300 s trickle tail must drain the burst capacity \
         (ups {}, downs {})",
        m.scale_ups,
        m.scale_downs
    );
    // Conservation: every request recorded exactly once, none shed.
    let mut ids: Vec<u64> = m.records.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), total);
    assert_eq!(m.shed_count(), 0);
}

/// Scale-down determinism: the drain path must not introduce ordering
/// nondeterminism (same trace, same fleet history, same metrics).
#[test]
fn autoscaled_run_is_reproducible() {
    let trace = burst_then_trickle(17);
    let run = || {
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        cfg.autoscale = Some(autoscale_cfg());
        Simulation::new(cfg, &trace).run(&trace)
    };
    let a = run();
    let b = run();
    assert_eq!(a.completed_count(), b.completed_count());
    assert_eq!((a.scale_ups, a.scale_downs), (b.scale_ups, b.scale_downs));
    assert!((a.device_seconds - b.device_seconds).abs() < 1e-9);
    assert!((a.slo_attainment() - b.slo_attainment()).abs() < 1e-12);
}
