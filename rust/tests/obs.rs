//! Trace-determinism suite for the observability subsystem (tier-2).
//!
//! The flight recorder's contract is stronger than "the metrics don't
//! change": the exported JSONL itself must be *byte-identical* across
//! re-runs and `--threads` lane counts (events are recorded on the
//! event-loop thread in dispatch order, floats render at fixed width),
//! and turning observability on must leave the golden metrics digest
//! bit-identical to a run with it off (the observer records, it never
//! steers). Both halves are asserted here on the same scenario shapes
//! the golden suite pins — scale (steady-state incremental scheduling)
//! and autoscale (provision/drain churn).

use qlm::baselines::Policy;
use qlm::metrics::RunMetrics;
use qlm::obs::{ObsConfig, ObsReport, ReportOptions};
use qlm::sim::Simulation;
use qlm::workload::{Scenario, ScenarioKnobs, Trace};

/// One scenario run with the given observability config (mirrors the
/// golden suite's `run_scenario`, plus the obs knobs).
fn run_obs(
    scenario: Scenario,
    policy: Policy,
    requests: usize,
    threads: usize,
    obs: ObsConfig,
) -> (RunMetrics, Option<ObsReport>) {
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests,
        fleet: scenario.default_fleet(),
        seed: 42,
    };
    let run = scenario.build(&knobs);
    let trace = Trace::generate(&run.spec, knobs.seed);
    let mut cfg = run.sim_config(policy);
    cfg.seed = knobs.seed;
    cfg.threads = threads;
    cfg.obs = obs;
    Simulation::new(cfg, &trace).run_with_obs(&trace)
}

fn full_obs() -> ObsConfig {
    ObsConfig {
        trace: true,
        telemetry_every_s: Some(10.0),
    }
}

#[test]
fn same_seed_produces_byte_identical_jsonl() {
    let (_, a) = run_obs(Scenario::MixedSlo, Policy::qlm(), 400, 1, full_obs());
    let (_, b) = run_obs(Scenario::MixedSlo, Policy::qlm(), 400, 1, full_obs());
    let (a, b) = (a.expect("obs enabled"), b.expect("obs enabled"));
    assert!(!a.trace_jsonl.is_empty(), "trace recorded nothing");
    assert_eq!(a.trace_jsonl, b.trace_jsonl, "trace bytes differ run-to-run");
    assert_eq!(
        a.telemetry_jsonl, b.telemetry_jsonl,
        "telemetry bytes differ run-to-run"
    );
    // The lifecycle kinds a mixed-SLO run must exercise.
    for kind in ["submitted", "pulled", "first-token", "completed"] {
        assert!(
            a.trace_jsonl.contains(&format!(r#""ev":"{kind}""#)),
            "no {kind} events in the trace"
        );
    }
}

#[test]
fn threads_do_not_change_trace_bytes() {
    // The scale shape at test size: every pooled lane count must export
    // the identical trace and telemetry bytes to the serial run — the
    // recorder sits on the single-threaded event loop, so lane count
    // must be invisible in the JSONL, not merely in the metrics.
    let (serial_m, serial) = run_obs(Scenario::Scale, Policy::qlm(), 1200, 1, full_obs());
    let serial = serial.expect("obs enabled");
    for threads in [2, 4] {
        let (par_m, par) = run_obs(Scenario::Scale, Policy::qlm(), 1200, threads, full_obs());
        let par = par.expect("obs enabled");
        assert_eq!(serial_m.digest(), par_m.digest(), "threads={threads}");
        assert_eq!(
            serial.trace_jsonl, par.trace_jsonl,
            "threads={threads} changed the trace bytes"
        );
        assert_eq!(
            serial.telemetry_jsonl, par.telemetry_jsonl,
            "threads={threads} changed the telemetry bytes"
        );
    }
}

#[test]
fn tracing_on_leaves_golden_digests_unchanged() {
    // Record-never-steer, asserted end to end: a run with the recorder,
    // sampler, and ledger all on must produce the bit-identical metrics
    // digest of a run with observability off — on both golden shapes
    // (scale: steady state; autoscale: provision/drain churn).
    for (scenario, requests) in [(Scenario::Scale, 1200), (Scenario::Autoscale, 1000)] {
        let (off, no_report) = run_obs(scenario, Policy::qlm(), requests, 1, ObsConfig::default());
        assert!(no_report.is_none(), "disabled obs must allocate no state");
        let (on, report) = run_obs(scenario, Policy::qlm(), requests, 1, full_obs());
        assert!(report.is_some());
        assert_eq!(
            off.digest(),
            on.digest(),
            "observability changed {} metrics",
            scenario.name()
        );
    }
}

#[test]
fn ledger_joins_and_report_renders_rwt_table() {
    let (_, report) = run_obs(Scenario::MixedSlo, Policy::qlm(), 400, 1, full_obs());
    let report = report.expect("obs enabled");
    assert!(
        !report.rwt_errors.is_empty(),
        "no predicted/actual RWT pairs joined"
    );
    for e in &report.rwt_errors {
        assert!(e.n > 0);
        assert!(e.mae_s.is_finite() && e.mae_s >= 0.0);
        assert!(e.p90_s.is_finite() && e.p90_s >= 0.0);
    }
    // The offline report replays the same join from the trace bytes.
    let rendered = qlm::obs::render(
        &report.trace_jsonl,
        &ReportOptions {
            req: None,
            timelines: 2,
        },
    );
    assert!(rendered.contains("RWT prediction error"));
    assert!(rendered.contains("mae_s"));
    assert!(rendered.contains("interactive"));
    assert!(rendered.contains("timeline"));
    // Pass-mix counters flowed through the policy seam.
    assert!(report.sched.passes > 0, "no scheduler passes absorbed");
    assert_eq!(report.sched.passes, report.sched.full + report.sched.delta);
}

#[test]
fn telemetry_samples_on_fixed_simulated_cadence() {
    let (_, report) = run_obs(Scenario::MixedSlo, Policy::qlm(), 400, 1, full_obs());
    let telemetry = report.expect("obs enabled").telemetry_jsonl.expect("cadence set");
    assert!(!telemetry.is_empty(), "sampler fired never");
    let mut prev = 0.0f64;
    for line in telemetry.lines() {
        let t = qlm::obs::json::field_f64(line, "t").expect("sample has a timestamp");
        assert!(t > prev, "samples must advance strictly in sim time");
        // Boundaries are exact multiples of the 10 s cadence.
        assert!(
            (t / 10.0 - (t / 10.0).round()).abs() < 1e-9,
            "sample at t={t} is off-cadence"
        );
        prev = t;
    }
}
