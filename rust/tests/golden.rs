//! Golden-equivalence suite for the decomposed engine.
//!
//! The engine refactor (EventCore / SchedulingPolicy / FleetController /
//! parallel view pass) is required to preserve behavior bit for bit, so
//! every test here pins a seed and asserts *exact* `RunMetrics`
//! equality via an order-stable digest:
//!
//! * run-to-run: the same (policy, scenario, seed) always produces the
//!   identical digest — any nondeterminism in the new seams (HashMap
//!   iteration, thread scheduling) breaks it;
//! * threads: `--threads 4` ≡ `--threads 1` on the scale and autoscale
//!   scenario shapes — the parallel view/pricing pass must be
//!   invisible in the metrics.
//!
//! Wall-clock fields (`scheduler_wall_s`) are excluded from the digest;
//! everything the paper's figures are computed from is included.
//!
//! On top of the self-consistency checks, a committed pinned-digest
//! ledger (`tests/golden_digests.txt`, regenerated with
//! `QLM_BLESS_GOLDEN=1`) pins each (scenario, policy) digest across
//! commits, so a future refactor that silently changes behavior —
//! deterministic or not — fails here instead of shipping. The ledger
//! is blessed and checked on the same platform (CI): float libm
//! differences across OS/arch can shift last-ulp bits, so treat a
//! local mismatch on a different platform as a signal to re-check on
//! CI, not necessarily a bug.

use qlm::baselines::Policy;
use qlm::coordinator::lso::LsoConfig;
use qlm::metrics::RunMetrics;
use qlm::sim::Simulation;
use qlm::workload::{Scenario, ScenarioKnobs, Trace};

/// FNV-1a over every deterministic field of the run: per-request
/// outcomes (records are sorted by id in `finish`), autoscaler actions,
/// the device-seconds ledger, and the scheduler invocation count.
fn digest(m: &RunMetrics) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(PRIME);
    };
    for r in &m.records {
        mix(r.id);
        mix(r.model.0 as u64);
        mix(r.arrival_s.to_bits());
        mix(r.first_token_s.map(f64::to_bits).unwrap_or(u64::MAX));
        mix(r.completed_s.map(f64::to_bits).unwrap_or(u64::MAX));
        mix(r.shed as u64);
    }
    mix(m.records.len() as u64);
    mix(m.duration_s.to_bits());
    mix(m.device_seconds.to_bits());
    mix(m.scale_ups);
    mix(m.scale_downs);
    mix(m.scheduler_invocations);
    h
}

/// Run one scenario at reduced size with the given policy/thread count.
fn run_scenario(scenario: Scenario, policy: Policy, requests: usize, threads: usize) -> RunMetrics {
    // Default fleets (8 for the heavy scenarios) keep the view count
    // above the parallel pass's fan-out threshold (2 × threads).
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests,
        fleet: scenario.default_fleet(),
        seed: 42,
    };
    let run = scenario.build(&knobs);
    let trace = Trace::generate(&run.spec, knobs.seed);
    // Shared assembly (`ScenarioRun::sim_config`): the suite pins the
    // exact configuration the `qlm sim` / `qlm compare` CLI paths run.
    let mut cfg = run.sim_config(policy);
    cfg.seed = knobs.seed;
    cfg.threads = threads;
    Simulation::new(cfg, &trace).run(&trace)
}

#[test]
fn threaded_equals_serial_on_scale_scenario() {
    // The scale shape (mixed SLO classes, multiple models, incremental
    // scheduler in steady state) at test size: 4 worker threads must
    // produce the identical digest to the serial run.
    let serial = run_scenario(Scenario::Scale, Policy::qlm(), 2500, 1);
    let par = run_scenario(Scenario::Scale, Policy::qlm(), 2500, 4);
    assert_eq!(serial.completed_count(), par.completed_count());
    assert_eq!(digest(&serial), digest(&par), "threads changed the metrics");
}

#[test]
fn threaded_equals_serial_on_autoscale_scenario() {
    // Autoscale adds view-set churn (provision + drain) on top of the
    // parallel pass — the hardest case for threads ≡ serial. Two
    // workers so the trough fleet (4 views) already fans out.
    let serial = run_scenario(Scenario::Autoscale, Policy::qlm(), 2000, 1);
    let par = run_scenario(Scenario::Autoscale, Policy::qlm(), 2000, 2);
    assert_eq!(serial.scale_ups, par.scale_ups);
    assert_eq!(serial.scale_downs, par.scale_downs);
    assert_eq!(digest(&serial), digest(&par), "threads changed the metrics");
}

/// The pinned-digest ledger: one `scenario/policy digest` line per
/// (policy, scenario) pair, committed next to this file. When present,
/// the golden test asserts today's digests against it — so ANY
/// behavior drift in a future refactor (a changed tie-break, a ported
/// policy's load formula) fails the suite even though the drifted
/// engine is itself perfectly deterministic. Regenerate deliberately
/// with `QLM_BLESS_GOLDEN=1 cargo test -q --test golden` after an
/// *intentional* behavior change and commit the diff.
fn ledger_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden_digests.txt")
}

#[test]
fn golden_digests_reproducible_per_policy_and_scenario() {
    // Every policy behind the trait seam, on the paper's two headline
    // workload shapes: the same pinned seed must reproduce the same
    // metrics digest run over run (and the digest must be non-trivial —
    // the run actually served traffic), and must match the committed
    // pinned-digest ledger when one exists.
    let policies = [
        Policy::qlm(),
        Policy::qlm_with(LsoConfig::without_eviction()),
        Policy::qlm_with(LsoConfig::without_swapping()),
        Policy::qlm_with(LsoConfig::without_load_balancing()),
        Policy::Shepherd,
        Policy::Edf,
        Policy::Sjf,
        Policy::VllmFcfs,
    ];
    let pinned: std::collections::HashMap<String, u64> = std::fs::read_to_string(ledger_path())
        .map(|s| {
            s.lines()
                .filter_map(|l| {
                    let (key, val) = l.trim().split_once(' ')?;
                    Some((key.to_string(), val.parse().ok()?))
                })
                .collect()
        })
        .unwrap_or_default();
    let mut ledger = String::new();
    for scenario in [Scenario::MixedSlo, Scenario::MultiModel] {
        for policy in policies {
            let a = run_scenario(scenario, policy, 400, 1);
            let b = run_scenario(scenario, policy, 400, 1);
            assert!(
                a.completed_count() > 0,
                "{} on {} served nothing: {}",
                policy.name(),
                scenario.name(),
                a.summary()
            );
            assert_eq!(
                digest(&a),
                digest(&b),
                "{} on {} is not reproducible",
                policy.name(),
                scenario.name()
            );
            let key = format!("{}/{}", scenario.name(), policy.name());
            if let Some(&want) = pinned.get(&key) {
                assert_eq!(
                    digest(&a),
                    want,
                    "{key}: metrics drifted from the committed golden ledger \
                     (intentional? re-bless with QLM_BLESS_GOLDEN=1)"
                );
            }
            ledger.push_str(&format!("{key} {}\n", digest(&a)));
        }
    }
    if std::env::var_os("QLM_BLESS_GOLDEN").is_some() {
        std::fs::write(ledger_path(), ledger).expect("write golden ledger");
    }
}

#[test]
fn threaded_equals_serial_across_policies() {
    // The parallel pass must be invisible for every policy family, not
    // just QLM (baselines share the view-refresh fan-out; the 8-wide
    // mixed-slo fleet fans out at 4 workers).
    for policy in [Policy::qlm(), Policy::Edf, Policy::Sjf, Policy::Shepherd] {
        let serial = run_scenario(Scenario::MixedSlo, policy, 300, 1);
        let par = run_scenario(Scenario::MixedSlo, policy, 300, 4);
        assert_eq!(
            digest(&serial),
            digest(&par),
            "threads changed {} metrics",
            policy.name()
        );
    }
}
