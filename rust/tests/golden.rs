//! Golden-equivalence suite for the decomposed engine + scheduler.
//!
//! The layered refactors (EventCore / SchedulingPolicy / FleetController
//! / the `coordinator/sched` scheduling core / the persistent worker
//! pool) are required to preserve behavior bit for bit, so every test
//! here pins a seed and asserts *exact* `RunMetrics` equality via the
//! order-stable [`RunMetrics::digest`]:
//!
//! * run-to-run: the same (policy, scenario, seed) always produces the
//!   identical digest — any nondeterminism in the seams (HashMap
//!   iteration, pool lane scheduling) breaks it;
//! * threads: every lane count in {2, 4} ≡ serial on the scale and
//!   autoscale scenario shapes — the persistent pool behind the view
//!   refresh and the repricing walk must be invisible in the metrics.
//!
//! Wall-clock fields (`scheduler_wall_s`) are excluded from the digest;
//! everything the paper's figures are computed from is included.
//!
//! On top of the self-consistency checks, a committed pinned-digest
//! ledger (`tests/golden_digests.txt`, regenerated with
//! `QLM_BLESS_GOLDEN=1`) pins each (scenario, policy) digest across
//! commits, so a future refactor that silently changes behavior —
//! deterministic or not — fails here instead of shipping. The ledger
//! is blessed and checked on the same platform (CI): float libm
//! differences across OS/arch can shift last-ulp bits, so treat a
//! local mismatch on a different platform as a signal to re-check on
//! CI, not necessarily a bug.

use qlm::baselines::Policy;
use qlm::coordinator::lso::LsoConfig;
use qlm::metrics::RunMetrics;
use qlm::sim::Simulation;
use qlm::workload::{Scenario, ScenarioKnobs, Trace};

/// Run one scenario at reduced size with the given policy/thread count.
fn run_scenario(scenario: Scenario, policy: Policy, requests: usize, threads: usize) -> RunMetrics {
    // Default fleets (8 for the heavy scenarios) keep the view count
    // above the parallel pass's fan-out threshold (2 × threads).
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests,
        fleet: scenario.default_fleet(),
        seed: 42,
    };
    let run = scenario.build(&knobs);
    let trace = Trace::generate(&run.spec, knobs.seed);
    // Shared assembly (`ScenarioRun::sim_config`): the suite pins the
    // exact configuration the `qlm sim` / `qlm compare` CLI paths run.
    let mut cfg = run.sim_config(policy);
    cfg.seed = knobs.seed;
    cfg.threads = threads;
    Simulation::new(cfg, &trace).run(&trace)
}

/// The same run on the retained `BinaryHeap` clock instead of the
/// timer wheel (`Simulation::new_with_heap_clock`) — the wheel ≡ heap
/// equivalence gate below drives whole scenarios through both.
fn run_scenario_heap_clock(
    scenario: Scenario,
    policy: Policy,
    requests: usize,
    threads: usize,
) -> RunMetrics {
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests,
        fleet: scenario.default_fleet(),
        seed: 42,
    };
    let run = scenario.build(&knobs);
    let trace = Trace::generate(&run.spec, knobs.seed);
    let mut cfg = run.sim_config(policy);
    cfg.seed = knobs.seed;
    cfg.threads = threads;
    Simulation::new_with_heap_clock(cfg, &trace).run(&trace)
}

/// The same scenario driven end-to-end by the pull-based
/// [`qlm::workload::ArrivalStream`] (`Simulation::new_streaming`)
/// instead of a materialized trace — the gigascale path's correctness
/// half. Profiling moments come from the two-pass seeded replay
/// (`profile_spec`), arrivals are merged into the event loop on
/// demand, and the result must still collide digest for digest with
/// the materialized run.
fn run_scenario_streamed(
    scenario: Scenario,
    policy: Policy,
    requests: usize,
    threads: usize,
) -> RunMetrics {
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests,
        fleet: scenario.default_fleet(),
        seed: 42,
    };
    let run = scenario.build(&knobs);
    let mut cfg = run.sim_config(policy);
    cfg.seed = knobs.seed;
    cfg.threads = threads;
    Simulation::new_streaming(cfg, &run.spec, knobs.seed).run_streaming()
}

#[test]
fn streamed_equals_materialized_on_scale_scenario() {
    // Streaming is a memory-layout change, not a behavior change: the
    // merged (stream, clock) pop order must reproduce the materialized
    // push order exactly — arrivals before same-timestamp events, trace
    // order within a timestamp — at every lane count.
    for threads in [1, 2, 4] {
        let mat = run_scenario(Scenario::Scale, Policy::qlm(), 2500, threads);
        let streamed = run_scenario_streamed(Scenario::Scale, Policy::qlm(), 2500, threads);
        assert_eq!(mat.completed_count(), streamed.completed_count(), "threads={threads}");
        assert_eq!(
            mat.digest(),
            streamed.digest(),
            "threads={threads}: streamed arrivals diverged from the materialized trace"
        );
    }
}

#[test]
fn streamed_equals_materialized_on_megascale_scenario() {
    // The megascale shape at test size: the multi-model catalog spreads
    // arrivals across every per-model shard, so this doubles as a
    // sharded-routing equivalence check under streaming.
    for threads in [1, 2, 4] {
        let mat = run_scenario(Scenario::Megascale, Policy::qlm(), 2000, threads);
        let streamed = run_scenario_streamed(Scenario::Megascale, Policy::qlm(), 2000, threads);
        assert_eq!(mat.completed_count(), streamed.completed_count(), "threads={threads}");
        assert_eq!(
            mat.digest(),
            streamed.digest(),
            "threads={threads}: streamed arrivals diverged from the materialized trace"
        );
    }
}

#[test]
fn compact_records_preserve_the_aggregate_tally() {
    // Compact mode drops per-request records at ack time, so the run
    // can only report through the CompactTally — which must agree with
    // the full-records run on what was served.
    let full = run_scenario(Scenario::Scale, Policy::qlm(), 2000, 1);
    let knobs = ScenarioKnobs {
        rate: Scenario::Scale.default_rate(),
        requests: 2000,
        fleet: Scenario::Scale.default_fleet(),
        seed: 42,
    };
    let run = Scenario::Scale.build(&knobs);
    let mut cfg = run.sim_config(Policy::qlm());
    cfg.seed = knobs.seed;
    cfg.threads = 1;
    cfg.compact_records = true;
    let m = Simulation::new_streaming(cfg, &run.spec, knobs.seed).run_streaming();
    let tally = m.compact.expect("compact run must carry a tally");
    assert_eq!(
        tally.completed,
        full.completed_count(),
        "compact tally lost completions"
    );
    let att = tally.ttft_attainment();
    assert!((0.0..=1.0).contains(&att), "attainment out of range: {att}");
    assert!(tally.tokens_generated > 0, "no tokens recorded in the tally");
}

#[test]
fn timer_wheel_equals_heap_clock_on_scale_scenario() {
    // The tentpole's correctness half: swapping the event queue must be
    // invisible in the metrics. The scale shape (incremental scheduler
    // in steady state, multi-model swaps) at test size, at every lane
    // count — the wheel-backed run and the heap-backed run must collide
    // digest for digest.
    for threads in [1, 2, 4] {
        let wheel = run_scenario(Scenario::Scale, Policy::qlm(), 2500, threads);
        let heap = run_scenario_heap_clock(Scenario::Scale, Policy::qlm(), 2500, threads);
        assert_eq!(wheel.completed_count(), heap.completed_count(), "threads={threads}");
        assert_eq!(
            wheel.digest(),
            heap.digest(),
            "threads={threads}: timer wheel diverged from the heap clock"
        );
    }
}

#[test]
fn timer_wheel_equals_heap_clock_on_autoscale_scenario() {
    // Autoscale adds provision events and view-set churn — the clock
    // carries a moving instance population and far-future provision
    // timers, the wheel's cascade-heavy regime.
    for threads in [1, 2, 4] {
        let wheel = run_scenario(Scenario::Autoscale, Policy::qlm(), 2000, threads);
        let heap = run_scenario_heap_clock(Scenario::Autoscale, Policy::qlm(), 2000, threads);
        assert_eq!(wheel.scale_ups, heap.scale_ups, "threads={threads}");
        assert_eq!(wheel.scale_downs, heap.scale_downs, "threads={threads}");
        assert_eq!(
            wheel.digest(),
            heap.digest(),
            "threads={threads}: timer wheel diverged from the heap clock"
        );
    }
}

#[test]
fn threaded_equals_serial_on_scale_scenario() {
    // The scale shape (mixed SLO classes, multiple models, incremental
    // scheduler in steady state) at test size: every pooled lane count
    // must produce the identical digest to the serial run.
    let serial = run_scenario(Scenario::Scale, Policy::qlm(), 2500, 1);
    for threads in [2, 4] {
        let par = run_scenario(Scenario::Scale, Policy::qlm(), 2500, threads);
        assert_eq!(serial.completed_count(), par.completed_count());
        assert_eq!(
            serial.digest(),
            par.digest(),
            "threads={threads} changed the metrics"
        );
    }
}

#[test]
fn threaded_equals_serial_on_autoscale_scenario() {
    // Autoscale adds view-set churn (provision + drain) on top of the
    // parallel pass — the hardest case for threads ≡ serial. The trough
    // fleet (4 views) already fans out at two lanes; four lanes stays
    // gated until the autoscaler grows the fleet, exercising both sides
    // of the engagement gate in one run.
    let serial = run_scenario(Scenario::Autoscale, Policy::qlm(), 2000, 1);
    for threads in [2, 4] {
        let par = run_scenario(Scenario::Autoscale, Policy::qlm(), 2000, threads);
        assert_eq!(serial.scale_ups, par.scale_ups, "threads={threads}");
        assert_eq!(serial.scale_downs, par.scale_downs, "threads={threads}");
        assert_eq!(
            serial.digest(),
            par.digest(),
            "threads={threads} changed the metrics"
        );
    }
}

/// The pinned-digest ledger: one `scenario/policy digest` line per
/// (policy, scenario) pair, committed next to this file. When present,
/// the golden test asserts today's digests against it — so ANY
/// behavior drift in a future refactor (a changed tie-break, a ported
/// policy's load formula) fails the suite even though the drifted
/// engine is itself perfectly deterministic. Regenerate deliberately
/// with `QLM_BLESS_GOLDEN=1 cargo test -q --test golden` after an
/// *intentional* behavior change and commit the diff.
fn ledger_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden_digests.txt")
}

#[test]
fn golden_digests_reproducible_per_policy_and_scenario() {
    // Every policy behind the trait seam — including the PR's WFQ and
    // EDF+swap-penalty baselines — on the paper's two headline workload
    // shapes: the same pinned seed must reproduce the same metrics
    // digest run over run (and the digest must be non-trivial — the run
    // actually served traffic), and must match the committed
    // pinned-digest ledger when one exists.
    let policies = [
        Policy::qlm(),
        Policy::qlm_with(LsoConfig::without_eviction()),
        Policy::qlm_with(LsoConfig::without_swapping()),
        Policy::qlm_with(LsoConfig::without_load_balancing()),
        Policy::Shepherd,
        Policy::Edf,
        Policy::EdfSwap,
        Policy::Wfq,
        Policy::Sjf,
        Policy::VllmFcfs,
        Policy::Chunked,
    ];
    let pinned: std::collections::HashMap<String, u64> = std::fs::read_to_string(ledger_path())
        .map(|s| {
            s.lines()
                .filter_map(|l| {
                    let (key, val) = l.trim().split_once(' ')?;
                    Some((key.to_string(), val.parse().ok()?))
                })
                .collect()
        })
        .unwrap_or_default();
    let mut ledger = String::new();
    for scenario in [Scenario::MixedSlo, Scenario::MultiModel] {
        for policy in policies {
            let a = run_scenario(scenario, policy, 400, 1);
            let b = run_scenario(scenario, policy, 400, 1);
            assert!(
                a.completed_count() > 0,
                "{} on {} served nothing: {}",
                policy.name(),
                scenario.name(),
                a.summary()
            );
            assert_eq!(
                a.digest(),
                b.digest(),
                "{} on {} is not reproducible",
                policy.name(),
                scenario.name()
            );
            let key = format!("{}/{}", scenario.name(), policy.name());
            if let Some(&want) = pinned.get(&key) {
                assert_eq!(
                    a.digest(),
                    want,
                    "{key}: metrics drifted from the committed golden ledger \
                     (intentional? re-bless with QLM_BLESS_GOLDEN=1)"
                );
            }
            ledger.push_str(&format!("{key} {}\n", a.digest()));
        }
    }
    if std::env::var_os("QLM_BLESS_GOLDEN").is_some() {
        std::fs::write(ledger_path(), ledger).expect("write golden ledger");
    }
}

#[test]
fn threaded_equals_serial_across_policies() {
    // The parallel pass must be invisible for every policy family, not
    // just QLM (baselines share the view-refresh fan-out; the 8-wide
    // mixed-slo fleet fans out at 4 lanes). WFQ and EDF+swap ride the
    // same pool-backed refresh as the rest.
    for policy in [
        Policy::qlm(),
        Policy::Edf,
        Policy::EdfSwap,
        Policy::Wfq,
        Policy::Sjf,
        Policy::Shepherd,
        Policy::Chunked,
    ] {
        let serial = run_scenario(Scenario::MixedSlo, policy, 300, 1);
        let par = run_scenario(Scenario::MixedSlo, policy, 300, 4);
        assert_eq!(
            serial.digest(),
            par.digest(),
            "threads changed {} metrics",
            policy.name()
        );
    }
}
