//! Integration tests: cross-module scenarios over the full coordinator +
//! backend + simulator stack.

use qlm::backend::{InstanceId, ModelCatalog, ModelId};
use qlm::baselines::Policy;
use qlm::coordinator::lso::LsoConfig;
use qlm::coordinator::request::Request;
use qlm::coordinator::GlobalQueue;
use qlm::sim::{fleet_a100, fleet_mixed, SimConfig, Simulation};
use qlm::workload::{Scenario, ScenarioKnobs, SloClass, Trace, TraceRequest, WorkloadSpec};

fn run(policy: Policy, trace: &Trace, fleet_n: u32, multi: bool) -> qlm::metrics::RunMetrics {
    let catalog = if multi {
        ModelCatalog::paper_multi_model()
    } else {
        ModelCatalog::paper()
    };
    let cfg = SimConfig::new(fleet_a100(fleet_n), catalog, policy);
    Simulation::new(cfg, trace).run(trace)
}

#[test]
fn all_policies_conserve_requests() {
    // Every submitted request is accounted exactly once in the records.
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 20.0, 400), 1);
    for policy in [
        Policy::qlm(),
        Policy::Edf,
        Policy::VllmFcfs,
        Policy::Shepherd,
        Policy::qlm_with(LsoConfig::without_eviction()),
        Policy::qlm_with(LsoConfig::without_load_balancing()),
    ] {
        let m = run(policy, &trace, 2, false);
        assert_eq!(m.records.len(), 400, "{}", m.policy);
        let mut ids: Vec<u64> = m.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 400, "{}: duplicated records", m.policy);
    }
}

#[test]
fn ttft_never_negative_and_completion_after_first_token() {
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(1), 25.0, 500), 2);
    let m = run(Policy::qlm(), &trace, 2, false);
    for r in &m.records {
        if let Some(t) = r.ttft() {
            assert!(t >= 0.0, "negative ttft for {}", r.id);
        }
        if let (Some(f), Some(c)) = (r.first_token_s, r.completed_s) {
            assert!(c >= f, "completed before first token for {}", r.id);
        }
    }
}

#[test]
fn interactive_prioritized_under_overload() {
    // Under 3× overload, QLM must keep interactive attainment well above
    // the batch-1 class (the whole point of queue reordering).
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(1), 120.0, 1200), 3);
    let m = run(Policy::qlm(), &trace, 1, false);
    let inter = m.slo_attainment_class(SloClass::Interactive);
    let vllm = run(Policy::VllmFcfs, &trace, 1, false);
    assert!(
        inter >= vllm.slo_attainment_class(SloClass::Interactive),
        "qlm interactive {} < vllm {}",
        inter,
        vllm.slo_attainment_class(SloClass::Interactive)
    );
}

#[test]
fn multi_model_qlm_beats_edf_throughput() {
    let trace = Trace::generate(
        &WorkloadSpec::w_b(
            vec![ModelId(3), ModelId(4)],
            vec![ModelId(5), ModelId(6)],
            10.0,
            600,
        ),
        4,
    );
    let qlm = run(Policy::qlm(), &trace, 2, true);
    let edf = run(Policy::Edf, &trace, 2, true);
    assert!(
        qlm.throughput_rps() > edf.throughput_rps(),
        "qlm {} vs edf {}",
        qlm.throughput_rps(),
        edf.throughput_rps()
    );
    assert!(
        qlm.total_model_swaps() < edf.total_model_swaps(),
        "qlm swaps {} vs edf {}",
        qlm.total_model_swaps(),
        edf.total_model_swaps()
    );
}

#[test]
fn heterogeneous_fleet_serves_everything() {
    // Enough pressure that the scheduler must spill onto the slower A10s
    // (at light load parking everything on the A100s is the right call).
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 60.0, 900), 5);
    let cfg = SimConfig::new(fleet_mixed(4, 0.5), ModelCatalog::paper(), Policy::qlm());
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert_eq!(m.completed_count(), 900, "{}", m.summary());
    // Both device kinds must have done work.
    let a10_tokens: u64 = m.instances[2..].iter().map(|i| i.tokens_generated).sum();
    let a100_tokens: u64 = m.instances[..2].iter().map(|i| i.tokens_generated).sum();
    assert!(
        a10_tokens > 0 && a100_tokens > 0,
        "a10={a10_tokens} a100={a100_tokens}"
    );
    // And the faster devices should carry more of the load.
    assert!(a100_tokens > a10_tokens);
}

#[test]
fn scenarios_run_end_to_end_at_small_scale() {
    // Every CLI scenario must run through the full stack and serve
    // essentially everything at light load.
    for s in Scenario::ALL {
        let k = ScenarioKnobs {
            rate: 6.0,
            requests: 200,
            fleet: 2,
            seed: 11,
        };
        let run = s.build(&k);
        let trace = Trace::generate(&run.spec, k.seed);
        let mut cfg = SimConfig::new(run.fleet, run.catalog, Policy::qlm());
        cfg.seed = k.seed;
        cfg.failures = run.failures.clone();
        let m = Simulation::new(cfg, &trace).run(&trace);
        assert_eq!(m.records.len(), 200, "{}", s.name());
        assert!(
            m.completed_count() >= 190,
            "{}: {}",
            s.name(),
            m.summary()
        );
    }
}

#[test]
fn failover_mid_run_completes_on_survivor() {
    // Kill an instance while requests are genuinely in flight: the
    // survivor must absorb the dead instance's queue (§4).
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 15.0, 400), 17);
    let mut cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
    cfg.failures = vec![(4.0, InstanceId(0))];
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert_eq!(m.completed_count(), 400, "{}", m.summary());
    // The dead instance stops generating after the failure; the survivor
    // carries the bulk of the load.
    assert!(
        m.instances[1].tokens_generated > m.instances[0].tokens_generated,
        "survivor {} vs dead {}",
        m.instances[1].tokens_generated,
        m.instances[0].tokens_generated
    );
}

#[test]
fn global_queue_survives_instance_failure() {
    // §4 fault tolerance: losing an instance loses no request data.
    let mut q = GlobalQueue::new();
    let mk = |arrival: f64| {
        Request::from_trace(
            0,
            &TraceRequest {
                arrival_s: arrival,
                model: ModelId(0),
                class: SloClass::Interactive,
                slo: SloClass::Interactive.target(),
                input_tokens: 64,
                output_tokens: 16,
                mega: false,
            },
        )
    };
    let ids: Vec<u64> = (0..10).map(|i| q.submit(mk(i as f64))).collect();
    for &id in &ids[..5] {
        q.mark_running(id);
    }
    let affected = q.fail_instance(InstanceId(0), &ids[..5]);
    assert_eq!(affected.len(), 5);
    assert_eq!(q.len_total(), 10, "no request lost");
    assert_eq!(q.len_waiting(), 10, "all requests schedulable again");
}

#[test]
fn failover_spanning_model_shards_recovers_every_model() {
    // Kill an instance whose running batch — and stale `evicted_from`
    // KV pointers — span several per-model shards: `fail_instance`
    // must revert requests in every shard it touches and invalidate
    // cross-shard eviction pointers, and the rerun must be
    // bit-deterministic.
    let k = ScenarioKnobs {
        rate: 12.0,
        requests: 300,
        fleet: 3,
        seed: 23,
    };
    let trace = Trace::generate(&Scenario::MultiModel.build(&k).spec, k.seed);
    let drive = || {
        let run = Scenario::MultiModel.build(&k);
        let mut cfg = SimConfig::new(run.fleet, run.catalog, Policy::qlm());
        cfg.seed = k.seed;
        cfg.failures = vec![(5.0, InstanceId(1))];
        Simulation::new(cfg, &trace).run(&trace)
    };
    let a = drive();
    assert_eq!(a.records.len(), 300);
    let models: std::collections::BTreeSet<ModelId> =
        a.records.iter().map(|r| r.model).collect();
    assert!(models.len() >= 3, "trace must span shards, got {models:?}");
    let done = a.records.iter().filter(|r| r.completed_s.is_some()).count();
    let shed = a.records.iter().filter(|r| r.shed).count();
    assert_eq!(done + shed, 300, "requests lost across shards: {}", a.summary());
    assert!(done >= 290, "failover starved the fleet: {}", a.summary());
    let b = drive();
    assert_eq!(a.digest(), b.digest(), "multi-shard failover not deterministic");
}

#[test]
fn scheduler_passes_skip_clean_model_shards() {
    // Per-shard dirt: with a multi-model catalog most passes mutate a
    // few models' queues, and every other shard is provably clean —
    // the run must record real skips, or the dirt gate is dead weight.
    let k = ScenarioKnobs {
        rate: 12.0,
        requests: 400,
        fleet: 3,
        seed: 9,
    };
    let run = Scenario::MultiModel.build(&k);
    let trace = Trace::generate(&run.spec, k.seed);
    let mut cfg = SimConfig::new(run.fleet, run.catalog, Policy::qlm());
    cfg.seed = k.seed;
    let m = Simulation::new(cfg, &trace).run(&trace);
    assert!(m.shards_scanned > 0, "no scheduler pass scanned any shard");
    assert!(
        m.shards_skipped > 0,
        "no pass ever skipped a clean shard (scanned={}, skipped={})",
        m.shards_scanned,
        m.shards_skipped
    );
}

#[test]
fn deterministic_end_to_end() {
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(1), 30.0, 400), 6);
    let a = run(Policy::qlm(), &trace, 2, false);
    let b = run(Policy::qlm(), &trace, 2, false);
    assert_eq!(a.completed_count(), b.completed_count());
    assert_eq!(a.total_model_swaps(), b.total_model_swaps());
    assert!((a.mean_ttft() - b.mean_ttft()).abs() < 1e-9);
    assert!((a.duration_s - b.duration_s).abs() < 1e-9);
}

#[test]
fn scale_up_improves_attainment() {
    // §9: when SLOs can't be met, adding GPUs is the remedy — attainment
    // must be monotone (within noise) in fleet size.
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(1), 80.0, 800), 7);
    let m1 = run(Policy::qlm(), &trace, 1, false);
    let m4 = run(Policy::qlm(), &trace, 4, false);
    assert!(
        m4.slo_attainment() >= m1.slo_attainment() - 0.02,
        "1 gpu {} vs 4 gpus {}",
        m1.slo_attainment(),
        m4.slo_attainment()
    );
    assert!(m4.duration_s <= m1.duration_s * 1.05);
}

#[test]
fn bursty_arrivals_handled() {
    use qlm::workload::{ArrivalProcess, RequestClassSpec, ShareGptSampler};
    let spec = WorkloadSpec {
        name: "bursty".into(),
        streams: vec![RequestClassSpec {
            class: SloClass::Interactive,
            models: vec![ModelId(0)],
            arrivals: ArrivalProcess::Bursty {
                rate: 20.0,
                burstiness: 6.0,
                phase_len_s: 2.0,
            },
            count: 400,
            mega_fraction: 0.0,
        }],
        sampler: ShareGptSampler::default(),
    };
    let trace = Trace::generate(&spec, 8);
    let m = run(Policy::qlm(), &trace, 2, false);
    assert_eq!(m.completed_count(), 400, "{}", m.summary());
}
