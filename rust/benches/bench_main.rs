//! Benchmark harness (hand-rolled — the offline environment has no
//! criterion). `cargo bench` runs every benchmark and prints
//! mean ± stddev wall time plus derived throughput numbers; pass a
//! substring to run a subset, e.g. `cargo bench -- queue` (the CI
//! bench-smoke job runs exactly that).
//!
//! Benches cover the paper's headline end-to-end results (Fig. 9 / 12
//! operating points) and the hot paths the §Perf pass optimizes:
//! the global-queue submit→schedule→ack loop (measured against the
//! committed pre-refactor baseline below), RWT estimation,
//! global-scheduler solves, the KV allocator, the continuous-batching
//! step loop, and the PJRT decode step (feature "pjrt", artifacts
//! required).

use std::time::Instant;

use qlm::backend::{
    GpuKind, Instance, InstanceConfig, InstanceId, KvCache, ModelCatalog, ModelId, PerfModel,
    RunningSeq,
};
use qlm::baselines::Policy;
use qlm::capacity::{CapacityPlanner, PlannerConfig, TierSpec};
use qlm::coordinator::request::Request;
use qlm::coordinator::request_group::{GroupId, RequestGroup};
use qlm::coordinator::rwt::{ProfileTable, RwtEstimator};
use qlm::coordinator::scheduler::{
    GlobalScheduler, InstanceView, SchedDelta, SchedulerConfig, SolverKind,
};
use qlm::coordinator::GlobalQueue;
use qlm::sim::event::{EventCore, EventKind};
use qlm::sim::{fleet_a100, SimConfig, Simulation};
use qlm::util::{mean, stddev};
use qlm::obs::ObsConfig;
use qlm::workload::{Scenario, ScenarioKnobs, SloClass, SloTarget, Trace, TraceRequest, WorkloadSpec};

/// Run `f` for `iters` timed iterations (after 1 warmup); report stats
/// and return the mean wall time in milliseconds.
fn bench<F: FnMut() -> u64>(name: &str, iters: usize, mut f: F) -> f64 {
    let _ = f(); // warmup
    let mut times = Vec::with_capacity(iters);
    let mut work = 0u64;
    for _ in 0..iters {
        let t0 = Instant::now();
        work = f();
        times.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    let m = mean(&times);
    let sd = stddev(&times);
    let per_item = if work > 0 {
        format!(
            "  ({:.3} µs/item over {} items)",
            m * 1000.0 / work as f64,
            work
        )
    } else {
        String::new()
    };
    println!("{name:<44} {m:>9.3} ms ± {sd:>7.3}{per_item}");
    m
}

/// Perf-trajectory artifact: headline bench numbers accumulated during
/// the run and merged into `BENCH_qlm.json` (flat `"key": number`
/// object). A filtered run (`cargo bench -- queue`) rewrites only the
/// keys it measured, so CI jobs build up one artifact across runs and
/// successive commits can be diffed key-by-key.
mod perf_log {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, OnceLock};

    static RECORDS: OnceLock<Mutex<BTreeMap<String, f64>>> = OnceLock::new();

    fn records() -> &'static Mutex<BTreeMap<String, f64>> {
        RECORDS.get_or_init(|| Mutex::new(BTreeMap::new()))
    }

    pub fn record(key: &str, value: f64) {
        records().lock().unwrap().insert(key.to_string(), value);
    }

    /// Best-effort parse of a previously written flat object: one
    /// `"key": number` pair per line. Anything unrecognized is dropped
    /// (this file is ours; nothing else writes it).
    fn read_existing(path: &str) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        let Ok(text) = std::fs::read_to_string(path) else {
            return out;
        };
        for line in text.lines() {
            let line = line.trim().trim_end_matches(',');
            let Some((key, val)) = line.split_once(':') else {
                continue;
            };
            let key = key.trim().trim_matches('"');
            if key.is_empty() {
                continue;
            }
            if let Ok(v) = val.trim().parse::<f64>() {
                out.insert(key.to_string(), v);
            }
        }
        out
    }

    pub fn write(path: &str) {
        let mut all = read_existing(path);
        all.extend(records().lock().unwrap().iter().map(|(k, v)| (k.clone(), *v)));
        if all.is_empty() {
            return;
        }
        let body: Vec<String> = all
            .iter()
            .map(|(k, v)| format!("  \"{k}\": {v:.6}"))
            .collect();
        let json = format!("{{\n{}\n}}\n", body.join(",\n"));
        match std::fs::write(path, json) {
            Ok(()) => println!("perf trajectory written to {path} ({} keys)", all.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

/// Counting global allocator: every heap allocation (and growth
/// realloc) bumps one relaxed counter, so a bench can report
/// *allocations per pass* for the hot paths the `hot-loop-alloc` audit
/// rule guards (`cargo bench -- hot_alloc`). Frees are not counted —
/// the churn signal is how often the path asks the allocator for
/// memory, not its balance.
mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCS: AtomicU64 = AtomicU64::new(0);
    /// Live heap bytes (adds on alloc, subtracts on free) and the
    /// high-water mark — the memory half of the gigascale gate: a
    /// streamed-compact 10M-request run must peak at O(in-flight)
    /// bytes, while a materialized trace shows up as gigabytes here.
    static BYTES: AtomicU64 = AtomicU64::new(0);
    static PEAK: AtomicU64 = AtomicU64::new(0);

    pub struct Counting;

    fn note_grow(sz: u64) {
        let cur = BYTES.fetch_add(sz, Ordering::Relaxed) + sz;
        // Relaxed max-update CAS loop: racing threads can only raise it.
        let mut peak = PEAK.load(Ordering::Relaxed);
        while cur > peak {
            match PEAK.compare_exchange_weak(peak, cur, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(p) => peak = p,
            }
        }
    }

    // SAFETY: delegates verbatim to `System`; the only addition is
    // relaxed atomic accounting, which allocates nothing itself.
    unsafe impl GlobalAlloc for Counting {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            let p = unsafe { System.alloc(layout) };
            if !p.is_null() {
                note_grow(layout.size() as u64);
            }
            p
        }
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
            unsafe { System.dealloc(ptr, layout) }
        }
        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            let p = unsafe { System.realloc(ptr, layout, new_size) };
            if !p.is_null() {
                BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
                note_grow(new_size as u64);
            }
            p
        }
    }

    pub fn allocs() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }

    /// High-water heap mark (bytes) since process start / last reset.
    pub fn peak_bytes() -> u64 {
        PEAK.load(Ordering::Relaxed)
    }

    /// Drop the high-water mark to the current live size, so a bench
    /// measures its own peak rather than inheriting an earlier bench's.
    pub fn reset_peak() {
        PEAK.store(BYTES.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

#[global_allocator]
static GLOBAL: alloc_count::Counting = alloc_count::Counting;

fn grp(id: u64, model: u32, n: usize, slo: f64) -> RequestGroup {
    RequestGroup {
        id: GroupId(id),
        model: ModelId(model),
        class: SloClass::Batch1,
        slo: SloTarget::new(slo, 1.0),
        earliest_arrival_s: 0.0,
        members: (0..n as u64).collect(),
        mega: false,
    }
}

fn views(n: u32, catalog: &ModelCatalog) -> Vec<InstanceView> {
    let prompt = qlm::backend::perf::PROFILE_MEAN_PROMPT_TOKENS;
    (0..n)
        .map(|i| {
            let mut perf_for = std::collections::BTreeMap::new();
            let mut swap_time = std::collections::BTreeMap::new();
            for m in catalog.ids() {
                if let Some(p) = PerfModel::try_profile(catalog.get(m), GpuKind::A100, prompt) {
                    swap_time.insert(m, p.swap_cpu_gpu_s);
                    perf_for.insert(m, p);
                }
            }
            InstanceView {
                id: InstanceId(i),
                active_model: Some(ModelId(0)),
                perf_for,
                swap_time,
                executing: None,
            }
        })
        .collect()
}

/// The seed's `GlobalQueue` (pre-refactor baseline, committed here so the
/// speedup claim stays measurable): `HashMap` store + linearly scanned
/// `Vec` waiting set — `mark_running`/`complete` pay an O(n) retain.
mod legacy {
    use std::collections::HashMap;

    use qlm::backend::InstanceId;
    use qlm::coordinator::request::{Request, RequestState};

    #[derive(Debug, Default)]
    pub struct LegacyGlobalQueue {
        store: HashMap<u64, Request>,
        waiting: Vec<u64>,
        next_id: u64,
        pub completed: Vec<Request>,
    }

    impl LegacyGlobalQueue {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn submit(&mut self, mut req: Request) -> u64 {
            let id = self.next_id;
            self.next_id += 1;
            req.id = id;
            req.state = RequestState::Waiting;
            self.waiting.push(id);
            self.store.insert(id, req);
            id
        }

        pub fn waiting_ids(&self) -> &[u64] {
            &self.waiting
        }

        pub fn mark_running(&mut self, id: u64) {
            if let Some(r) = self.store.get_mut(&id) {
                r.state = RequestState::Running;
            }
            self.waiting.retain(|&x| x != id);
        }

        pub fn requeue_evicted(&mut self, id: u64, generated: u32, evicted_from: InstanceId) {
            if let Some(r) = self.store.get_mut(&id) {
                r.state = RequestState::Evicted;
                r.generated = generated;
                r.evicted_from = Some(evicted_from);
                if !self.waiting.contains(&id) {
                    self.waiting.push(id);
                }
            }
        }

        pub fn complete(&mut self, id: u64, first_token_s: Option<f64>, completed_s: f64) {
            if let Some(mut r) = self.store.remove(&id) {
                r.state = RequestState::Completed;
                if r.first_token_s.is_none() {
                    r.first_token_s = first_token_s;
                }
                r.completed_s = Some(completed_s);
                self.completed.push(r);
            }
            self.waiting.retain(|&x| x != id);
        }
    }
}

fn hot_path_request(arrival: f64) -> Request {
    Request::from_trace(
        0,
        &TraceRequest {
            arrival_s: arrival,
            model: ModelId(0),
            class: SloClass::Interactive,
            slo: SloClass::Interactive.target(),
            input_tokens: 161,
            output_tokens: 338,
            mega: false,
        },
    )
}

const HOT_PATH_N: usize = 8_000;
const HOT_PATH_BATCH: usize = 64;

/// The submit→schedule→ack loop against the slab-backed queue.
fn drive_slab(n: usize) -> u64 {
    let mut q = GlobalQueue::new();
    let ids: Vec<u64> = (0..n)
        .map(|i| q.submit(hot_path_request(i as f64)))
        .collect();
    let mut acked = 0u64;
    for _chunk in ids.chunks(HOT_PATH_BATCH) {
        // "Schedule": snapshot the head of the waiting set, as the
        // scheduler's group refresh does.
        let head: Vec<u64> = q.waiting_ids().take(HOT_PATH_BATCH).collect();
        for &id in &head {
            q.mark_running(id);
        }
        for (j, &id) in head.iter().enumerate() {
            if j % 4 == 0 {
                q.requeue_evicted(id, 3, InstanceId(0));
            } else {
                q.complete(id, Some(1.0), 2.0, 338);
                acked += 1;
            }
        }
    }
    // Drain the requeued tail.
    let rest: Vec<u64> = q.waiting_ids().collect();
    for id in rest {
        q.mark_running(id);
        q.complete(id, Some(1.0), 2.0, 338);
        acked += 1;
    }
    acked
}

/// The identical op sequence against the pre-refactor baseline.
fn drive_legacy(n: usize) -> u64 {
    let mut q = legacy::LegacyGlobalQueue::new();
    let ids: Vec<u64> = (0..n)
        .map(|i| q.submit(hot_path_request(i as f64)))
        .collect();
    let mut acked = 0u64;
    for _chunk in ids.chunks(HOT_PATH_BATCH) {
        let head: Vec<u64> = q
            .waiting_ids()
            .iter()
            .take(HOT_PATH_BATCH)
            .copied()
            .collect();
        for &id in &head {
            q.mark_running(id);
        }
        for (j, &id) in head.iter().enumerate() {
            if j % 4 == 0 {
                q.requeue_evicted(id, 3, InstanceId(0));
            } else {
                q.complete(id, Some(1.0), 2.0);
                acked += 1;
            }
        }
    }
    let rest: Vec<u64> = q.waiting_ids().to_vec();
    for id in rest {
        q.mark_running(id);
        q.complete(id, Some(1.0), 2.0);
        acked += 1;
    }
    acked
}

/// The PR's headline perf claim: slab store + ordered waiting set vs the
/// seed's HashMap + Vec on the same submit→schedule→ack op sequence.
fn bench_queue_hot_path() {
    let slab_ms = bench("queue/submit-schedule-ack (slab)", 20, || {
        drive_slab(HOT_PATH_N)
    });
    let legacy_ms = bench("queue/submit-schedule-ack (legacy)", 20, || {
        drive_legacy(HOT_PATH_N)
    });
    let speedup = legacy_ms / slab_ms.max(1e-9);
    println!(
        "queue/hot-path speedup: {speedup:.1}x over pre-refactor baseline \
         ({legacy_ms:.2} ms -> {slab_ms:.2} ms, target >= 2x)"
    );
    perf_log::record("queue_slab_ms", slab_ms);
    perf_log::record("queue_legacy_ms", legacy_ms);
    perf_log::record("queue_speedup_x", speedup);
}

fn bench_rwt() {
    let catalog = ModelCatalog::paper();
    let est = RwtEstimator::new(ProfileTable::default());
    let perf = PerfModel::profile(catalog.get(ModelId(0)), GpuKind::A100, 161.0);
    let groups: Vec<RequestGroup> = (0..512).map(|i| grp(i, 0, 256, 60.0)).collect();
    let refs: Vec<&RequestGroup> = groups.iter().collect();
    bench("rwt/estimate_queue (512 groups)", 50, || {
        let e = est.estimate_queue(&refs, &perf, Some(ModelId(0)), |_| 1.0);
        e.len() as u64
    });
}

fn bench_scheduler() {
    let catalog = ModelCatalog::paper_multi_model();
    let est = RwtEstimator::new(ProfileTable::default());
    let vs = views(10, &catalog);
    for n_groups in [64usize, 390, 1562] {
        let groups: Vec<RequestGroup> = (0..n_groups as u64)
            .map(|g| grp(g, (g % 4) as u32, 256, 60.0 + (g % 7) as f64 * 300.0))
            .collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            est.clone(),
        );
        bench(
            &format!(
                "scheduler/greedy ({n_groups} groups ≈ {}K reqs)",
                n_groups * 256 / 1000
            ),
            5,
            || sched.schedule(&refs, &vs, 0.0).stats.groups as u64,
        );
    }
    // Exact MILP reference point (Fig. 20's right-hand regime).
    let groups: Vec<RequestGroup> =
        (0..5u64).map(|g| grp(g, (g % 2) as u32, 256, 60.0)).collect();
    let refs: Vec<&RequestGroup> = groups.iter().collect();
    let sched = GlobalScheduler::new(
        SchedulerConfig {
            solver: SolverKind::ExactMilp,
            milp_max_groups: 5,
            node_limit: 50_000,
            ..Default::default()
        },
        est,
    );
    bench("scheduler/exact-milp (5 groups)", 5, || {
        sched.schedule(&refs, &vs[..1], 0.0).stats.milp_nodes as u64
    });
}

/// The incremental-scheduler claim: a steady-state delta pass (a few
/// dirty groups patched into the 1562-group cached plan — ≈400K queued
/// requests at δ·B = 256) vs a full re-solve of the same state. Also
/// proves the unchanged-input identity: an empty delta changes nothing
/// and the cached plan still equals the full solve's assignments.
fn bench_sched_incremental() {
    let catalog = ModelCatalog::paper_multi_model();
    let est = RwtEstimator::new(ProfileTable::default());
    let vs = views(10, &catalog);
    const N_GROUPS: usize = 1562;
    let groups: Vec<RequestGroup> = (0..N_GROUPS as u64)
        .map(|g| grp(g, (g % 4) as u32, 256, 60.0 + (g % 7) as f64 * 300.0))
        .collect();
    let refs: Vec<&RequestGroup> = groups.iter().collect();
    let cfg = SchedulerConfig {
        solver: SolverKind::Greedy,
        ..Default::default()
    };
    let full = GlobalScheduler::new(cfg, est.clone());
    let inc = GlobalScheduler::new(cfg, est);
    let base = full.schedule(&refs, &vs, 0.0);
    let warm = inc.schedule(&refs, &vs, 0.0);
    assert_eq!(base.orders, warm.orders, "same inputs, same plan");

    // Identity on unchanged inputs: an empty delta is a no-op patch and
    // the cached plan still equals the full solve's assignments.
    let empty = SchedDelta {
        dirty: vec![],
        removed: vec![],
        total_groups: N_GROUPS,
        groups: None,
    };
    let a = inc.try_schedule_delta(&empty, &vs, 0.0).expect("warm cache");
    assert!(a.orders.is_empty(), "unchanged inputs must change nothing");
    assert_eq!(
        inc.cached_orders().unwrap(),
        base.orders,
        "identical assignments on unchanged inputs"
    );

    let full_ms = bench("sched_incremental/full re-solve (1562 grp)", 10, || {
        full.schedule(&refs, &vs, 0.0).stats.groups as u64
    });
    let mut cursor = 0usize;
    let inc_ms = bench("sched_incremental/delta pass (4 dirty)", 10, || {
        let dirty: Vec<&RequestGroup> = (0..4)
            .map(|k| &groups[(cursor + k) % N_GROUPS])
            .collect();
        cursor = (cursor + 4) % N_GROUPS;
        let d = SchedDelta {
            dirty,
            removed: vec![],
            total_groups: N_GROUPS,
            groups: None,
        };
        let a = inc.try_schedule_delta(&d, &vs, 0.0).expect("delta path");
        a.stats.dirty as u64
    });
    let speedup = full_ms / inc_ms.max(1e-9);
    println!(
        "sched_incremental speedup: {speedup:.1}x delta vs full re-solve \
         ({full_ms:.3} ms -> {inc_ms:.3} ms, target >= 5x)"
    );
    perf_log::record("sched_incremental_full_ms", full_ms);
    perf_log::record("sched_incremental_delta_ms", inc_ms);
    perf_log::record("sched_incremental_speedup_x", speedup);
    assert!(
        speedup >= 5.0,
        "incremental scheduler must be >=5x cheaper in steady state, got {speedup:.1}x"
    );
}

/// The capacity planner's what-if search: minimal heterogeneous fleet
/// for the paper's W_A at moderate rate — binary search over two tiers
/// with RWT-estimator pricing per candidate.
fn bench_capacity_plan() {
    let spec = WorkloadSpec::w_a(ModelId(1), 20.0, 2000);
    let planner = CapacityPlanner::from_spec(
        &spec,
        ModelCatalog::paper(),
        PlannerConfig {
            tiers: vec![
                TierSpec {
                    gpu: GpuKind::A100,
                    max: 64,
                },
                TierSpec {
                    gpu: GpuKind::A10,
                    max: 32,
                },
            ],
            ..Default::default()
        },
        21,
    );
    // Θ profiling happens once, outside the timed loop (as at runtime).
    let warm = planner.plan();
    assert!(warm.feasible, "W_A at 20 req/s must be plannable: {warm:?}");
    assert!(warm.total_devices() >= 1);
    bench("capacity_plan/w_a what-if (64+32 tier max)", 10, || {
        planner.plan().total_devices() as u64
    });
}

/// Sweep the incremental-scheduler fallback threshold: delta-pass cost
/// vs dirty fraction against the full re-solve of the same state — the
/// data behind `SchedulerConfig::incremental_dirty_frac`'s default.
/// Self-validating: asserts the delta pass is still no slower than the
/// full solve at the default threshold, so a wrong crossover fails the
/// bench (and CI) instead of silently regressing the hot path.
fn bench_dirty_frac_sweep() {
    let catalog = ModelCatalog::paper_multi_model();
    let est = RwtEstimator::new(ProfileTable::default());
    let vs = views(10, &catalog);
    const N_GROUPS: usize = 1562;
    let groups: Vec<RequestGroup> = (0..N_GROUPS as u64)
        .map(|g| grp(g, (g % 4) as u32, 256, 60.0 + (g % 7) as f64 * 300.0))
        .collect();
    let refs: Vec<&RequestGroup> = groups.iter().collect();
    let full = GlobalScheduler::new(
        SchedulerConfig {
            solver: SolverKind::Greedy,
            ..Default::default()
        },
        est.clone(),
    );
    let full_ms = bench("dirty_frac/full re-solve (1562 grp)", 10, || {
        full.schedule(&refs, &vs, 0.0).stats.groups as u64
    });
    for frac in [0.05, 0.1, 0.25, 0.5, 0.75] {
        let inc = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                incremental_dirty_frac: 1.0, // measure, don't fall back
                ..Default::default()
            },
            est.clone(),
        );
        inc.schedule(&refs, &vs, 0.0);
        let n_dirty = ((N_GROUPS as f64 * frac) as usize).max(1);
        let mut cursor = 0usize;
        let inc_ms = bench(
            &format!("dirty_frac/delta at {:>2.0}% dirty", frac * 100.0),
            10,
            || {
                let dirty: Vec<&RequestGroup> = (0..n_dirty)
                    .map(|k| &groups[(cursor + k) % N_GROUPS])
                    .collect();
                cursor = (cursor + n_dirty) % N_GROUPS;
                let d = SchedDelta {
                    dirty,
                    removed: vec![],
                    total_groups: N_GROUPS,
                    groups: None,
                };
                let a = inc.try_schedule_delta(&d, &vs, 0.0).expect("delta path");
                a.stats.dirty as u64
            },
        );
        let ratio = inc_ms / full_ms.max(1e-9);
        println!(
            "dirty_frac {:>4.0}%: delta/full = {ratio:.2} ({n_dirty} dirty)",
            frac * 100.0,
        );
        if frac <= SchedulerConfig::default().incremental_dirty_frac {
            assert!(
                ratio <= 1.1,
                "delta pass slower than a full solve at {frac} dirty — \
                 SchedulerConfig::incremental_dirty_frac's default is past the crossover"
            );
        }
    }
}

/// The parallel view/pricing pass: per-instance view refresh fans out
/// over the engine's persistent `WorkerPool` (spawned once per
/// `Simulation`, workers parked between passes). Measured at a fleet
/// large enough that per-view work dominates dispatch cost; the
/// speedup floors are asserted only when the host actually has ≥4
/// cores (CI runners vary). Correctness is asserted always: the
/// threaded refresh digest and the threaded scheduler pricing must be
/// bit-identical to serial, and the pool must match the scoped-spawn
/// baseline it replaced (digest equality hard-gated, pool ≥ 1.0×
/// scoped wall time when the floor is armed).
fn bench_par_views() {
    const FLEET: usize = 2048;
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 20.0, 64), 7);
    let build = |threads: usize| {
        let mut cfg = SimConfig::new(
            fleet_a100(FLEET as u32),
            ModelCatalog::paper(),
            Policy::qlm(),
        );
        cfg.threads = threads;
        Simulation::new(cfg, &trace)
    };
    let mut serial = build(1);
    let mut par = build(4);
    assert_eq!(
        serial.refresh_views_for_bench(),
        par.refresh_views_for_bench(),
        "threaded view refresh must be bit-identical to serial"
    );
    let serial_ms = bench(
        &format!("par_views/refresh {FLEET} views (threads=1)"),
        30,
        || {
            serial.refresh_views_for_bench();
            FLEET as u64
        },
    );
    let par_ms = bench(
        &format!("par_views/refresh {FLEET} views (threads=4)"),
        30,
        || {
            par.refresh_views_for_bench();
            FLEET as u64
        },
    );
    let speedup = serial_ms / par_ms.max(1e-9);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "par_views speedup: {speedup:.2}x threaded vs serial refresh \
         ({serial_ms:.3} ms -> {par_ms:.3} ms, {cores} cores; floor 1.05x at >=4 cores)"
    );
    perf_log::record("par_views_serial_ms", serial_ms);
    perf_log::record("par_views_par_ms", par_ms);
    perf_log::record("par_views_speedup_x", speedup);
    // The floor asserts a *wall-clock* property, so it is deliberately
    // modest (the digest equality above is the hard correctness gate):
    // 1.05x tolerates oversubscribed CI runners while still failing if
    // the fan-out stops engaging entirely. It only arms when the serial
    // pass is slow enough (>= 0.5 ms) for the measurement to dominate
    // the ~20-50 µs/thread scoped-spawn overhead — below that, spawn
    // cost swamps the signal and a "speedup" number is noise.
    // QLM_SKIP_PAR_FLOOR opts a known-noisy host out entirely.
    let meaningful = serial_ms >= 0.5;
    if cores >= 4 && meaningful && std::env::var_os("QLM_SKIP_PAR_FLOOR").is_none() {
        assert!(
            speedup >= 1.05,
            "parallel view refresh must beat serial on a multicore host, got {speedup:.2}x"
        );
    }

    // Pool vs scoped spawn: the persistent pool replaced the per-pass
    // `std::thread::scope` fan-out, which paid ~20–50 µs per spawned
    // thread on every pass. Same simulation, same 4 lanes, same chunk
    // geometry — the only difference is dispatch (parked workers vs
    // fresh spawns), so the digests must collide exactly, and the pool
    // must be no slower than the baseline it replaced whenever the
    // wall-clock floor above is armed.
    let mut scoped = build(4);
    assert_eq!(
        scoped.refresh_views_scoped_for_bench(),
        par.refresh_views_for_bench(),
        "pool and scoped-spawn refresh must be bit-identical"
    );
    let scoped_ms = bench(
        &format!("par_views/refresh {FLEET} views (scoped, t=4)"),
        30,
        || {
            scoped.refresh_views_scoped_for_bench();
            FLEET as u64
        },
    );
    let pool_ms = bench(
        &format!("par_views/refresh {FLEET} views (pool,   t=4)"),
        30,
        || {
            par.refresh_views_for_bench();
            FLEET as u64
        },
    );
    let pool_vs_scoped = scoped_ms / pool_ms.max(1e-9);
    println!(
        "par_views pool-vs-scoped: {pool_vs_scoped:.2}x persistent pool vs scoped spawn \
         ({scoped_ms:.3} ms -> {pool_ms:.3} ms, no-regression floor at >=4 cores)"
    );
    perf_log::record("par_views_pool_vs_scoped_x", pool_vs_scoped);
    // Nominally the pool must be >= 1.0x the baseline it replaced (its
    // whole point is shedding ~20-50 µs of spawn cost per thread per
    // pass). The enforced floor leaves a 5% jitter allowance — two
    // timed runs on a shared CI runner can skew that much with no real
    // regression (same reasoning as the deliberately modest 1.05x
    // refresh floor above); a genuinely regressed pool (extra locking,
    // lost parallelism) lands well below it.
    if cores >= 4 && meaningful && std::env::var_os("QLM_SKIP_PAR_FLOOR").is_none() {
        assert!(
            pool_vs_scoped >= 0.95,
            "the persistent pool must not regress the scoped-spawn baseline, \
             got {pool_vs_scoped:.2}x"
        );
    }

    // The pricing half: the scheduler's per-queue repricing walk at the
    // paper's 64-instance testbed scale. The full solve's assignment
    // loop dominates wall time, so no speedup is asserted end to end —
    // the walk's thread-safety contract (bit-identical plan + penalty)
    // is what's enforced here.
    let catalog = ModelCatalog::paper_multi_model();
    let est = RwtEstimator::new(ProfileTable::default());
    let vs = views(64, &catalog);
    let groups: Vec<RequestGroup> = (0..1562u64)
        .map(|g| grp(g, (g % 4) as u32, 256, 60.0 + (g % 7) as f64 * 300.0))
        .collect();
    let refs: Vec<&RequestGroup> = groups.iter().collect();
    let mk = |threads: usize| {
        GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                threads,
                ..Default::default()
            },
            est.clone(),
        )
    };
    let s1 = mk(1);
    let s4 = mk(4);
    let a = s1.schedule(&refs, &vs, 0.0);
    let b = s4.schedule(&refs, &vs, 0.0);
    assert_eq!(a.orders, b.orders, "threaded pricing changed the plan");
    assert_eq!(
        a.total_penalty_s.to_bits(),
        b.total_penalty_s.to_bits(),
        "threaded pricing changed the penalty"
    );
    bench("par_views/solve+reprice 64 q (threads=1)", 5, || {
        s1.schedule(&refs, &vs, 0.0).stats.groups as u64
    });
    bench("par_views/solve+reprice 64 q (threads=4)", 5, || {
        s4.schedule(&refs, &vs, 0.0).stats.groups as u64
    });
}

fn bench_kv() {
    bench("kv_cache/alloc+append+free (1000 seqs)", 20, || {
        let mut kv = KvCache::new(500_000, 1_000_000);
        let mut n = 0;
        for i in 0..1000u64 {
            if kv.alloc_seq(i, 161).is_ok() {
                for _ in 0..64 {
                    let _ = kv.append_token(i);
                }
                n += 1;
            }
        }
        for i in 0..1000u64 {
            let _ = kv.free_seq(i);
        }
        n
    });
}

fn bench_instance_step() {
    bench("instance/step-loop (64 seqs × 200 iters)", 10, || {
        let mut inst = Instance::new(InstanceConfig::new(0, GpuKind::A100), ModelCatalog::paper());
        inst.swap_model(ModelId(0), 0.0);
        let t0 = inst.busy_until();
        for i in 0..64u64 {
            let _ = inst.try_admit(
                RunningSeq {
                    req_id: i,
                    model: ModelId(0),
                    prompt_tokens: 161,
                    target_output: 500,
                    generated: 0,
                    first_token_at: None,
                    arrival_s: 0.0,
                    prefilled: 0,
                    slice_left: 0,
                },
                t0,
            );
        }
        let mut now = t0;
        let mut steps = 0u64;
        for _ in 0..200 {
            let out = inst.step(now);
            now += out.dt;
            steps += 1;
        }
        steps * 64
    });
}

fn bench_e2e_fig09() {
    // Fig. 9 operating point at bench scale: W_A, 2×A100.
    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(1), 20.0, 600), 21);
    for policy in [Policy::qlm(), Policy::VllmFcfs] {
        let name = format!("e2e/single-model W_A 600 reqs [{}]", policy.name());
        let t = trace.clone();
        bench(&name, 3, || {
            let cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), policy);
            let m = Simulation::new(cfg, &t).run(&t);
            m.completed_count() as u64
        });
    }
}

fn bench_e2e_fig12() {
    // Fig. 12 operating point: W_B multi-model, 2×A100.
    let trace = Trace::generate(
        &WorkloadSpec::w_b(
            vec![ModelId(3), ModelId(4)],
            vec![ModelId(5), ModelId(6)],
            8.0,
            600,
        ),
        24,
    );
    for policy in [Policy::qlm(), Policy::Shepherd] {
        let name = format!("e2e/multi-model W_B 600 reqs [{}]", policy.name());
        let t = trace.clone();
        bench(&name, 3, || {
            let cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper_multi_model(), policy);
            let m = Simulation::new(cfg, &t).run(&t);
            m.completed_count() as u64
        });
    }
}

/// Observability trajectory: run the mixed-SLO scenario once with the
/// flight recorder + telemetry + RWT ledger on, and log (a) the RWT
/// estimator's per-class prediction error — the paper's Fig. 3/18
/// accuracy claim as a tracked number instead of a figure — and (b) the
/// scheduler pass-mix counters (delta-path share, dirty fraction, memo
/// hit rate) that tell whether the incremental scheduler is actually
/// taking its fast path at this workload shape.
fn bench_obs() {
    let scenario = Scenario::MixedSlo;
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests: 2000,
        fleet: scenario.default_fleet(),
        seed: 42,
    };
    let run = scenario.build(&knobs);
    let trace = Trace::generate(&run.spec, knobs.seed);
    let mut cfg = run.sim_config(Policy::qlm());
    cfg.seed = knobs.seed;
    cfg.obs = ObsConfig {
        trace: true,
        telemetry_every_s: Some(10.0),
    };
    let t0 = Instant::now();
    let (m, report) = Simulation::new(cfg, &trace).run_with_obs(&trace);
    let wall_ms = 1000.0 * t0.elapsed().as_secs_f64();
    let report = report.expect("observability was enabled");
    println!(
        "obs/mixed-slo 2000 reqs (traced)             {wall_ms:>9.3} ms  \
         ({} events, {} completed)",
        report.trace_jsonl.lines().count(),
        m.completed_count(),
    );
    for e in &report.rwt_errors {
        let key = format!("rwt_mae_{}_s", e.class.name().replace('-', "_"));
        println!("  {:<26} mae={:.3}s p90={:.3}s n={}", key, e.mae_s, e.p90_s, e.n);
        perf_log::record(&key, e.mae_s);
        perf_log::record(&format!("rwt_p90_{}_s", e.class.name().replace('-', "_")), e.p90_s);
    }
    let s = &report.sched;
    perf_log::record("sched_mix_passes", s.passes as f64);
    perf_log::record(
        "sched_mix_delta_share",
        s.delta as f64 / (s.passes.max(1)) as f64,
    );
    perf_log::record(
        "sched_mix_dirty_per_delta_pass",
        s.dirty as f64 / (s.delta.max(1)) as f64,
    );
    perf_log::record("sched_mix_crossings_drained", s.crossings_drained as f64);
    perf_log::record(
        "sched_mix_memo_hit_rate",
        s.memo_hits as f64 / ((s.memo_hits + s.memo_misses).max(1)) as f64,
    );
    println!(
        "  sched mix: {} passes, delta share {:.2}, memo hit rate {:.2}",
        s.passes,
        s.delta as f64 / (s.passes.max(1)) as f64,
        s.memo_hits as f64 / ((s.memo_hits + s.memo_misses).max(1)) as f64,
    );
}

fn xorshift(s: &mut u64) -> u64 {
    *s ^= *s << 13;
    *s ^= *s >> 7;
    *s ^= *s << 17;
    *s
}

/// Drive one `EventCore` through the steady-state shape of a serving
/// run: `n` arrivals spread over a 2 h horizon (millisecond resolution,
/// so duplicate timestamps occur), then a drain where every fourth pop
/// pushes a near-future wake — the pop→push interleave the engine's
/// iteration loop produces. Returns (pops, FNV digest over the popped
/// `(t, seq)` stream) so wheel and heap runs can be compared exactly.
fn drive_clock(core: &mut EventCore, n: usize) -> (u64, u64) {
    let mut seed = 0x517c_c1b7_2722_0a95u64;
    for i in 0..n {
        let t = (xorshift(&mut seed) % 7_200_000) as f64 / 1000.0;
        core.push(t, EventKind::Arrival(i));
    }
    let mut h: u64 = 0xcbf29ce484222325;
    let mut pops = 0u64;
    let mut extra = n / 4;
    while let Some(e) = core.pop() {
        h ^= e.t.to_bits();
        h = h.wrapping_mul(0x100000001b3);
        h ^= e.seq;
        h = h.wrapping_mul(0x100000001b3);
        pops += 1;
        if extra > 0 && pops % 4 == 0 {
            extra -= 1;
            let dt = (xorshift(&mut seed) % 2_000) as f64 / 1000.0;
            core.push(e.t + dt, EventKind::Wake(InstanceId(0)));
        }
    }
    (pops, h)
}

/// The tentpole clock claim: the two-level timer wheel vs the
/// `BinaryHeap` it replaced, at the megascale event count. Digest
/// equality over the full 1.25M-pop stream is the hard gate; the wall
/// times feed the CI `event_core speedup` floor (>= 2x).
fn bench_event_core() {
    const N: usize = 1_000_000;
    let mut wheel = EventCore::new(1);
    let mut heap = EventCore::new_heap_baseline(1);
    let (wheel_pops, wheel_digest) = drive_clock(&mut wheel, N);
    let (heap_pops, heap_digest) = drive_clock(&mut heap, N);
    assert_eq!(wheel_pops, heap_pops, "wheel and heap popped different event counts");
    assert_eq!(
        wheel_digest,
        heap_digest,
        "wheel pop order diverged from the (t, seq) heap order"
    );
    let wheel_ms = bench("event_core/wheel 1M arrivals + wakes", 3, || {
        let mut c = EventCore::new(1);
        drive_clock(&mut c, N).0
    });
    let heap_ms = bench("event_core/heap  1M arrivals + wakes", 3, || {
        let mut c = EventCore::new_heap_baseline(1);
        drive_clock(&mut c, N).0
    });
    let speedup = heap_ms / wheel_ms.max(1e-9);
    let events_per_sec = wheel_pops as f64 / (wheel_ms / 1000.0).max(1e-9);
    println!(
        "event_core speedup: {speedup:.1}x wheel vs heap at {wheel_pops} events \
         ({heap_ms:.1} ms -> {wheel_ms:.1} ms, target >= 2x)"
    );
    perf_log::record("event_core_wheel_ms", wheel_ms);
    perf_log::record("event_core_heap_ms", heap_ms);
    perf_log::record("event_core_speedup_x", speedup);
    perf_log::record("events_per_sec", events_per_sec);
}

/// Allocation census of the steady-state scheduler pass (the paths the
/// `hot-loop-alloc` audit rule marks): a warm 4-dirty delta pass over
/// the 1562-group cached plan, and the per-instance view refresh. The
/// counting global allocator reports how many times each pass asks the
/// allocator for memory; `alloc_per_pass` lands in `BENCH_qlm.json` so
/// scratch-buffer regressions show up as a diffable number.
fn bench_hot_alloc() {
    let catalog = ModelCatalog::paper_multi_model();
    let est = RwtEstimator::new(ProfileTable::default());
    let vs = views(10, &catalog);
    const N_GROUPS: usize = 1562;
    let groups: Vec<RequestGroup> = (0..N_GROUPS as u64)
        .map(|g| grp(g, (g % 4) as u32, 256, 60.0 + (g % 7) as f64 * 300.0))
        .collect();
    let refs: Vec<&RequestGroup> = groups.iter().collect();
    let inc = GlobalScheduler::new(
        SchedulerConfig {
            solver: SolverKind::Greedy,
            ..Default::default()
        },
        est,
    );
    inc.schedule(&refs, &vs, 0.0);
    let mut cursor = 0usize;
    let pass = |cursor: &mut usize| {
        let dirty: Vec<&RequestGroup> =
            (0..4).map(|k| &groups[(*cursor + k) % N_GROUPS]).collect();
        *cursor = (*cursor + 4) % N_GROUPS;
        let d = SchedDelta {
            dirty,
            removed: vec![],
            total_groups: N_GROUPS,
            groups: None,
        };
        inc.try_schedule_delta(&d, &vs, 0.0).expect("warm cache")
    };
    // Warm passes: scratch buffers and cached queues reach steady size.
    for _ in 0..8 {
        pass(&mut cursor);
    }
    const PASSES: u64 = 100;
    let a0 = alloc_count::allocs();
    for _ in 0..PASSES {
        pass(&mut cursor);
    }
    let per_pass = (alloc_count::allocs() - a0) as f64 / PASSES as f64;

    let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 20.0, 64), 7);
    let cfg = SimConfig::new(fleet_a100(64), ModelCatalog::paper(), Policy::qlm());
    let mut sim = Simulation::new(cfg, &trace);
    for _ in 0..8 {
        sim.refresh_views_for_bench();
    }
    let r0 = alloc_count::allocs();
    for _ in 0..PASSES {
        sim.refresh_views_for_bench();
    }
    let per_refresh = (alloc_count::allocs() - r0) as f64 / PASSES as f64;
    println!(
        "hot_alloc/delta pass (4 dirty, 1562 grp)     {per_pass:>9.1} allocs/pass \
         (driver's own Vecs included)"
    );
    println!("hot_alloc/view refresh (64 instances)        {per_refresh:>9.1} allocs/pass");
    perf_log::record("alloc_per_pass", per_pass);
    perf_log::record("alloc_per_view_refresh", per_refresh);
}

/// Wall-clock budget for the full megascale run (generous: CI runners
/// are slow and shared; a timer-wheel or arena regression blows it by
/// an order of magnitude, not by percent).
const MEGASCALE_BUDGET_S: f64 = 600.0;

/// The 1M-request scale gate: generate and run `--scenario megascale`
/// end to end, record the wall time, and fail if it blows the budget.
/// Explicit-only (`cargo bench -- megascale`): a full-default bench run
/// should not cost minutes. `QLM_SKIP_SCALE_GATE=1` skips the budget
/// assert for known-slow hosts; the wall time is still recorded.
fn bench_megascale() {
    let scenario = Scenario::Megascale;
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests: scenario.requests_for(scenario.default_rate(), 7200.0),
        fleet: scenario.default_fleet(),
        seed: 7,
    };
    let run = scenario.build(&knobs);
    let trace = Trace::generate(&run.spec, knobs.seed);
    assert!(
        trace.len() >= 1_000_000,
        "megascale must be a 1M+ request trace, got {}",
        trace.len()
    );
    let mut cfg = run.sim_config(Policy::qlm());
    cfg.seed = knobs.seed;
    let t0 = Instant::now();
    let m = Simulation::new(cfg, &trace).run(&trace);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "megascale/{} reqs end-to-end               {wall:>9.1} s wall ({} completed)",
        trace.len(),
        m.completed_count()
    );
    perf_log::record("megascale_wall_s", wall);
    perf_log::record("megascale_requests", trace.len() as f64);
    if std::env::var_os("QLM_SKIP_SCALE_GATE").is_none() {
        assert!(
            wall <= MEGASCALE_BUDGET_S,
            "megascale run blew its wall-clock budget: {wall:.1} s > {MEGASCALE_BUDGET_S} s \
             (set QLM_SKIP_SCALE_GATE=1 to waive on a known-slow host)"
        );
    }
}

/// Wall-clock budget for the 10M-request gigascale run: ~8.5× the
/// megascale event count, so ~8.5× its budget, rounded up for shared
/// CI runners. A streaming or sharding regression blows it by an order
/// of magnitude, not by percent.
const GIGASCALE_BUDGET_S: f64 = 3600.0;

/// Peak-heap budget for gigascale: the streamed-compact path holds
/// O(in-flight) request state plus the broker's 8-byte-per-id route
/// table (~80 MB at 10M). A materialized trace (~0.6 GB) or an
/// archived completed set (~2 GB) lands far past this line.
const GIGASCALE_PEAK_BYTES: u64 = 2_000_000_000;

/// The 10M-request gigascale gate: run `--scenario gigascale` through
/// the streamed-arrival + compact-records path end to end, recording
/// wall time AND peak heap bytes (the counting allocator's high-water
/// mark) into `BENCH_qlm.json`. Explicit-only (`cargo bench --
/// gigascale`); `QLM_SKIP_SCALE_GATE=1` waives both budget asserts on
/// known-slow hosts while still recording the numbers. The trace is
/// never materialized — `Simulation::new_streaming` profiles and
/// replays the seeded stream, which is the whole point of the gate.
fn bench_gigascale() {
    let scenario = Scenario::Gigascale;
    let knobs = ScenarioKnobs {
        rate: scenario.default_rate(),
        requests: scenario.requests_for(scenario.default_rate(), 7200.0),
        fleet: scenario.default_fleet(),
        seed: 7,
    };
    let run = scenario.build(&knobs);
    let total = run.spec.total_requests();
    assert!(
        total >= 10_000_000,
        "gigascale must be a 10M+ request workload, got {total}"
    );
    let mut cfg = run.sim_config(Policy::qlm());
    cfg.seed = knobs.seed;
    cfg.compact_records = true;
    alloc_count::reset_peak();
    let t0 = Instant::now();
    let m = Simulation::new_streaming(cfg, &run.spec, knobs.seed).run_streaming();
    let wall = t0.elapsed().as_secs_f64();
    let peak = alloc_count::peak_bytes();
    println!(
        "gigascale/{total} reqs streamed end-to-end   {wall:>9.1} s wall \
         ({} completed, peak heap {:.2} GB)",
        m.completed_count(),
        peak as f64 / 1e9,
    );
    perf_log::record("gigascale_wall_s", wall);
    perf_log::record("gigascale_requests", total as f64);
    perf_log::record("gigascale_peak_alloc_bytes", peak as f64);
    if std::env::var_os("QLM_SKIP_SCALE_GATE").is_none() {
        assert!(
            wall <= GIGASCALE_BUDGET_S,
            "gigascale run blew its wall-clock budget: {wall:.1} s > {GIGASCALE_BUDGET_S} s \
             (set QLM_SKIP_SCALE_GATE=1 to waive on a known-slow host)"
        );
        assert!(
            peak <= GIGASCALE_PEAK_BYTES,
            "gigascale run blew its peak-heap budget: {peak} B > {GIGASCALE_PEAK_BYTES} B — \
             something materialized O(total-requests) state on the streamed path"
        );
    }
}

/// The shard-parallel scheduling claim: per-queue repricing walks on a
/// multi-model `scale`-shaped cached plan are disjoint by construction
/// (one model's shard feeds one queue's groups), so the delta pass fans
/// them over the worker pool. This bench runs the same warm delta-pass
/// sequence through a serial (threads=1) and a sharded-parallel
/// (threads=4) scheduler, asserts the plans are identical, and reports
/// the speedup the CI bench-smoke job floors at >= 1.5x.
fn bench_shard_sched() {
    const N_INSTANCES: u32 = 16;
    const N_GROUPS: usize = 8192;
    const DIRTY_PER_PASS: usize = 16;
    const PASSES: usize = 24;
    let catalog = ModelCatalog::paper_multi_model();
    let vs = views(N_INSTANCES, &catalog);
    // Multi-model scale shape: groups spread over four models and seven
    // deadline tiers, ~512 groups per queue once placed.
    let groups: Vec<RequestGroup> = (0..N_GROUPS as u64)
        .map(|g| grp(g, (g % 4) as u32, 256, 60.0 + (g % 7) as f64 * 300.0))
        .collect();
    let refs: Vec<&RequestGroup> = groups.iter().collect();
    let drive = |threads: usize| -> (f64, Vec<(u32, Vec<GroupId>)>) {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                threads,
                ..Default::default()
            },
            RwtEstimator::new(ProfileTable::default()),
        );
        sched.schedule(&refs, &vs, 0.0);
        let mut cursor = 0usize;
        let mut pass = |cursor: &mut usize| {
            let dirty: Vec<&RequestGroup> = (0..DIRTY_PER_PASS)
                .map(|k| &groups[(*cursor + k * 37) % N_GROUPS])
                .collect();
            *cursor = (*cursor + DIRTY_PER_PASS) % N_GROUPS;
            let d = SchedDelta {
                dirty,
                removed: vec![],
                total_groups: N_GROUPS,
                groups: None,
            };
            sched.try_schedule_delta(&d, &vs, 0.0).expect("warm cache")
        };
        // Warm passes: scratch buffers and cached queues reach steady
        // size before the timed window.
        for _ in 0..4 {
            pass(&mut cursor);
        }
        let t0 = Instant::now();
        let mut last = None;
        for _ in 0..PASSES {
            last = Some(pass(&mut cursor));
        }
        let ms = t0.elapsed().as_secs_f64() * 1000.0 / PASSES as f64;
        let mut orders: Vec<(u32, Vec<GroupId>)> = last
            .expect("at least one pass")
            .orders
            .into_iter()
            .map(|(id, o)| (id.0, o))
            .collect();
        orders.sort_by_key(|(id, _)| *id);
        (ms, orders)
    };
    let (serial_ms, serial_orders) = drive(1);
    let (par_ms, par_orders) = drive(4);
    assert_eq!(
        serial_orders, par_orders,
        "shard-parallel delta pass diverged from the serial plan"
    );
    let speedup = serial_ms / par_ms.max(1e-9);
    println!(
        "shard_sched/delta pass {DIRTY_PER_PASS} dirty, {N_GROUPS} grp, {N_INSTANCES} q  \
         {serial_ms:>7.2} ms serial -> {par_ms:>7.2} ms x4"
    );
    println!("shard_sched speedup: {speedup:.1}x sharded vs unified (target >= 1.5x)");
    perf_log::record("shard_sched_serial_ms", serial_ms);
    perf_log::record("shard_sched_par_ms", par_ms);
    perf_log::record("shard_sched_speedup_x", speedup);
}

#[cfg(feature = "pjrt")]
fn bench_runtime_decode() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.txt").exists() {
        println!("runtime/decode-step: skipped (run `make artifacts`)");
        return;
    }
    let model = qlm::runtime::TinyModel::load(dir).expect("artifacts");
    let prompts: Vec<&[u8]> = vec![b"benchmark prompt for the tiny model"; 8];
    let (logits, mut state) = model.prefill(&prompts).expect("prefill");
    let tokens: Vec<i32> = logits
        .iter()
        .map(|l| qlm::runtime::TinyModel::argmax(l))
        .collect();
    bench("runtime/pjrt decode step (batch 8)", 20, || {
        let out = model.decode_step(&mut state, &tokens).expect("step");
        out.len() as u64 // 8 sequences → 8 tokens per step
    });
}

#[cfg(not(feature = "pjrt"))]
fn bench_runtime_decode() {
    println!("runtime/decode-step: skipped (build with --features pjrt)");
}

fn main() {
    // Optional substring filter: `cargo bench -- queue` runs only the
    // queue hot-path benches (what the CI bench-smoke job does).
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let runs = |name: &str| match filter.as_deref() {
        Some(f) => name.contains(f),
        None => true,
    };
    println!("qlm benchmarks (mean ± stddev over timed iterations)\n");
    if runs("queue") {
        bench_queue_hot_path();
    }
    if runs("event_core") {
        bench_event_core();
    }
    if runs("hot_alloc") {
        bench_hot_alloc();
    }
    // Explicit-only: the 1M-request end-to-end run costs minutes, so it
    // never rides along on an unfiltered `cargo bench`.
    if filter.as_deref() == Some("megascale") {
        bench_megascale();
    } else if filter.is_none() {
        println!("megascale: run explicitly with `cargo bench -- megascale` (1M-request gate)");
    }
    // Explicit-only for the same reason, an order of magnitude up: the
    // 10M-request streamed run is the wall + peak-heap CI gate.
    if filter.as_deref() == Some("gigascale") {
        bench_gigascale();
    } else if filter.is_none() {
        println!(
            "gigascale: run explicitly with `cargo bench -- gigascale` \
             (10M-request streamed gate)"
        );
    }
    if runs("shard_sched") {
        bench_shard_sched();
    }
    if runs("rwt") {
        bench_rwt();
    }
    if runs("scheduler") {
        bench_scheduler();
    }
    if runs("sched_incremental") {
        bench_sched_incremental();
    }
    if runs("dirty_frac") {
        bench_dirty_frac_sweep();
    }
    if runs("capacity_plan") {
        bench_capacity_plan();
    }
    if runs("par_views") {
        bench_par_views();
    }
    if runs("kv") {
        bench_kv();
    }
    if runs("instance") {
        bench_instance_step();
    }
    if runs("e2e") {
        bench_e2e_fig09();
        bench_e2e_fig12();
    }
    if runs("obs") {
        bench_obs();
    }
    if runs("runtime") {
        bench_runtime_decode();
    }
    perf_log::write("BENCH_qlm.json");
    println!("\nfigure regeneration: `qlm figures [--fig N] [--full]` (see DESIGN.md index)");
}
