//! Two-tier model placement (§5, Model Swapping): models live in storage,
//! are staged into CPU memory ("warm"), and swapped into GPU memory
//! ("active"). The registry tracks the tier of each model for one serving
//! instance and prices each transition.

use crate::backend::{ModelCatalog, ModelId, PerfModel};

/// Where a model's weights currently are, per instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelTier {
    /// Active in GPU memory.
    Gpu,
    /// Warm in host CPU memory.
    Cpu,
    /// Cold in the model registry (storage).
    Storage,
}

/// Per-instance model placement state.
#[derive(Debug, Clone)]
pub struct ModelRegistry {
    catalog: ModelCatalog,
    /// CPU memory budget for warm models (GiB). The paper provisions
    /// 80 GB for 7B/13B models and 320 GB for Llama-70B (§8.3).
    cpu_capacity_gib: f64,
    cpu_resident: Vec<ModelId>,
    gpu_model: Option<ModelId>,
    /// Cumulative swap counts for metrics / Fig. 5-style analyses.
    pub swaps_to_gpu: u64,
    pub stages_to_cpu: u64,
}

impl ModelRegistry {
    pub fn new(catalog: ModelCatalog, cpu_capacity_gib: f64) -> Self {
        ModelRegistry {
            catalog,
            cpu_capacity_gib,
            cpu_resident: Vec::new(),
            gpu_model: None,
            swaps_to_gpu: 0,
            stages_to_cpu: 0,
        }
    }

    pub fn catalog(&self) -> &ModelCatalog {
        &self.catalog
    }

    pub fn active(&self) -> Option<ModelId> {
        self.gpu_model
    }

    pub fn tier(&self, m: ModelId) -> ModelTier {
        if self.gpu_model == Some(m) {
            ModelTier::Gpu
        } else if self.cpu_resident.contains(&m) {
            ModelTier::Cpu
        } else {
            ModelTier::Storage
        }
    }

    fn cpu_used_gib(&self) -> f64 {
        self.cpu_resident
            .iter()
            .map(|&m| self.catalog.get(m).weight_gib)
            .sum()
    }

    /// Warm-start hint from the virtual-queue order (§5): models appearing
    /// later in the virtual queue are staged into CPU memory, front first,
    /// until the CPU budget is exhausted; the rest stay cold.
    pub fn set_warm_set(&mut self, queue_order: &[ModelId]) {
        let mut resident = Vec::new();
        let mut used = 0.0;
        for &m in queue_order {
            if Some(m) == self.gpu_model || resident.contains(&m) {
                continue;
            }
            let w = self.catalog.get(m).weight_gib;
            if used + w <= self.cpu_capacity_gib {
                if !self.cpu_resident.contains(&m) {
                    self.stages_to_cpu += 1;
                }
                resident.push(m);
                used += w;
            }
        }
        self.cpu_resident = resident;
    }

    /// Time to make `m` active on the GPU from its current tier.
    /// Storage-resident models pay both the storage→CPU stage and the
    /// CPU→GPU swap (§5: "two distinct swaps").
    pub fn swap_in_time_s(&self, m: ModelId, perf: &PerfModel) -> f64 {
        match self.tier(m) {
            ModelTier::Gpu => 0.0,
            ModelTier::Cpu => perf.swap_cpu_gpu_s,
            ModelTier::Storage => perf.swap_storage_cpu_s + perf.swap_cpu_gpu_s,
        }
    }

    /// Make `m` the active GPU model; returns the swap latency. The
    /// previously active model is demoted to CPU if it fits, else storage.
    pub fn swap_to_gpu(&mut self, m: ModelId, perf: &PerfModel) -> f64 {
        let t = self.swap_in_time_s(m, perf);
        if self.gpu_model == Some(m) {
            return 0.0;
        }
        if let Some(prev) = self.gpu_model.take() {
            let w = self.catalog.get(prev).weight_gib;
            if self.cpu_used_gib() + w <= self.cpu_capacity_gib
                && !self.cpu_resident.contains(&prev)
            {
                self.cpu_resident.push(prev);
            }
        }
        self.cpu_resident.retain(|&x| x != m);
        self.gpu_model = Some(m);
        self.swaps_to_gpu += 1;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GpuKind;

    fn setup() -> (ModelRegistry, PerfModel) {
        let catalog = ModelCatalog::paper();
        let perf = PerfModel::profile(catalog.get(ModelId(0)), GpuKind::A100, 161.0);
        (ModelRegistry::new(catalog, 80.0), perf)
    }

    #[test]
    fn initial_tier_is_storage() {
        let (reg, _) = setup();
        assert_eq!(reg.tier(ModelId(0)), ModelTier::Storage);
        assert_eq!(reg.active(), None);
    }

    #[test]
    fn swap_from_storage_costs_both_hops() {
        let (mut reg, perf) = setup();
        let cold = reg.swap_in_time_s(ModelId(0), &perf);
        assert!((cold - (perf.swap_storage_cpu_s + perf.swap_cpu_gpu_s)).abs() < 1e-12);
        reg.set_warm_set(&[ModelId(0)]);
        let warm = reg.swap_in_time_s(ModelId(0), &perf);
        assert!((warm - perf.swap_cpu_gpu_s).abs() < 1e-12);
        reg.swap_to_gpu(ModelId(0), &perf);
        assert_eq!(reg.swap_in_time_s(ModelId(0), &perf), 0.0);
    }

    #[test]
    fn warm_set_respects_cpu_budget() {
        let (mut reg, _) = setup();
        // 80 GiB budget: mistral (13.6) + vicuna (24.2) fit; llama (130) doesn't.
        reg.set_warm_set(&[ModelId(2), ModelId(0), ModelId(1)]);
        assert_eq!(reg.tier(ModelId(2)), ModelTier::Storage);
        assert_eq!(reg.tier(ModelId(0)), ModelTier::Cpu);
        assert_eq!(reg.tier(ModelId(1)), ModelTier::Cpu);
    }

    #[test]
    fn swap_demotes_previous_to_cpu() {
        let (mut reg, perf) = setup();
        reg.swap_to_gpu(ModelId(0), &perf);
        reg.swap_to_gpu(ModelId(1), &perf);
        assert_eq!(reg.active(), Some(ModelId(1)));
        assert_eq!(reg.tier(ModelId(0)), ModelTier::Cpu);
        assert_eq!(reg.swaps_to_gpu, 2);
    }

    #[test]
    fn swap_to_active_model_is_free() {
        let (mut reg, perf) = setup();
        reg.swap_to_gpu(ModelId(0), &perf);
        let swaps = reg.swaps_to_gpu;
        assert_eq!(reg.swap_to_gpu(ModelId(0), &perf), 0.0);
        assert_eq!(reg.swaps_to_gpu, swaps);
    }
}
