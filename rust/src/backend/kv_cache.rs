//! Paged KV-cache block allocator — the PagedAttention memory manager
//! (§2.1). KV memory is carved into fixed-size blocks (16 tokens each, as
//! in vLLM); a sequence holds an ordered list of blocks; allocation is
//! O(1) via a free list; eviction moves a sequence's blocks to a CPU-side
//! table so decoding can resume without prompt recompute (§5, Request
//! Eviction).

use std::collections::HashMap;

/// Tokens per KV block (vLLM default).
pub const BLOCK_TOKENS: u32 = 16;

/// Identifier of a physical KV block on the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub u32);

/// A sequence's KV footprint.
#[derive(Debug, Clone, Default)]
struct SeqAlloc {
    blocks: Vec<BlockId>,
    tokens: u64,
}

/// Paged allocator over a fixed device token budget, plus a CPU-side
/// swap space for evicted sequences.
#[derive(Debug)]
pub struct KvCache {
    free: Vec<BlockId>,
    total_blocks: u32,
    gpu: HashMap<u64, SeqAlloc>,
    /// seq id → token count parked in CPU memory (blocks are freed on
    /// device; token count suffices to re-admit).
    cpu: HashMap<u64, u64>,
    cpu_tokens: u64,
    cpu_token_capacity: u64,
}

/// Errors from allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    OutOfBlocks,
    UnknownSeq,
    CpuFull,
}

impl KvCache {
    /// `token_capacity` device tokens, `cpu_token_capacity` swap tokens.
    pub fn new(token_capacity: u64, cpu_token_capacity: u64) -> Self {
        let total_blocks = (token_capacity / BLOCK_TOKENS as u64) as u32;
        KvCache {
            free: (0..total_blocks).rev().map(BlockId).collect(),
            total_blocks,
            gpu: HashMap::new(),
            cpu: HashMap::new(),
            cpu_tokens: 0,
            cpu_token_capacity,
        }
    }

    pub fn total_blocks(&self) -> u32 {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn used_blocks(&self) -> u32 {
        self.total_blocks - self.free_blocks()
    }

    /// Device tokens currently allocated.
    pub fn gpu_tokens(&self) -> u64 {
        self.gpu.values().map(|s| s.tokens).sum()
    }

    /// Tokens parked in CPU swap space.
    pub fn cpu_tokens(&self) -> u64 {
        self.cpu_tokens
    }

    /// Device utilization in [0,1].
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    fn blocks_for(tokens: u64) -> u32 {
        tokens.div_ceil(BLOCK_TOKENS as u64) as u32
    }

    /// Can `tokens` more tokens be appended for `seq` (or a new seq)?
    pub fn can_grow(&self, seq: u64, tokens: u64) -> bool {
        let cur = self.gpu.get(&seq).map(|s| s.tokens).unwrap_or(0);
        let need = Self::blocks_for(cur + tokens)
            .saturating_sub(Self::blocks_for(cur).min(Self::blocks_for(cur + tokens)));
        need <= self.free_blocks()
    }

    /// Allocate KV for a new sequence's prompt (prefill).
    pub fn alloc_seq(&mut self, seq: u64, prompt_tokens: u64) -> Result<(), KvError> {
        debug_assert!(!self.gpu.contains_key(&seq), "seq {seq} already allocated");
        let need = Self::blocks_for(prompt_tokens);
        if need > self.free_blocks() {
            return Err(KvError::OutOfBlocks);
        }
        let blocks = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.gpu.insert(
            seq,
            SeqAlloc {
                blocks,
                tokens: prompt_tokens,
            },
        );
        Ok(())
    }

    /// Append one generated token (decode iteration); may need a new block.
    pub fn append_token(&mut self, seq: u64) -> Result<(), KvError> {
        let alloc = self.gpu.get_mut(&seq).ok_or(KvError::UnknownSeq)?;
        let before = Self::blocks_for(alloc.tokens);
        let after = Self::blocks_for(alloc.tokens + 1);
        if after > before {
            match self.free.pop() {
                Some(b) => alloc.blocks.push(b),
                None => return Err(KvError::OutOfBlocks),
            }
        }
        alloc.tokens += 1;
        Ok(())
    }

    /// Free a finished sequence's device blocks.
    pub fn free_seq(&mut self, seq: u64) -> Result<u64, KvError> {
        let alloc = self.gpu.remove(&seq).ok_or(KvError::UnknownSeq)?;
        self.free.extend(alloc.blocks);
        Ok(alloc.tokens)
    }

    /// Evict a running sequence's KV to CPU memory (§5, Request Eviction:
    /// "we migrate it to CPU memory instead"). Returns tokens moved.
    pub fn evict_to_cpu(&mut self, seq: u64) -> Result<u64, KvError> {
        let tokens = self.gpu.get(&seq).ok_or(KvError::UnknownSeq)?.tokens;
        if self.cpu_tokens + tokens > self.cpu_token_capacity {
            return Err(KvError::CpuFull);
        }
        let alloc = self.gpu.remove(&seq).unwrap();
        self.free.extend(alloc.blocks);
        self.cpu.insert(seq, tokens);
        self.cpu_tokens += tokens;
        Ok(tokens)
    }

    /// Restore an evicted sequence's KV from CPU to the device.
    pub fn restore_from_cpu(&mut self, seq: u64) -> Result<u64, KvError> {
        let &tokens = self.cpu.get(&seq).ok_or(KvError::UnknownSeq)?;
        let need = Self::blocks_for(tokens);
        if need > self.free_blocks() {
            return Err(KvError::OutOfBlocks);
        }
        self.cpu.remove(&seq);
        self.cpu_tokens -= tokens;
        let blocks = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.gpu.insert(seq, SeqAlloc { blocks, tokens });
        Ok(tokens)
    }

    /// Tokens held in CPU swap for `seq`, if evicted.
    pub fn cpu_resident(&self, seq: u64) -> Option<u64> {
        self.cpu.get(&seq).copied()
    }

    /// Drop an evicted sequence entirely (e.g. it finished elsewhere).
    pub fn drop_cpu(&mut self, seq: u64) {
        if let Some(t) = self.cpu.remove(&seq) {
            self.cpu_tokens -= t;
        }
    }

    /// Flush everything (model swap flushes the KV cache, §5).
    pub fn flush(&mut self) {
        self.gpu.clear();
        self.cpu.clear();
        self.cpu_tokens = 0;
        self.free = (0..self.total_blocks).rev().map(BlockId).collect();
    }

    /// Tokens on device for `seq`.
    pub fn seq_tokens(&self, seq: u64) -> Option<u64> {
        self.gpu.get(&seq).map(|s| s.tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_free_round_trip() {
        let mut kv = KvCache::new(1024, 10_000);
        assert_eq!(kv.total_blocks(), 64);
        kv.alloc_seq(1, 100).unwrap();
        assert_eq!(kv.used_blocks(), 7); // ceil(100/16)
        assert_eq!(kv.free_seq(1).unwrap(), 100);
        assert_eq!(kv.used_blocks(), 0);
    }

    #[test]
    fn append_grows_blocks_lazily() {
        let mut kv = KvCache::new(1024, 0);
        kv.alloc_seq(1, 16).unwrap();
        assert_eq!(kv.used_blocks(), 1);
        kv.append_token(1).unwrap(); // 17 tokens → 2 blocks
        assert_eq!(kv.used_blocks(), 2);
        for _ in 0..15 {
            kv.append_token(1).unwrap();
        }
        assert_eq!(kv.used_blocks(), 2); // 32 tokens still 2 blocks
    }

    #[test]
    fn out_of_blocks_reported() {
        let mut kv = KvCache::new(32, 0);
        kv.alloc_seq(1, 32).unwrap();
        assert_eq!(kv.alloc_seq(2, 1), Err(KvError::OutOfBlocks));
        assert_eq!(kv.append_token(1), Err(KvError::OutOfBlocks));
    }

    #[test]
    fn evict_restore_preserves_tokens() {
        let mut kv = KvCache::new(1024, 10_000);
        kv.alloc_seq(7, 200).unwrap();
        let moved = kv.evict_to_cpu(7).unwrap();
        assert_eq!(moved, 200);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.cpu_tokens(), 200);
        assert_eq!(kv.cpu_resident(7), Some(200));
        let back = kv.restore_from_cpu(7).unwrap();
        assert_eq!(back, 200);
        assert_eq!(kv.seq_tokens(7), Some(200));
        assert_eq!(kv.cpu_tokens(), 0);
    }

    #[test]
    fn cpu_capacity_enforced() {
        let mut kv = KvCache::new(1024, 100);
        kv.alloc_seq(1, 80).unwrap();
        kv.alloc_seq(2, 80).unwrap();
        kv.evict_to_cpu(1).unwrap();
        assert_eq!(kv.evict_to_cpu(2), Err(KvError::CpuFull));
    }

    #[test]
    fn eviction_frees_device_space_for_new_seq() {
        // The §2.4 Insight-2 scenario: device full of batch requests, an
        // interactive request needs room now.
        let mut kv = KvCache::new(160, 10_000);
        kv.alloc_seq(1, 160).unwrap();
        assert!(kv.alloc_seq(2, 64).is_err());
        kv.evict_to_cpu(1).unwrap();
        kv.alloc_seq(2, 64).unwrap();
        assert_eq!(kv.seq_tokens(2), Some(64));
    }

    #[test]
    fn flush_resets_everything() {
        let mut kv = KvCache::new(1024, 1000);
        kv.alloc_seq(1, 100).unwrap();
        kv.alloc_seq(2, 50).unwrap();
        kv.evict_to_cpu(2).unwrap();
        kv.flush();
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.cpu_tokens(), 0);
        assert_eq!(kv.free_blocks(), kv.total_blocks());
    }

    #[test]
    fn no_block_leak_under_churn() {
        let mut kv = KvCache::new(10_000, 100_000);
        let mut rng = crate::util::Rng::new(42);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..2_000 {
            match rng.usize(4) {
                0 => {
                    let t = 1 + rng.usize(300) as u64;
                    if kv.alloc_seq(next_id, t).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !live.is_empty() => {
                    let i = rng.usize(live.len());
                    let s = live.swap_remove(i);
                    kv.free_seq(s).unwrap();
                }
                2 if !live.is_empty() => {
                    let s = live[rng.usize(live.len())];
                    let _ = kv.append_token(s);
                }
                3 if !live.is_empty() => {
                    let i = rng.usize(live.len());
                    let s = live[i];
                    if kv.evict_to_cpu(s).is_ok() {
                        live.swap_remove(i);
                        if kv.restore_from_cpu(s).is_ok() {
                            live.push(s);
                        } else {
                            kv.drop_cpu(s);
                        }
                    }
                }
                _ => {}
            }
        }
        for s in live.drain(..) {
            kv.free_seq(s).unwrap();
        }
        assert_eq!(kv.free_blocks(), kv.total_blocks(), "leaked blocks");
    }
}
