//! LLM model catalog. The paper serves Mistral-7B, Vicuna-13B, and
//! Llama-70B (plus fine-tuned variants for the multi-model workloads).
//! A model is characterized by the constants that drive the timing model
//! and memory accounting: weight bytes, KV bytes/token, parameter count.

/// Opaque model identifier (index into a [`ModelCatalog`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(pub u32);

/// Static description of an LLM.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub id: ModelId,
    pub name: String,
    /// Parameter count (drives prefill FLOPs).
    pub params_b: f64,
    /// Weight footprint in GiB (bf16 unless noted).
    pub weight_gib: f64,
    /// KV cache bytes per token = 2 (K,V) · layers · kv_heads · head_dim · 2 B.
    pub kv_bytes_per_token: u64,
    /// Tensor-parallel degree the instance uses (Llama-70B spans GPUs).
    pub tp_degree: u32,
}

impl ModelSpec {
    fn new(
        id: u32,
        name: &str,
        params_b: f64,
        layers: u64,
        kv_heads: u64,
        head_dim: u64,
        tp_degree: u32,
    ) -> Self {
        ModelSpec {
            id: ModelId(id),
            name: name.to_string(),
            params_b,
            weight_gib: params_b * 2.0 / 1.073741824, // bf16, GiB
            kv_bytes_per_token: 2 * layers * kv_heads * head_dim * 2,
            tp_degree,
        }
    }
}

/// The set of models available to a cluster.
#[derive(Debug, Clone)]
pub struct ModelCatalog {
    pub models: Vec<ModelSpec>,
}

impl ModelCatalog {
    /// The paper's three base models.
    pub fn paper() -> Self {
        ModelCatalog {
            models: vec![
                // Mistral-7B: 32 layers, GQA 8 kv-heads × 128.
                ModelSpec::new(0, "mistral-7b", 7.3, 32, 8, 128, 1),
                // Vicuna-13B: 40 layers, MHA 40 kv-heads × 128.
                ModelSpec::new(1, "vicuna-13b", 13.0, 40, 40, 128, 1),
                // Llama-70B: 80 layers, GQA 8 kv-heads × 128, TP-4.
                ModelSpec::new(2, "llama-70b", 70.0, 80, 8, 128, 4),
            ],
        }
    }

    /// Paper catalog plus fine-tuned variants (same architecture, distinct
    /// weights ⇒ distinct swaps), as used by W_B: Batch-1 on fine-tuned
    /// Mistral-7B + Llama-70B, Batch-2 on fine-tuned Vicuna-13B + Llama-70B.
    pub fn paper_multi_model() -> Self {
        let mut c = Self::paper();
        let mk = |id: u32, base: &ModelSpec, suffix: &str| {
            let mut m = base.clone();
            m.id = ModelId(id);
            m.name = format!("{}-{}", m.name, suffix);
            m
        };
        let mistral = c.models[0].clone();
        let vicuna = c.models[1].clone();
        let llama = c.models[2].clone();
        c.models.push(mk(3, &mistral, "ft-b1"));
        c.models.push(mk(4, &llama, "ft-b1"));
        c.models.push(mk(5, &vicuna, "ft-b2"));
        c.models.push(mk(6, &llama, "ft-b2"));
        c
    }

    /// The tiny real model served end-to-end through the PJRT runtime
    /// (examples/e2e_serve.rs). Must match python/compile/model.py.
    pub fn tiny() -> Self {
        ModelCatalog {
            models: vec![ModelSpec::new(0, "tiny-qlm-2m", 0.002, 4, 4, 16, 1)],
        }
    }

    pub fn get(&self, id: ModelId) -> &ModelSpec {
        &self.models[id.0 as usize]
    }

    pub fn by_name(&self, name: &str) -> Option<&ModelSpec> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn ids(&self) -> Vec<ModelId> {
        self.models.iter().map(|m| m.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_match_architecture() {
        let c = ModelCatalog::paper();
        // Mistral-7B GQA: 2·32·8·128·2 = 131072 B/token.
        assert_eq!(c.by_name("mistral-7b").unwrap().kv_bytes_per_token, 131_072);
        // Vicuna-13B MHA: 2·40·40·128·2 = 819200 B/token.
        assert_eq!(c.by_name("vicuna-13b").unwrap().kv_bytes_per_token, 819_200);
        // Llama-70B GQA: 2·80·8·128·2 = 327680 B/token.
        assert_eq!(c.by_name("llama-70b").unwrap().kv_bytes_per_token, 327_680);
    }

    #[test]
    fn weights_are_bf16_sized() {
        let c = ModelCatalog::paper();
        let m = c.by_name("llama-70b").unwrap();
        assert!((m.weight_gib - 130.4).abs() < 1.0, "{}", m.weight_gib);
    }

    #[test]
    fn multi_model_variants_share_architecture() {
        let c = ModelCatalog::paper_multi_model();
        assert_eq!(c.models.len(), 7);
        let base = c.by_name("mistral-7b").unwrap();
        let ft = c.by_name("mistral-7b-ft-b1").unwrap();
        assert_eq!(base.kv_bytes_per_token, ft.kv_bytes_per_token);
        assert_ne!(base.id, ft.id);
    }

    #[test]
    fn lookup_by_id_and_name_agree() {
        let c = ModelCatalog::paper();
        for m in &c.models {
            assert_eq!(c.get(m.id).name, m.name);
            assert_eq!(c.by_name(&m.name).unwrap().id, m.id);
        }
    }
}
