//! A vLLM-like LLM serving instance: continuous batching with iteration-
//! level scheduling (§2.1), a paged KV cache, internal preemption when the
//! cache overflows, LSO-initiated request eviction (§5), and model
//! swapping. Timing comes from [`PerfModel`] — the simulated analogue of a
//! profiled real instance (DESIGN.md §Substitutions).
//!
//! The iteration model is token-granular: prefill advances in *chunks*
//! (bounded per iteration by `chunk_tokens`, so a mega prompt no longer
//! stalls the whole batch for its full prefill — the sliding-window
//! chunking of arXiv 2606.05933), and decode is accounted in fixed-length
//! *slices* (`slice_tokens`; slice boundaries are the preemption points
//! slice-level scheduling, arXiv 2406.13511, migrates requests at). With
//! both knobs unset the step degenerates to whole-prompt prefill plus
//! one-token decode — the classic continuous-batching iteration.
//!
//! All methods take `now` explicitly: the discrete-event simulator owns
//! the clock, and the real PJRT-backed engine (`runtime::engine`) reuses
//! the same batching logic with wall-clock timing.

use std::collections::HashMap;

use crate::backend::kv_cache::KvError;
use crate::backend::{GpuKind, KvCache, ModelCatalog, ModelId, ModelRegistry, PerfModel};

/// Identifier of a serving instance (one per virtual queue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstanceId(pub u32);

/// Static configuration of one instance.
#[derive(Debug, Clone)]
pub struct InstanceConfig {
    pub id: InstanceId,
    pub gpu: GpuKind,
    /// CPU memory for warm models, GiB (§8.3 overhead discussion).
    pub cpu_model_mem_gib: f64,
    /// CPU swap space for evicted KV, in tokens.
    pub cpu_kv_tokens: u64,
    /// Mean prompt length used for profiling (workload profiling, §6).
    pub mean_prompt_tokens: f64,
}

impl InstanceConfig {
    pub fn new(id: u32, gpu: GpuKind) -> Self {
        InstanceConfig {
            id: InstanceId(id),
            gpu,
            cpu_model_mem_gib: 320.0,
            cpu_kv_tokens: 2_000_000,
            mean_prompt_tokens: crate::backend::perf::PROFILE_MEAN_PROMPT_TOKENS,
        }
    }
}

/// A sequence admitted to the instance.
#[derive(Debug, Clone)]
pub struct RunningSeq {
    pub req_id: u64,
    pub model: ModelId,
    pub prompt_tokens: u32,
    /// Ground-truth output length (simulator-only knowledge).
    pub target_output: u32,
    pub generated: u32,
    pub first_token_at: Option<f64>,
    pub arrival_s: f64,
    /// Prompt tokens prefilled so far (chunked-prefill progress). Decode
    /// is gated on `prefilled >= prompt_tokens`.
    pub prefilled: u32,
    /// Decode tokens left in the current slice; 0 when slicing is off.
    pub slice_left: u32,
}

impl RunningSeq {
    pub fn remaining(&self) -> u32 {
        self.target_output.saturating_sub(self.generated)
    }

    /// True once the whole prompt has been prefilled (or recomputed) and
    /// the sequence is in its decode phase.
    pub fn prefill_done(&self) -> bool {
        self.generated > 0 || self.prefilled >= self.prompt_tokens
    }
}

/// Result of one continuous-batching iteration.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Simulated duration of this iteration.
    pub dt: f64,
    /// Sequences that emitted their final token this iteration.
    pub completed: Vec<RunningSeq>,
    /// (req_id, t) pairs whose first token was produced this iteration.
    pub first_tokens: Vec<(u64, f64)>,
    /// Sequences internally preempted to CPU swap this iteration.
    pub preempted: u64,
    /// Decode tokens produced this iteration, per sequence. Sequences
    /// that only advanced prefill are not listed.
    pub produced: Vec<(u64, u32)>,
    /// Sequences whose decode slice expired this iteration — the
    /// migration points the load balancer may move a request at.
    pub slice_expired: Vec<u64>,
    /// (req_id, tokens) prefill installments advanced this iteration.
    /// Populated only when the owner enabled chunk tracing
    /// ([`Instance::set_trace_chunks`]) — empty, allocation-free
    /// otherwise.
    pub prefill_chunks: Vec<(u64, u32)>,
}

/// Why an admission attempt was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// KV cache cannot hold the prompt right now (HOL blocking).
    NoCapacity,
    /// Instance serves a different model; a swap LSO is needed first.
    WrongModel,
    /// Running batch at max_num_seqs.
    BatchFull,
    /// Instance is mid-swap.
    Busy,
}

/// Counters exported to the metrics layer.
#[derive(Debug, Clone, Default)]
pub struct InstanceStats {
    pub tokens_generated: u64,
    pub requests_completed: u64,
    pub internal_preemptions: u64,
    pub lso_evictions: u64,
    pub kv_bytes_evicted: u64,
    pub busy_s: f64,
    pub idle_s: f64,
    pub swap_s: f64,
    /// Integral of batch size over busy time (for mean-batch metrics).
    pub batch_time_integral: f64,
}

/// One LLM serving instance (Def. 2.3: serving system + loaded model).
#[derive(Debug)]
pub struct Instance {
    pub config: InstanceConfig,
    registry: ModelRegistry,
    perf_cache: HashMap<ModelId, PerfModel>,
    kv: KvCache,
    running: Vec<RunningSeq>,
    /// Internally preempted sequences (KV parked in CPU swap), resumed
    /// LIFO when space frees — mirrors vLLM's recompute/swap policy.
    swapped: Vec<RunningSeq>,
    /// Time until which the instance is occupied by a model swap.
    busy_until: f64,
    pub stats: InstanceStats,
    last_step_end: f64,
    /// Per-iteration prefill token budget shared by the batch; `None`
    /// means whole prompts prefill in one iteration.
    chunk_tokens: Option<u32>,
    /// Decode slice length; slice boundaries are migration points.
    slice_tokens: Option<u32>,
    /// Report per-iteration prefill installments in [`StepOutcome`]
    /// (flight-recorder support). Off by default: tracing disabled must
    /// not change what `step` computes or allocates.
    trace_chunks: bool,
}

impl Instance {
    pub fn new(config: InstanceConfig, catalog: ModelCatalog) -> Self {
        let registry = ModelRegistry::new(catalog, config.cpu_model_mem_gib);
        Instance {
            kv: KvCache::new(0, config.cpu_kv_tokens),
            config,
            registry,
            perf_cache: HashMap::new(),
            running: Vec::new(),
            swapped: Vec::new(),
            busy_until: 0.0,
            stats: InstanceStats::default(),
            last_step_end: 0.0,
            chunk_tokens: None,
            slice_tokens: None,
            trace_chunks: false,
        }
    }

    /// Configure the token-granular knobs: per-iteration prefill chunk
    /// budget and decode slice length. `None` disables the respective
    /// behavior. Applies to subsequent admissions/iterations.
    pub fn set_token_knobs(&mut self, chunk_tokens: Option<u32>, slice_tokens: Option<u32>) {
        self.chunk_tokens = chunk_tokens;
        self.slice_tokens = slice_tokens;
    }

    /// Override just the prefill chunk budget (the sliding-window chunk
    /// controller adjusts this between iterations).
    pub fn set_chunk_tokens(&mut self, chunk_tokens: Option<u32>) {
        self.chunk_tokens = chunk_tokens;
    }

    pub fn chunk_tokens(&self) -> Option<u32> {
        self.chunk_tokens
    }

    pub fn slice_tokens(&self) -> Option<u32> {
        self.slice_tokens
    }

    /// Enable/disable reporting of per-iteration prefill installments
    /// in [`StepOutcome::prefill_chunks`] (the flight recorder turns
    /// this on; everything else leaves it off).
    pub fn set_trace_chunks(&mut self, on: bool) {
        self.trace_chunks = on;
    }

    /// Profiled constants for `model` on this instance's GPU (cached —
    /// profiling is a one-time cost per combination, §6).
    pub fn perf(&mut self, model: ModelId) -> PerfModel {
        let gpu = self.config.gpu;
        let prompt = self.config.mean_prompt_tokens;
        let catalog = self.registry.catalog();
        *self
            .perf_cache
            .entry(model)
            .or_insert_with(|| PerfModel::profile(catalog.get(model), gpu, prompt))
    }

    /// Read-only perf lookup (panics if not yet profiled).
    pub fn perf_cached(&self, model: ModelId) -> &PerfModel {
        &self.perf_cache[&model]
    }

    pub fn active_model(&self) -> Option<ModelId> {
        self.registry.active()
    }

    pub fn registry_mut(&mut self) -> &mut ModelRegistry {
        &mut self.registry
    }

    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn swapped_len(&self) -> usize {
        self.swapped.len()
    }

    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    pub fn is_swapping(&self, now: f64) -> bool {
        now < self.busy_until
    }

    /// True if the instance has no work at all.
    pub fn is_idle(&self) -> bool {
        self.running.is_empty() && self.swapped.is_empty()
    }

    /// Total tokens (prompt + generated so far) of the running batch.
    pub fn resident_tokens(&self) -> u64 {
        self.kv.gpu_tokens()
    }

    /// Spare KV capacity (tokens) available for admission, after holding
    /// back a 5% watermark for decode growth of the running batch
    /// (vLLM-style headroom to limit preemption thrash).
    pub fn spare_tokens(&self) -> u64 {
        let free = self.kv.free_blocks() as u64 * crate::backend::kv_cache::BLOCK_TOKENS as u64;
        let reserve = (self.kv.total_blocks() as u64
            * crate::backend::kv_cache::BLOCK_TOKENS as u64)
            / 20;
        free.saturating_sub(reserve)
    }

    /// Free running-batch slots under max_num_seqs.
    pub fn batch_slots_free(&self) -> u32 {
        match self.registry.active() {
            Some(m) => {
                let max = self.perf_cache.get(&m).map(|p| p.max_batch).unwrap_or(256);
                max.saturating_sub(self.running.len() as u32)
            }
            None => 0,
        }
    }

    /// Swap the active model (Model Swapping LSO, §5). Flushes the KV
    /// cache; all running/preempted sequences are returned so the caller
    /// (QLM agent) re-enqueues them in the global queue. Returns
    /// (ready_at, displaced sequences).
    pub fn swap_model(&mut self, model: ModelId, now: f64) -> (f64, Vec<RunningSeq>) {
        if self.registry.active() == Some(model) {
            return (now, Vec::new());
        }
        let perf = self.perf(model);
        let swap_s = self.registry.swap_in_time_s(model, &perf);
        self.registry.swap_to_gpu(model, &perf);
        let mut displaced: Vec<RunningSeq> = self.running.drain(..).collect();
        displaced.extend(self.swapped.drain(..));
        // New KV geometry for the new model.
        self.kv = KvCache::new(perf.token_capacity, self.config.cpu_kv_tokens);
        self.busy_until = now + swap_s;
        self.stats.swap_s += swap_s;
        (self.busy_until, displaced)
    }

    /// Pull one request into the running batch (Request Pulling LSO, §5).
    /// KV for the prompt is allocated; prefill is charged in the next
    /// `step`. `kv_restore_tokens` > 0 marks a previously evicted request
    /// whose KV is being restored instead of recomputed.
    pub fn try_admit(
        &mut self,
        mut seq: RunningSeq,
        now: f64,
    ) -> Result<(), (RunningSeq, AdmitError)> {
        if self.is_swapping(now) {
            return Err((seq, AdmitError::Busy));
        }
        let Some(active) = self.registry.active() else {
            return Err((seq, AdmitError::WrongModel));
        };
        if active != seq.model {
            return Err((seq, AdmitError::WrongModel));
        }
        let perf = self.perf(active);
        if self.running.len() as u32 >= perf.max_batch {
            return Err((seq, AdmitError::BatchFull));
        }
        let tokens = seq.prompt_tokens as u64 + seq.generated as u64;
        match self.kv.alloc_seq(seq.req_id, tokens) {
            Ok(()) => {
                if seq.generated > 0 || seq.first_token_at.is_some() {
                    // Previously evicted sequence: its prompt KV is
                    // recomputed off the inference path (§5), so it
                    // re-enters fully prefilled.
                    seq.prefilled = seq.prompt_tokens;
                }
                if let Some(s) = self.slice_tokens {
                    seq.slice_left = s.max(1);
                }
                self.running.push(seq);
                Ok(())
            }
            Err(_) => Err((seq, AdmitError::NoCapacity)),
        }
    }

    /// Evict specific requests back to the global queue (Request Eviction
    /// LSO, §5). KV is migrated to CPU asynchronously (the paper hides the
    /// copy with async transfers, so no time is charged on the inference
    /// path); the evicted sequences are returned for re-queueing.
    pub fn evict(&mut self, req_ids: &[u64], _now: f64) -> Vec<RunningSeq> {
        let mut out = Vec::new();
        let kv_bytes = self
            .registry
            .active()
            .map(|m| self.registry.catalog().get(m).kv_bytes_per_token)
            .unwrap_or(0);
        let mut i = 0;
        while i < self.running.len() {
            if req_ids.contains(&self.running[i].req_id) {
                let seq = self.running.swap_remove(i);
                let moved = self
                    .kv
                    .evict_to_cpu(seq.req_id)
                    .unwrap_or_else(|_| self.kv.free_seq(seq.req_id).unwrap_or(0));
                self.stats.lso_evictions += 1;
                self.stats.kv_bytes_evicted += moved * kv_bytes;
                out.push(seq);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Evict everything (used when the global scheduler replaces the head
    /// request group wholesale).
    pub fn evict_all(&mut self, now: f64) -> Vec<RunningSeq> {
        let ids: Vec<u64> = self.running.iter().map(|s| s.req_id).collect();
        self.evict(&ids, now)
    }

    /// Hard instance failure (§4 Fault Isolation): the device and its
    /// CPU swap space are gone. Every running and internally-preempted
    /// sequence is returned so the coordinator can revert it to Waiting
    /// in the global queue; the caller must stop scheduling onto this
    /// instance afterwards.
    pub fn fail(&mut self) -> Vec<RunningSeq> {
        let mut lost: Vec<RunningSeq> = self.running.drain(..).collect();
        lost.extend(self.swapped.drain(..));
        self.kv.flush();
        lost
    }

    /// Restore an evicted sequence whose KV is still in this instance's
    /// CPU swap (cheap re-admission after eviction).
    pub fn try_restore(
        &mut self,
        mut seq: RunningSeq,
        now: f64,
    ) -> Result<(), (RunningSeq, AdmitError)> {
        if self.kv.cpu_resident(seq.req_id).is_some() {
            if self.is_swapping(now) {
                return Err((seq, AdmitError::Busy));
            }
            match self.kv.restore_from_cpu(seq.req_id) {
                Ok(_) => {
                    // Parked KV covers the full prompt — no re-prefill.
                    seq.prefilled = seq.prompt_tokens;
                    if let Some(s) = self.slice_tokens {
                        seq.slice_left = s.max(1);
                    }
                    self.running.push(seq);
                    Ok(())
                }
                Err(_) => Err((seq, AdmitError::NoCapacity)),
            }
        } else {
            self.try_admit(seq, now)
        }
    }

    /// One continuous-batching iteration: resume preempted sequences if
    /// space allows, advance prefill chunks under the shared per-iteration
    /// token budget (shortest remaining prefill first), generate one token
    /// for every fully-prefilled sequence, preempt on KV overflow, account
    /// decode slices, and retire finished sequences.
    pub fn step(&mut self, now: f64) -> StepOutcome {
        let mut out = StepOutcome::default();
        if self.is_swapping(now) {
            // Swap in flight: the instance is blocked until busy_until.
            out.dt = self.busy_until - now;
            return out;
        }
        let Some(active) = self.registry.active() else {
            return out;
        };
        let perf = self.perf(active);

        // 1. Resume internally preempted sequences (LIFO) while space allows.
        while let Some(seq) = self.swapped.pop() {
            if (self.running.len() as u32) < perf.max_batch
                && self.kv.restore_from_cpu(seq.req_id).is_ok()
            {
                self.running.push(seq);
            } else {
                self.swapped.push(seq);
                break;
            }
        }

        if self.running.is_empty() {
            return out;
        }

        // 2. Chunked prefill: advance un-prefilled sequences under the
        //    shared per-iteration token budget, shortest remaining prefill
        //    first (ties by admission order) — a mega prompt mid-prefill
        //    must not starve a short urgent prompt of the budget; letting
        //    short prefills overtake long ones is the point of chunking.
        //    Prefill is compute-bound, so cost is additive per chunk (each
        //    chunk pays the per-iteration overhead once).
        let mut budget = self.chunk_tokens.unwrap_or(u32::MAX).max(1);
        let mut prefill_s = 0.0;
        let mut chunk_cost: HashMap<u64, f64> = HashMap::new();
        let mut needy: Vec<usize> = (0..self.running.len())
            .filter(|&i| !self.running[i].prefill_done())
            .collect();
        needy.sort_by_key(|&i| {
            let s = &self.running[i];
            (s.prompt_tokens - s.prefilled, i)
        });
        for i in needy {
            if budget == 0 {
                break;
            }
            let seq = &mut self.running[i];
            let adv = budget.min(seq.prompt_tokens - seq.prefilled);
            seq.prefilled += adv;
            budget -= adv;
            let cost = perf.prefill_cost(adv);
            prefill_s += cost;
            chunk_cost.insert(seq.req_id, cost);
            if self.trace_chunks && adv > 0 {
                out.prefill_chunks.push((seq.req_id, adv));
            }
        }

        // Decode time is charged only when at least one sequence is past
        // its prefill (a batch of pure mid-prefill chunks emits no token).
        let decode_s = if self.running.iter().any(|s| s.prefill_done()) {
            perf.step_time(self.kv.gpu_tokens())
        } else {
            0.0
        };
        let dt = prefill_s + decode_s;
        let t_end = now + dt;

        // 3. Decode one token per fully-prefilled sequence; allocate KV
        //    growth, preempting the most recently admitted sequences on
        //    overflow (vLLM preempts the newest to guarantee progress of
        //    the oldest).
        let mut idx = 0;
        while idx < self.running.len() {
            if !self.running[idx].prefill_done() {
                idx += 1;
                continue;
            }
            let req_id = self.running[idx].req_id;
            match self.kv.append_token(req_id) {
                Ok(()) => idx += 1,
                Err(KvError::OutOfBlocks) => {
                    // Preempt the last sequence (not the one making progress
                    // unless it is the only one).
                    let victim_idx = if self.running.len() > 1 && idx < self.running.len() - 1 {
                        self.running.len() - 1
                    } else {
                        idx
                    };
                    let victim = self.running.swap_remove(victim_idx);
                    match self.kv.evict_to_cpu(victim.req_id) {
                        Ok(_) => {
                            self.swapped.push(victim);
                            out.preempted += 1;
                            self.stats.internal_preemptions += 1;
                        }
                        Err(_) => {
                            // CPU swap full: drop KV; the sequence will
                            // recompute its prefix when resumed.
                            let _ = self.kv.free_seq(victim.req_id);
                            self.swapped.push(victim);
                            out.preempted += 1;
                            self.stats.internal_preemptions += 1;
                        }
                    }
                    if victim_idx == idx {
                        // The current sequence was the victim; don't advance.
                        continue;
                    }
                }
                Err(_) => unreachable!("running seq must be allocated"),
            }
        }

        // 4. Account generation, slices, and completions. Prefill chunks
        // within one iteration are staggered: a prompt finishing its
        // prefill gets its first token after the cumulative chunk time of
        // the prompts before it.
        let mut i = 0;
        let mut cum_prefill = 0.0;
        while i < self.running.len() {
            let seq = &mut self.running[i];
            if let Some(&c) = chunk_cost.get(&seq.req_id) {
                cum_prefill += c;
            }
            if !seq.prefill_done() {
                i += 1;
                continue;
            }
            seq.generated += 1;
            self.stats.tokens_generated += 1;
            out.produced.push((seq.req_id, 1));
            if seq.first_token_at.is_none() {
                let t = now + cum_prefill;
                seq.first_token_at = Some(t);
                out.first_tokens.push((seq.req_id, t));
            }
            if let Some(s) = self.slice_tokens {
                if seq.slice_left > 0 {
                    seq.slice_left -= 1;
                }
                if seq.slice_left == 0 && seq.generated < seq.target_output {
                    out.slice_expired.push(seq.req_id);
                    seq.slice_left = s.max(1);
                }
            }
            if seq.generated >= seq.target_output {
                let done = self.running.swap_remove(i);
                let _ = self.kv.free_seq(done.req_id);
                self.stats.requests_completed += 1;
                out.completed.push(done);
            } else {
                i += 1;
            }
        }

        self.stats.busy_s += dt;
        self.stats.batch_time_integral += dt * (self.running.len() + out.completed.len()) as f64;
        if now > self.last_step_end {
            self.stats.idle_s += now - self.last_step_end;
        }
        self.last_step_end = t_end;
        out.dt = dt;
        out
    }

    /// Observed token throughput Θ over the instance lifetime.
    pub fn observed_throughput(&self) -> f64 {
        if self.stats.busy_s == 0.0 {
            0.0
        } else {
            self.stats.tokens_generated as f64 / self.stats.busy_s
        }
    }

    /// Mean running batch size over busy time.
    pub fn mean_batch(&self) -> f64 {
        if self.stats.busy_s == 0.0 {
            0.0
        } else {
            self.stats.batch_time_integral / self.stats.busy_s
        }
    }

    /// Device utilization = busy / (busy + idle).
    pub fn utilization(&self) -> f64 {
        let t = self.stats.busy_s + self.stats.idle_s + self.stats.swap_s;
        if t == 0.0 {
            0.0
        } else {
            self.stats.busy_s / t
        }
    }

    /// Ids of currently running requests (for LSO decisions).
    pub fn running_req_ids(&self) -> Vec<u64> {
        self.running.iter().map(|s| s.req_id).collect()
    }

    /// Running sequences view.
    pub fn running(&self) -> &[RunningSeq] {
        &self.running
    }

    /// Internally preempted sequences parked in CPU swap. These are
    /// still Running from the broker's point of view — metrics and
    /// horizon accounting must include them alongside `running()`.
    pub fn swapped(&self) -> &[RunningSeq] {
        &self.swapped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_seq(id: u64, prompt: u32, output: u32) -> RunningSeq {
        RunningSeq {
            req_id: id,
            model: ModelId(0),
            prompt_tokens: prompt,
            target_output: output,
            generated: 0,
            first_token_at: None,
            arrival_s: 0.0,
            prefilled: 0,
            slice_left: 0,
        }
    }

    fn mk_instance() -> Instance {
        let mut inst = Instance::new(InstanceConfig::new(0, GpuKind::A100), ModelCatalog::paper());
        inst.swap_model(ModelId(0), 0.0);
        inst
    }

    #[test]
    fn admit_requires_matching_model() {
        let mut inst = mk_instance();
        let mut seq = mk_seq(1, 100, 10);
        seq.model = ModelId(1);
        let err = inst.try_admit(seq, 100.0).unwrap_err().1;
        assert_eq!(err, AdmitError::WrongModel);
    }

    #[test]
    fn admit_during_swap_refused() {
        let mut inst = mk_instance();
        // swap_model(…, 0.0) leaves busy_until > 0 (storage→gpu cost).
        assert!(inst.is_swapping(0.0));
        let err = inst.try_admit(mk_seq(1, 100, 10), 0.0).unwrap_err().1;
        assert_eq!(err, AdmitError::Busy);
    }

    #[test]
    fn request_runs_to_completion_with_ttft() {
        let mut inst = mk_instance();
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 100, 5), t0).unwrap();
        let mut now = t0;
        let mut completed = Vec::new();
        let mut first = None;
        for _ in 0..10 {
            let out = inst.step(now);
            now += out.dt;
            if let Some(&(_, t)) = out.first_tokens.first() {
                first = Some(t);
            }
            completed.extend(out.completed);
            if !completed.is_empty() {
                break;
            }
        }
        assert_eq!(completed.len(), 1);
        assert_eq!(completed[0].generated, 5);
        let perf = *inst.perf_cached(ModelId(0));
        // First token lands after one token-accurate prefill.
        assert!((first.unwrap() - (t0 + perf.prefill_cost(100))).abs() < 1e-9);
        assert_eq!(inst.stats.requests_completed, 1);
        assert_eq!(inst.resident_tokens(), 0, "KV freed at completion");
    }

    #[test]
    fn continuous_batching_joins_mid_flight() {
        let mut inst = mk_instance();
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 50, 100), t0).unwrap();
        let out = inst.step(t0);
        let now = t0 + out.dt;
        // Second request joins while the first is decoding.
        inst.try_admit(mk_seq(2, 50, 3), now).unwrap();
        assert_eq!(inst.running_len(), 2);
        let resident = inst.resident_tokens();
        let out2 = inst.step(now);
        // Step with one new prefill costs prefill + decode (incl. KV read).
        let perf = *inst.perf_cached(ModelId(0));
        assert!((out2.dt - (perf.prefill_cost(50) + perf.step_time(resident))).abs() < 1e-9);
    }

    #[test]
    fn chunked_prefill_spreads_over_iterations() {
        let mut inst = mk_instance();
        inst.set_token_knobs(Some(256), None);
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 600, 5), t0).unwrap();
        let perf = *inst.perf_cached(ModelId(0));

        // Iteration 1: chunk of 256, no token emitted yet.
        let o1 = inst.step(t0);
        assert!(o1.first_tokens.is_empty());
        assert!(o1.produced.is_empty());
        assert!((o1.dt - perf.prefill_cost(256)).abs() < 1e-9);
        // Iteration 2: second chunk of 256.
        let o2 = inst.step(t0 + o1.dt);
        assert!(o2.first_tokens.is_empty());
        // Iteration 3: final 88-token chunk plus the first decode token.
        let now3 = t0 + o1.dt + o2.dt;
        let o3 = inst.step(now3);
        assert_eq!(o3.produced, vec![(1, 1)]);
        let (_, first) = o3.first_tokens[0];
        assert!((first - (now3 + perf.prefill_cost(88))).abs() < 1e-9);
        assert!((o3.dt - (perf.prefill_cost(88) + perf.step_time(600))).abs() < 1e-9);
    }

    #[test]
    fn chunk_budget_ties_break_by_admission_order() {
        let mut inst = mk_instance();
        inst.set_token_knobs(Some(100), None);
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 80, 5), t0).unwrap();
        inst.try_admit(mk_seq(2, 80, 5), t0).unwrap();
        // Equal remaining prefill → admission order: seq 1 prefills all
        // 80 of the 100-token budget, seq 2 only 20.
        let o1 = inst.step(t0);
        assert_eq!(o1.first_tokens.len(), 1);
        assert_eq!(o1.first_tokens[0].0, 1);
        // Next iteration finishes seq 2's prefill.
        let o2 = inst.step(t0 + o1.dt);
        assert!(o2.first_tokens.iter().any(|&(id, _)| id == 2));
    }

    #[test]
    fn short_prefill_overtakes_resident_mega_within_budget() {
        let mut inst = mk_instance();
        inst.set_token_knobs(Some(256), None);
        let t0 = inst.busy_until();
        // A mega prompt is admitted first and starts chunking.
        inst.try_admit(mk_seq(1, 600, 5), t0).unwrap();
        let o1 = inst.step(t0);
        let now = t0 + o1.dt;
        // A short prompt joins mid-prefill. Shortest-remaining-first
        // budget order means it prefills fully THIS iteration and emits
        // its first token while the mega is still chunking — the mega
        // cannot starve it of the shared budget.
        inst.try_admit(mk_seq(2, 100, 5), now).unwrap();
        let o2 = inst.step(now);
        assert!(o2.first_tokens.iter().any(|&(id, _)| id == 2));
        assert!(o2.produced.contains(&(2, 1)));
        assert!(o2.first_tokens.iter().all(|&(id, _)| id != 1));
    }

    #[test]
    fn decode_slices_expire_and_reset() {
        let mut inst = mk_instance();
        inst.set_token_knobs(None, Some(2));
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 10, 10), t0).unwrap();
        let mut now = t0;
        let mut expiries = 0;
        let mut completed = false;
        for _ in 0..20 {
            let out = inst.step(now);
            now += out.dt;
            expiries += out.slice_expired.len();
            if !out.completed.is_empty() {
                // The final token must not also report a slice expiry.
                assert!(out.slice_expired.is_empty());
                completed = true;
                break;
            }
        }
        assert!(completed);
        // 10 decode tokens at slice length 2: boundaries after tokens
        // 2, 4, 6, 8 (the 10th is completion, not a migration point).
        assert_eq!(expiries, 4);
    }

    #[test]
    fn eviction_returns_seqs_and_frees_kv() {
        let mut inst = mk_instance();
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 100, 50), t0).unwrap();
        inst.try_admit(mk_seq(2, 100, 50), t0).unwrap();
        let before = inst.resident_tokens();
        assert!(before > 0);
        let evicted = inst.evict(&[1], t0);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].req_id, 1);
        assert_eq!(inst.running_len(), 1);
        assert!(inst.resident_tokens() < before);
        assert_eq!(inst.stats.lso_evictions, 1);
    }

    #[test]
    fn evicted_seq_restores_without_reprefill() {
        let mut inst = mk_instance();
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 100, 50), t0).unwrap();
        // Generate a few tokens first.
        let mut now = t0;
        for _ in 0..3 {
            now += inst.step(now).dt;
        }
        let mut evicted = inst.evict(&[1], now);
        let seq = evicted.pop().unwrap();
        assert_eq!(seq.generated, 3);
        inst.try_restore(seq, now).unwrap();
        assert_eq!(inst.running_len(), 1);
        // KV restored including generated tokens: 100 + 3.
        assert_eq!(inst.resident_tokens(), 103);
    }

    #[test]
    fn swap_model_displaces_running() {
        let mut inst = mk_instance();
        let t0 = inst.busy_until();
        inst.try_admit(mk_seq(1, 100, 50), t0).unwrap();
        let (ready_at, displaced) = inst.swap_model(ModelId(1), t0);
        assert_eq!(displaced.len(), 1);
        assert!(ready_at > t0);
        assert_eq!(inst.active_model(), Some(ModelId(1)));
        assert_eq!(inst.running_len(), 0);
    }

    #[test]
    fn preemption_on_kv_overflow() {
        // Tiny KV: force overflow during decode.
        let mut inst = Instance::new(InstanceConfig::new(0, GpuKind::A100), ModelCatalog::paper());
        inst.swap_model(ModelId(0), 0.0);
        let t0 = inst.busy_until();
        // Shrink the cache artificially by filling with big prompts near
        // capacity: compute capacity and admit prompts to fill ~100%.
        let perf = inst.perf(ModelId(0));
        let cap = perf.token_capacity;
        let n = 4u64;
        // Leave a small margin so all prompts admit (block rounding), but
        // little enough that decode growth overflows within a few steps.
        let per = cap / n - 64;
        for id in 0..n {
            inst.try_admit(mk_seq(id, per as u32, 1000), t0).unwrap();
        }
        let mut now = t0;
        let mut preempted = 0;
        for _ in 0..200 {
            let out = inst.step(now);
            now += out.dt;
            preempted += out.preempted;
        }
        assert!(preempted > 0, "expected KV-overflow preemption");
        // Everyone still alive somewhere (running or swapped).
        assert_eq!(inst.running_len() + inst.swapped_len(), n as usize);
    }

    #[test]
    fn throughput_and_batch_accounting() {
        let mut inst = mk_instance();
        let t0 = inst.busy_until();
        for id in 0..8 {
            inst.try_admit(mk_seq(id, 50, 20), t0).unwrap();
        }
        let mut now = t0;
        while !inst.is_idle() {
            let out = inst.step(now);
            now += out.dt;
            if out.dt == 0.0 {
                break;
            }
        }
        assert_eq!(inst.stats.requests_completed, 8);
        assert_eq!(inst.stats.tokens_generated, 8 * 20);
        assert!(inst.observed_throughput() > 0.0);
        assert!(inst.mean_batch() > 1.0);
    }
}
