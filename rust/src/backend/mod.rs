//! The serving-instance substrate: everything below QLM's coordinator.
//!
//! The paper runs vLLM on NVIDIA A10/A100 GPUs; we rebuild the pieces QLM
//! interacts with — a continuous-batching engine with a paged KV cache,
//! request preemption/eviction, and two-tier model swapping — with an
//! analytic timing model calibrated per (model, GPU) exactly the way QLM's
//! offline profiling step (§6) characterizes real instances.

pub mod gpu;
pub mod model;
pub mod perf;
pub mod kv_cache;
pub mod instance;
pub mod model_registry;

pub use gpu::{GpuKind, GpuSpec};
pub use instance::{Instance, InstanceConfig, InstanceId, RunningSeq, StepOutcome};
pub use kv_cache::{BlockId, KvCache};
pub use model::{ModelCatalog, ModelId, ModelSpec};
pub use model_registry::{ModelRegistry, ModelTier};
pub use perf::PerfModel;
