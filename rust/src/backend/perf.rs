//! Analytic timing model for a serving instance — the substitute for the
//! paper's real-GPU profiling (DESIGN.md §Substitutions).
//!
//! QLM's RWT estimator consumes exactly the constants this module
//! produces for a (model, GPU, tp_degree) triple: prefill time `P`, decode
//! time per output token `d`, inefficiency factor `ε`, token generation
//! throughput `Θ`, and model swap time `S` (paper §6, Table 1; §7).
//!
//! First-order physics, matching published vLLM measurements within ~2×:
//! * decode step is weight-load bound: one iteration streams all weights
//!   from HBM once regardless of batch size (hence continuous batching);
//! * prefill is compute bound: 2·params·prompt_tokens FLOPs at a fraction
//!   of peak;
//! * swap is link bound: weights move over PCIe, parallel across the TP
//!   group members.

use crate::backend::{GpuKind, ModelSpec};

/// Fraction of GPU memory usable for KV after runtime overheads
/// (vLLM's gpu_memory_utilization default is 0.9).
pub const GPU_MEM_UTIL: f64 = 0.9;

/// Mean prompt length (tokens) the offline profiling step (§6) runs
/// with. Every consumer of a profiled [`PerfModel`] — the engine's
/// scheduler views, provisioning cold-start pricing, and the capacity
/// planner's what-if pricing — must profile at the same prompt length
/// or their Θ estimates silently diverge.
pub const PROFILE_MEAN_PROMPT_TOKENS: f64 = 161.0;

/// Achievable fraction of peak bf16 FLOPs during prefill.
const PREFILL_EFF: f64 = 0.45;

/// Achievable fraction of peak HBM bandwidth during decode.
const DECODE_BW_EFF: f64 = 0.75;

/// Storage → CPU staging bandwidth (GiB/s) for cold model loads.
const STORAGE_GIBS: f64 = 4.0;

/// Per-iteration fixed overhead (scheduler, kernel launch), seconds.
const STEP_OVERHEAD_S: f64 = 0.002;

/// Achievable fraction of PCIe bandwidth during a weight swap (allocation,
/// layout, and driver overheads halve the raw link rate in practice).
const SWAP_EFF: f64 = 0.5;

/// Profiled performance constants for one (model, GPU) combination — the
/// output of QLM's "Hardware Profiling" step (§6, Offline Profiling).
#[derive(Debug, Clone, Copy)]
pub struct PerfModel {
    pub gpu: GpuKind,
    /// Tensor-parallel degree (GPUs per instance).
    pub tp: u32,
    /// Weight-load-bound decode step floor, seconds (`d`).
    pub decode_s_per_token: f64,
    /// Incremental step cost per KV-resident token (attention reads the
    /// cache every iteration): seconds per resident token per step.
    pub kv_read_s_per_token: f64,
    /// Token throughput measured by hardware profiling (§6 Offline
    /// Profiling) — when set, the RWT estimator uses this instead of the
    /// analytic model.
    pub measured_theta: Option<f64>,
    /// Prefill time for a *mean-length* prompt, seconds (`P`). §6:
    /// prefill is near-constant per model for in-distribution prompt
    /// lengths, so the RWT estimator prices with this constant; the
    /// execution backend charges the token-accurate [`Self::prefill_cost`]
    /// so mega prompts actually block the batch they run in.
    pub prefill_s: f64,
    /// Compute-bound prefill slope, seconds per prompt token — the
    /// per-token cost a prefill chunk of any size is billed at.
    pub prefill_s_per_token: f64,
    /// Continuous-batching inefficiency factor (`ε` ≥ 1).
    pub epsilon: f64,
    /// Max tokens resident in the KV cache across the running batch.
    pub token_capacity: u64,
    /// Max concurrently running sequences (vLLM max_num_seqs analogue).
    pub max_batch: u32,
    /// CPU → GPU model swap time, seconds (`S`).
    pub swap_cpu_gpu_s: f64,
    /// Storage → CPU model staging time, seconds.
    pub swap_storage_cpu_s: f64,
    /// KV eviction bandwidth GPU→CPU, bytes/s.
    pub evict_bytes_per_s: f64,
}

impl PerfModel {
    /// Does `model` fit on a `tp_degree`-way group of `gpu` devices?
    pub fn fits(model: &ModelSpec, gpu: GpuKind) -> bool {
        let spec = gpu.spec();
        let tp = model.tp_degree.max(1);
        model.weight_gib < spec.mem_gib * tp as f64 * GPU_MEM_UTIL
    }

    /// Non-panicking profile.
    pub fn try_profile(
        model: &ModelSpec,
        gpu: GpuKind,
        mean_prompt_tokens: f64,
    ) -> Option<PerfModel> {
        if Self::fits(model, gpu) {
            Some(Self::profile(model, gpu, mean_prompt_tokens))
        } else {
            None
        }
    }

    /// Build the profile for `model` running on `tp`-way `gpu` devices.
    /// Panics if the weights do not fit in the TP group's memory.
    pub fn profile(model: &ModelSpec, gpu: GpuKind, mean_prompt_tokens: f64) -> PerfModel {
        let spec = gpu.spec();
        let tp = model.tp_degree.max(1);
        let total_mem_gib = spec.mem_gib * tp as f64 * GPU_MEM_UTIL;
        assert!(
            model.weight_gib < total_mem_gib,
            "{} ({:.0} GiB) does not fit on {}x{} ({:.0} GiB usable)",
            model.name,
            model.weight_gib,
            tp,
            gpu.name(),
            total_mem_gib
        );

        // Decode: stream weights once per step across the TP group, plus
        // read the resident KV cache (charged per token in step()).
        let bw = spec.hbm_gibs * tp as f64 * DECODE_BW_EFF;
        let decode_s = model.weight_gib / bw + STEP_OVERHEAD_S;
        let kv_read_s_per_token =
            model.kv_bytes_per_token as f64 / (bw * 1024.0 * 1024.0 * 1024.0);

        // Prefill: compute-bound, linear in prompt tokens.
        let prefill_s_per_token =
            2.0 * model.params_b * 1e9 / (spec.bf16_tflops * 1e12 * tp as f64 * PREFILL_EFF);
        let prefill_s = prefill_s_per_token * mean_prompt_tokens + STEP_OVERHEAD_S;

        // KV capacity from leftover memory.
        let kv_mem_bytes = ((total_mem_gib - model.weight_gib) * 1024.0 * 1024.0 * 1024.0)
            .max(0.0) as u64;
        let token_capacity = kv_mem_bytes / model.kv_bytes_per_token;

        // Swap times: PCIe transfers parallel across TP members.
        let link = spec.pcie_gibs * tp as f64 * SWAP_EFF;
        let swap_cpu_gpu_s = model.weight_gib / link;
        let swap_storage_cpu_s = model.weight_gib / STORAGE_GIBS;

        PerfModel {
            gpu,
            tp,
            decode_s_per_token: decode_s,
            kv_read_s_per_token,
            measured_theta: None,
            prefill_s,
            prefill_s_per_token,
            epsilon: 1.15,
            token_capacity,
            max_batch: 256,
            swap_cpu_gpu_s,
            swap_storage_cpu_s,
            evict_bytes_per_s: spec.pcie_gibs * 1024.0 * 1024.0 * 1024.0,
        }
    }

    /// Decode-step latency at `resident_tokens` of live KV.
    pub fn step_time(&self, resident_tokens: u64) -> f64 {
        (self.decode_s_per_token + resident_tokens as f64 * self.kv_read_s_per_token)
            * self.epsilon
    }

    /// Token-accurate prefill cost for `tokens` prompt tokens processed
    /// as one contiguous chunk (chunked-prefill step cost): the
    /// compute-bound slope plus the per-iteration admission overhead.
    /// `prefill_cost(mean_prompt)` ≡ `prefill_s`, so the whole-request
    /// path is the single-chunk special case.
    pub fn prefill_cost(&self, tokens: u32) -> f64 {
        self.prefill_s_per_token * tokens as f64 + STEP_OVERHEAD_S
    }

    /// Token generation throughput Θ (tokens/s) at running batch size `b`
    /// with `mean_tokens_per_req` resident per request — Appendix A.1,
    /// Eq. 15: Θ = B / (δ · ε), with δ including the KV-read term.
    pub fn throughput_at(&self, b: u32, mean_tokens_per_req: f64) -> f64 {
        let b = b.min(self.max_batch) as f64;
        b / self.step_time((b * mean_tokens_per_req) as u64)
    }

    /// Θ = B / (δ·ε) at full weight-load-bound batching (Eq. 15 with the
    /// original constant-δ reading).
    pub fn throughput(&self, b: u32) -> f64 {
        b.min(self.max_batch) as f64 / (self.decode_s_per_token * self.epsilon)
    }

    /// Θ at the steady-state batch size implied by the token capacity and
    /// a mean per-request footprint — Appendix A.1, Eq. 16. Prefers the
    /// hardware-profiled measurement when available (§6).
    pub fn steady_throughput(&self, mean_tokens_per_req: f64) -> f64 {
        if let Some(t) = self.measured_theta {
            return t;
        }
        let b = (self.token_capacity as f64 / mean_tokens_per_req)
            .min(self.max_batch as f64)
            .max(1.0);
        self.throughput_at(b as u32, mean_tokens_per_req)
    }

    /// Steady-state batch size for a mean per-request token footprint.
    pub fn steady_batch(&self, mean_tokens_per_req: f64) -> u32 {
        (self.token_capacity as f64 / mean_tokens_per_req)
            .min(self.max_batch as f64)
            .max(1.0) as u32
    }

    /// Time to evict `tokens` of KV to CPU memory (GPU→CPU copy).
    pub fn evict_time_s(&self, tokens: u64, kv_bytes_per_token: u64) -> f64 {
        (tokens * kv_bytes_per_token) as f64 / self.evict_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ModelCatalog;

    fn profiles() -> Vec<PerfModel> {
        let c = ModelCatalog::paper();
        c.models
            .iter()
            .map(|m| PerfModel::profile(m, GpuKind::A100, 161.0))
            .collect()
    }

    #[test]
    fn decode_times_plausible() {
        let ps = profiles();
        // Mistral-7B on A100: ~10-15 ms/step; Llama-70B TP4: ~25-35 ms.
        assert!(ps[0].decode_s_per_token < 0.02, "{}", ps[0].decode_s_per_token);
        assert!(ps[2].decode_s_per_token < 0.05, "{}", ps[2].decode_s_per_token);
        assert!(ps[2].decode_s_per_token > ps[0].decode_s_per_token);
    }

    #[test]
    fn larger_model_lower_token_capacity_per_gib() {
        let ps = profiles();
        // Vicuna-13B MHA has ~6× the KV bytes/token of Mistral ⇒ far lower capacity.
        assert!(ps[0].token_capacity > ps[1].token_capacity);
    }

    #[test]
    fn swap_slower_than_decode_step() {
        // §2.4 Insight 3: swaps are expensive relative to per-token work.
        for p in profiles() {
            assert!(p.swap_cpu_gpu_s > 50.0 * p.decode_s_per_token);
        }
    }

    #[test]
    #[should_panic]
    fn llama70_does_not_fit_single_a10() {
        let c = ModelCatalog::paper();
        let mut llama = c.by_name("llama-70b").unwrap().clone();
        llama.tp_degree = 1;
        PerfModel::profile(&llama, GpuKind::A10, 161.0);
    }

    #[test]
    fn throughput_monotone_in_batch() {
        let p = &profiles()[0];
        assert!(p.throughput(64) > p.throughput(8));
        // Saturates at max_batch.
        assert_eq!(p.throughput(256), p.throughput(512));
    }

    #[test]
    fn a10_slower_than_a100() {
        let c = ModelCatalog::paper();
        let m = c.by_name("mistral-7b").unwrap();
        let a10 = PerfModel::profile(m, GpuKind::A10, 161.0);
        let a100 = PerfModel::profile(m, GpuKind::A100, 161.0);
        assert!(a10.decode_s_per_token > a100.decode_s_per_token);
        assert!(a10.token_capacity < a100.token_capacity);
        assert!(a10.steady_throughput(500.0) < a100.steady_throughput(500.0));
    }

    #[test]
    fn prefill_cost_linear_and_consistent_with_profile_constant() {
        let p = &profiles()[1]; // Vicuna-13B
        // The profiled constant is the mean-prompt single-chunk cost.
        assert!((p.prefill_cost(161) - p.prefill_s).abs() < 1e-9);
        // Each chunk pays the per-iteration overhead, so two chunks cost
        // exactly one extra overhead over the contiguous prefill.
        let whole = p.prefill_cost(3200);
        let halves = p.prefill_cost(1600) * 2.0;
        assert!(halves > whole);
        assert!(halves - whole < 0.005, "only the fixed overhead doubles");
        // A mega prompt costs ~20x the mean prompt, not the same constant.
        assert!(p.prefill_cost(3200) > 10.0 * p.prefill_cost(161));
    }

    #[test]
    fn evict_time_linear_in_tokens() {
        let p = &profiles()[0];
        let t1 = p.evict_time_s(1000, 131_072);
        let t2 = p.evict_time_s(2000, 131_072);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
