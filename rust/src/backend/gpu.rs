//! GPU device catalog. The paper's testbed: 30× NVIDIA A10 (24 GB) and
//! 50× NVIDIA A100 (80 GB) (§8, Experiment Setup). Heterogeneity enters
//! QLM only through the profiled constants the RWT estimator consumes, so
//! a device is fully described by this spec.

/// Device model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuKind {
    A10,
    A100,
}

/// Static hardware description used by the analytic timing model.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub kind: GpuKind,
    /// HBM capacity in GiB.
    pub mem_gib: f64,
    /// HBM bandwidth, GiB/s — decode is weight-load bound (§2.1).
    pub hbm_gibs: f64,
    /// Host link bandwidth, GiB/s — governs KV eviction and CPU→GPU model
    /// swaps ("GPU-to-CPU memory bandwidth is typically at least 10× less
    /// than the GPU memory bandwidth", §5).
    pub pcie_gibs: f64,
    /// Dense bf16 throughput, TFLOP/s — prefill is compute bound.
    pub bf16_tflops: f64,
}

impl GpuKind {
    pub fn spec(&self) -> GpuSpec {
        match self {
            GpuKind::A10 => GpuSpec {
                kind: *self,
                mem_gib: 24.0,
                hbm_gibs: 600.0,
                pcie_gibs: 25.0,
                bf16_tflops: 125.0,
            },
            GpuKind::A100 => GpuSpec {
                kind: *self,
                mem_gib: 80.0,
                hbm_gibs: 1935.0,
                pcie_gibs: 32.0,
                bf16_tflops: 312.0,
            },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GpuKind::A10 => "A10",
            GpuKind::A100 => "A100",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_roughly_3x_a10_memory() {
        // §8.3: "The A10 ... ~3× lower GPU memory".
        let r = GpuKind::A100.spec().mem_gib / GpuKind::A10.spec().mem_gib;
        assert!((3.0..3.5).contains(&r));
    }

    #[test]
    fn pcie_much_slower_than_hbm() {
        for k in [GpuKind::A10, GpuKind::A100] {
            let s = k.spec();
            assert!(s.hbm_gibs / s.pcie_gibs >= 10.0, "{k:?}");
        }
    }
}
