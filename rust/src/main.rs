//! The `qlm` CLI: run simulations, regenerate paper figures, and serve
//! the real tiny model through the PJRT runtime.
//!
//! Argument parsing is hand-rolled (the offline build has no clap);
//! subcommands:
//!
//! ```text
//! qlm sim [--scenario S] [--list] [--policy P] [--rate R] [--requests N]
//!         [--fleet N] [--seed S] [--horizon SECS] [--threads N]
//!         [--chunk-tokens N] [--slice-tokens N] [--stream] [--compact]
//!         [--trace-out FILE] [--telemetry-out FILE] [--telemetry-every SECS]
//!         `--stream` replays arrivals lazily from the seed (no
//!         materialized trace; bit-identical metrics); `--compact` folds
//!         completions into aggregates instead of archiving records.
//!         Both default ON for `--scenario gigascale` (10M+ requests).
//! qlm report <trace.jsonl> [--req ID] [--timelines N]   render a recorded
//!            flight-recorder trace: event counts, the RWT-accuracy table,
//!            per-request timelines
//! qlm compare [--scenario S] [--rate R] [--requests N] [--fleet N]
//!             [--seed S] [--threads N]       Fig. 11/14 policy table
//! qlm compare --threads-sweep 1,2,4 [--scenario scale]   Fig. 20-scale
//!             worker-pool sweep (one trace, QLM at each lane count,
//!             digest equality enforced)
//! qlm plan [--scenario S] [--rate R] [--requests N] [--horizon SECS]
//!          [--max-a100 N] [--max-a10 N] [--util F]    capacity planner
//! qlm figures [--fig N] [--full]         regenerate paper figures
//! qlm simulate [--policy P] [--rate R] [--requests N] [--fleet N]
//!              [--multi-model] [--seed S]
//! qlm serve [--artifacts DIR] [--requests N] [--fcfs]   (feature "pjrt")
//! qlm audit [--root DIR] [--list] [--explain RULE]   static-analysis pass
//!           over src/+tests/ (determinism / concurrency / architecture
//!           invariants; nonzero exit on any unwaived violation)
//! qlm bench-scheduler [--requests N]     Fig. 20-style overhead probe
//! ```
//!
//! Every simulation-driving subcommand shares one knob parser
//! ([`CliArgs`]), so `--chunk-tokens` / `--slice-tokens` (the
//! token-granular iteration overrides) mean the same thing everywhere.

use std::process::ExitCode;

use qlm::backend::{GpuKind, ModelCatalog, ModelId};
use qlm::baselines::Policy;
use qlm::capacity::{CapacityPlanner, PlannerConfig, TierSpec};
use qlm::coordinator::lso::LsoConfig;
use qlm::figures::{run_figure, Scale, ALL_FIGURES};
use qlm::sim::{fleet_a100, SimConfig, Simulation};
use qlm::workload::{Scenario, ScenarioKnobs, ScenarioRun, SloClass, Trace, WorkloadSpec};

/// Minimal flag parser: --key value / --switch.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = argv.get(i + 1).filter(|v| !v.starts_with("--"));
                if let Some(v) = val {
                    flags.push((name.to_string(), Some(v.clone())));
                    i += 2;
                } else {
                    flags.push((name.to_string(), None));
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn usage() -> ExitCode {
    eprintln!(
        "qlm — Queue Management for SLO-Oriented LLM Serving (SoCC '24 reproduction)

USAGE:
  qlm sim [--scenario burst|diurnal|mixed-slo|multi-model|failover|scale
          |autoscale|mega|megascale|gigascale] [--list] [--policy P] [--rate R]
          [--requests N] [--fleet N] [--seed S] [--horizon SECS] [--full-solve]
          [--threads N] [--chunk-tokens N] [--slice-tokens N]
          [--stream] [--compact] [--trace-out FILE]
          [--telemetry-out FILE] [--telemetry-every SECS]
          (--stream = seeded lazy arrivals, no materialized trace;
          --compact = aggregate-only completion records; both default on
          for gigascale)
  qlm report <trace.jsonl> [--req ID] [--timelines N]   event counts, the
             per-class RWT prediction-error table, request timelines from a
             `--trace-out` flight-recorder file
  qlm compare [--scenario S] [--rate R] [--requests N] [--fleet N] [--seed S]
              [--horizon SECS] [--threads N] [--chunk-tokens N]
              [--slice-tokens N]    every policy + LSO ablation,
              one shared trace (Fig. 11/14 table)
  qlm compare --threads-sweep 1,2,4 [--scenario scale]   QLM over one shared
              trace at each worker-lane count (defaults to the scenario's
              full Fig. 20-scale request count; digests must collide)
  qlm plan [--scenario S] [--rate R] [--requests N] [--horizon SECS]
           [--max-a100 N] [--max-a10 N] [--util F] [--seed S]
  qlm figures [--fig N] [--full]
  qlm simulate [--policy qlm|edf|edf-swap|vllm|sjf|wfq|shepherd|chunked
               |qlm-noevict|qlm-noswap|qlm-nolb] [--rate R] [--requests N]
               [--fleet N] [--multi-model] [--seed S] [--chunk-tokens N]
               [--slice-tokens N]
  qlm serve [--artifacts DIR] [--requests N] [--fcfs] [--max-new N]
  qlm audit [--root DIR] [--list] [--explain RULE]   enforce the
            determinism/concurrency/architecture invariants (exit 1 on
            any unwaived violation; --list shows per-rule counts)
  qlm bench-scheduler"
    );
    ExitCode::from(2)
}

/// Resolve `--scenario`, printing the canonical unknown-scenario error.
fn parse_scenario(args: &Args) -> Option<Scenario> {
    let name = args.get("scenario").unwrap_or("mixed-slo");
    let scenario = Scenario::from_name(name);
    if scenario.is_none() {
        eprintln!(
            "unknown scenario {name} \
             (known: burst, diurnal, mixed-slo, multi-model, failover, scale, \
             autoscale, mega, megascale, gigascale)"
        );
    }
    scenario
}

/// `--chunk-tokens` / `--slice-tokens`: the token-granular iteration
/// overrides. Absent flags leave the engine defaults (policy-dependent;
/// the chunked policy brings its own, everything else runs whole-request
/// iterations).
fn parse_token_knobs(args: &Args) -> (Option<u32>, Option<u32>) {
    let knob = |name: &str| args.get(name).and_then(|v| v.parse::<u32>().ok());
    (knob("chunk-tokens"), knob("slice-tokens"))
}

/// The knobs every simulation-driving subcommand shares (`sim`,
/// `compare`, the threads sweep, `plan`), parsed in ONE place so each
/// flag means the same thing everywhere. The only per-command freedom is
/// the default `--requests` count (`compare` runs a table-scale sample;
/// the rest fill the horizon). `--full-solve` disables the incremental
/// scheduler (the Fig. 20 overhead baseline; see `cargo bench --
/// sched_incremental`); `--threads N` fans the view/pricing pass out
/// over N workers (identical metrics to serial; `cargo bench --
/// par_views`). Keeping this in one struct is what guarantees the
/// compare table runs under exactly the config `qlm sim` would use.
struct CliArgs {
    scenario: Scenario,
    horizon_s: f64,
    knobs: ScenarioKnobs,
    full_solve: bool,
    threads: usize,
    chunk_tokens: Option<u32>,
    slice_tokens: Option<u32>,
}

impl CliArgs {
    /// Parse the shared knobs; `default_requests` supplies the
    /// per-command `--requests` fallback from (scenario, rate, horizon).
    fn parse(
        args: &Args,
        default_requests: impl FnOnce(Scenario, f64, f64) -> usize,
    ) -> Option<CliArgs> {
        let scenario = parse_scenario(args)?;
        let horizon_s = args.get_f64("horizon", 7200.0);
        let rate = args.get_f64("rate", scenario.default_rate());
        let knobs = ScenarioKnobs {
            rate,
            requests: args.get_usize("requests", default_requests(scenario, rate, horizon_s)),
            fleet: args.get_usize("fleet", scenario.default_fleet() as usize) as u32,
            seed: args.get_usize("seed", 42) as u64,
        };
        let (chunk_tokens, slice_tokens) = parse_token_knobs(args);
        Some(CliArgs {
            scenario,
            horizon_s,
            knobs,
            full_solve: args.has("full-solve"),
            threads: args.get_usize("threads", 1),
            chunk_tokens,
            slice_tokens,
        })
    }

    /// Assemble the simulation config for one policy run: the scenario's
    /// fleet/catalog/failures/capacity settings plus the shared switches.
    fn sim_config(&self, run: &ScenarioRun, policy: Policy) -> SimConfig {
        let mut cfg = run.sim_config(policy);
        cfg.seed = self.knobs.seed;
        cfg.horizon_s = self.horizon_s;
        cfg.sched_incremental = !self.full_solve;
        cfg.threads = self.threads;
        cfg.chunk_tokens = self.chunk_tokens;
        cfg.slice_tokens = self.slice_tokens;
        cfg
    }
}

fn parse_policy(name: &str) -> Option<Policy> {
    Some(match name {
        "qlm" => Policy::qlm(),
        "edf" => Policy::Edf,
        "edf-swap" => Policy::EdfSwap,
        "vllm" => Policy::VllmFcfs,
        "sjf" => Policy::Sjf,
        "wfq" => Policy::Wfq,
        "shepherd" => Policy::Shepherd,
        "chunked" => Policy::Chunked,
        "qlm-noevict" => Policy::qlm_with(LsoConfig::without_eviction()),
        "qlm-noswap" => Policy::qlm_with(LsoConfig::without_swapping()),
        "qlm-nolb" => Policy::qlm_with(LsoConfig::without_load_balancing()),
        "qlm-nopull" => Policy::qlm_with(LsoConfig::without_ordered_pulling()),
        _ => return None,
    })
}

fn cmd_figures(args: &Args) -> ExitCode {
    let scale = if args.has("full") {
        Scale::Full
    } else {
        Scale::Quick
    };
    let ids: Vec<u32> = match args.get("fig") {
        Some(v) => match v.parse() {
            Ok(id) => vec![id],
            Err(_) => {
                eprintln!("bad --fig {v}");
                return ExitCode::from(2);
            }
        },
        None => ALL_FIGURES.to_vec(),
    };
    for id in ids {
        match run_figure(id, scale) {
            Some(fig) => println!("{}", fig.render()),
            None => {
                eprintln!("unknown figure {id} (known: {ALL_FIGURES:?})");
                return ExitCode::from(2);
            }
        }
    }
    ExitCode::SUCCESS
}

/// Scenario-driven simulation: one command per paper regime.
fn cmd_sim(args: &Args) -> ExitCode {
    if args.has("list") {
        println!("available scenarios:");
        for s in Scenario::ALL {
            println!("  {:<12} {}", s.name(), s.description());
        }
        return ExitCode::SUCCESS;
    }
    let Some(cli) = CliArgs::parse(args, |s, rate, horizon| s.requests_for(rate, horizon)) else {
        return ExitCode::from(2);
    };
    let policy = match parse_policy(args.get("policy").unwrap_or("qlm")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy");
            return ExitCode::from(2);
        }
    };
    let scenario = cli.scenario;
    let run = scenario.build(&cli.knobs);
    // Streamed arrivals + compact records are how the 10M-request
    // gigascale regime stays O(in-flight); they default on there (a
    // materialized 10M-request trace is the failure mode the streamed
    // path exists to remove) and are opt-in everywhere else. Metrics
    // are bit-identical either way for --stream; --compact trades
    // per-request records for aggregates.
    let streamed = args.has("stream") || scenario == Scenario::Gigascale;
    let compact = args.has("compact") || scenario == Scenario::Gigascale;
    let total_requests = run.spec.total_requests();
    println!(
        "scenario {}: {}\n  {} requests, {} instances, rate {:.1} req/s, horizon {:.0}s{}",
        run.name,
        scenario.description(),
        total_requests,
        run.fleet.len(),
        cli.knobs.rate,
        cli.horizon_s,
        match (streamed, compact) {
            (true, true) => " (streamed arrivals, compact records)",
            (true, false) => " (streamed arrivals)",
            (false, true) => " (compact records)",
            (false, false) => "",
        },
    );
    for (t, inst) in &run.failures {
        println!("  failure injected: instance {} dies at t={t:.0}s", inst.0);
    }
    if let Some(auto) = run.autoscale {
        // The engine only autoscales group-based policies; don't tell
        // the operator a baseline run was autoscaled when it wasn't.
        if policy.uses_groups() {
            println!(
                "  autoscaler: {}..{} x {} (trough fleet starts the run)",
                auto.min_instances,
                auto.max_instances,
                auto.gpu.name(),
            );
        } else {
            println!(
                "  autoscaler: disabled ({} is not a group-based policy; fixed fleet)",
                policy.name(),
            );
        }
    }
    let mut cfg = cli.sim_config(&run, policy);
    cfg.compact_records = compact;
    // Observability: `--trace-out` turns the flight recorder (and the
    // RWT-accuracy ledger riding on it) on; `--telemetry-out` the fleet
    // sampler. Both recorded in sim time — off, the engine allocates no
    // observer state at all.
    let trace_out = args.get("trace-out").map(str::to_string);
    let telemetry_out = args.get("telemetry-out").map(str::to_string);
    cfg.obs.trace = trace_out.is_some();
    if telemetry_out.is_some() {
        cfg.obs.telemetry_every_s = Some(args.get_f64("telemetry-every", 10.0));
    }
    let wall = std::time::Instant::now();
    let (m, obs) = if streamed {
        Simulation::new_streaming(cfg, &run.spec, cli.knobs.seed).run_streaming_with_obs()
    } else {
        let trace = Trace::generate(&run.spec, cli.knobs.seed);
        Simulation::new(cfg, &trace).run_with_obs(&trace)
    };
    let wall_s = wall.elapsed().as_secs_f64();
    println!("{}", m.summary());
    if let Some(t) = &m.compact {
        // Compact runs archive no per-request records; the per-class
        // table has nothing to read, so report the folded aggregates.
        println!(
            "  compact tally: {} completed, TTFT attainment {:5.1}%, mean TTFT {:.2}s, \
             {} tokens generated",
            t.completed,
            100.0 * t.ttft_attainment(),
            t.mean_ttft(),
            t.tokens_generated,
        );
    } else {
        for class in [SloClass::Interactive, SloClass::Batch1, SloClass::Batch2] {
            println!(
                "  {:<12} SLO attainment {:5.1}%  (TTFT {:5.1}%, TPOT {:5.1}%)",
                class.name(),
                100.0 * m.slo_attainment_class(class),
                100.0 * m.ttft_attainment_class(class),
                100.0 * m.tpot_attainment_class(class),
            );
        }
    }
    println!(
        "  completed {}/{} requests over {:.0} simulated seconds ({:.1}s wall)",
        m.completed_count(),
        total_requests,
        m.duration_s,
        wall_s,
    );
    println!(
        "  scheduler: {} invocations, {:.1} ms total ({:.3} ms each)",
        m.scheduler_invocations,
        1000.0 * m.scheduler_wall_s,
        1000.0 * m.scheduler_wall_s / m.scheduler_invocations.max(1) as f64,
    );
    if m.scale_ups + m.scale_downs > 0 || m.shed_count() > 0 {
        println!(
            "  capacity: {} scale-ups, {} scale-downs, {:.1} device-hours, {} shed",
            m.scale_ups,
            m.scale_downs,
            m.device_hours(),
            m.shed_count(),
        );
    }
    if let Some(obs) = obs {
        if let Some(path) = &trace_out {
            if let Err(e) = std::fs::write(path, &obs.trace_jsonl) {
                eprintln!("failed to write {path}: {e}");
                return ExitCode::FAILURE;
            }
            println!(
                "  trace: {} events -> {path}",
                obs.trace_jsonl.lines().count()
            );
        }
        if let (Some(path), Some(jsonl)) = (&telemetry_out, &obs.telemetry_jsonl) {
            if let Err(e) = std::fs::write(path, jsonl) {
                eprintln!("failed to write {path}: {e}");
                return ExitCode::FAILURE;
            }
            println!("  telemetry: {} samples -> {path}", jsonl.lines().count());
        }
        let s = &obs.sched;
        if s.passes > 0 {
            println!(
                "  sched mix: {} passes ({} full, {} delta), {} dirty groups, \
                 {} crossings drained, memo {}/{} hits",
                s.passes,
                s.full,
                s.delta,
                s.dirty,
                s.crossings_drained,
                s.memo_hits,
                s.memo_hits + s.memo_misses,
            );
        }
        for e in &obs.rwt_errors {
            println!(
                "  rwt error {:<12} n={:<6} mae={:.3}s p90={:.3}s",
                e.class.name(),
                e.n,
                e.mae_s,
                e.p90_s,
            );
        }
    }
    ExitCode::SUCCESS
}

/// `qlm report <trace.jsonl>`: render a flight-recorder trace into
/// per-request timelines and aggregate tables (event counts, the
/// per-class RWT prediction-error join).
fn cmd_report(args: &Args) -> ExitCode {
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: qlm report <trace.jsonl> [--req ID] [--timelines N]");
        return ExitCode::from(2);
    };
    let jsonl = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to read {path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let opts = qlm::obs::ReportOptions {
        req: args.get("req").and_then(|v| v.parse().ok()),
        timelines: args.get_usize("timelines", 3),
    };
    print!("{}", qlm::obs::render(&jsonl, &opts));
    ExitCode::SUCCESS
}

/// Multi-SLO policy shoot-out (the Fig. 11/14 reproduction): run every
/// policy plus the four LSO ablations over ONE shared trace and print
/// an SLO-attainment / throughput / preemption table. The first
/// consumer of the `SchedulingPolicy` seam — adding a policy here is
/// one line once it exists in `baselines/`.
fn cmd_compare(args: &Args) -> ExitCode {
    if args.has("threads-sweep") {
        return cmd_compare_threads_sweep(args);
    }
    // Compare runs many simulations, so the default size is a table-
    // scale sample, not the scenario's horizon-filling request count.
    let Some(cli) = CliArgs::parse(args, |_, _, _| 2000) else {
        return ExitCode::from(2);
    };
    let run = cli.scenario.build(&cli.knobs);
    let policies: Vec<Policy> = vec![
        Policy::qlm(),
        Policy::qlm_with(LsoConfig::without_eviction()),
        Policy::qlm_with(LsoConfig::without_swapping()),
        Policy::qlm_with(LsoConfig::without_load_balancing()),
        Policy::qlm_with(LsoConfig::without_ordered_pulling()),
        Policy::Shepherd,
        Policy::Edf,
        Policy::EdfSwap,
        Policy::Wfq,
        Policy::Sjf,
        Policy::VllmFcfs,
        Policy::Chunked,
    ];
    println!(
        "compare on scenario {} — {} requests, {} instances, rate {:.1} req/s, seed {} \
         (seeded replay)",
        run.name,
        run.spec.total_requests(),
        run.fleet.len(),
        cli.knobs.rate,
        cli.knobs.seed,
    );
    println!(
        "{:<12} {:>6} {:>6} {:>6} {:>6} {:>6} {:>6} {:>9} {:>9} {:>8} {:>7} {:>6}",
        "policy",
        "slo%",
        "ttft%",
        "tpot%",
        "int%",
        "b1%",
        "b2%",
        "thr r/s",
        "p99ttft",
        "preempt",
        "evict",
        "swaps"
    );
    // Every row replays the same trace from the seed through the
    // arrival stream (`Trace::generate` is defined as the stream
    // collected, so the rows see byte-identical request sequences)
    // instead of sharing one materialized Vec — the table never holds a
    // trace at all, which is what lets `--scenario gigascale` fit.
    for policy in policies {
        let cfg = cli.sim_config(&run, policy);
        let m = Simulation::new_streaming(cfg, &run.spec, cli.knobs.seed).run_streaming();
        println!(
            "{:<12} {:>6.1} {:>6.1} {:>6.1} {:>6.1} {:>6.1} {:>6.1} {:>9.2} {:>8.2}s {:>8} {:>7} {:>6}",
            m.policy,
            100.0 * m.slo_attainment(),
            100.0 * m.ttft_attainment(),
            100.0 * m.tpot_attainment(),
            100.0 * m.slo_attainment_class(SloClass::Interactive),
            100.0 * m.slo_attainment_class(SloClass::Batch1),
            100.0 * m.slo_attainment_class(SloClass::Batch2),
            m.throughput_rps(),
            m.ttft_percentile(99.0),
            m.total_internal_preemptions(),
            m.total_evictions(),
            m.total_model_swaps(),
        );
    }
    ExitCode::SUCCESS
}

/// `qlm compare --threads-sweep 1,2,4`: the persistent worker pool at
/// Fig. 20 scale from the CLI, not just benches. One shared trace —
/// sized, when `--requests` is absent, to the scenario's full
/// horizon-filling count (the 100k-request floor for `scale` /
/// `autoscale`) — run under QLM once per lane count, reporting SLO,
/// scheduler overhead, and wall time per row. The runs must be
/// bit-identical: any digest divergence across lane counts exits
/// nonzero (the golden suite's threads ≡ serial contract, enforced at
/// full scale).
fn cmd_compare_threads_sweep(args: &Args) -> ExitCode {
    // Strict parsing: a malformed token must not silently shrink the
    // sweep, or the digest-equality verdict would cover fewer lane
    // counts than the operator asked for.
    let mut sweep: Vec<usize> = Vec::new();
    for tok in args.get("threads-sweep").unwrap_or("1,2,4").split(',') {
        match tok.trim().parse::<usize>() {
            Ok(t) if t >= 1 => sweep.push(t),
            _ => {
                eprintln!(
                    "bad --threads-sweep token {tok:?}: want positive lane counts, e.g. 1,2,4"
                );
                return ExitCode::from(2);
            }
        }
    }
    if sweep.is_empty() {
        eprintln!("--threads-sweep wants a comma-separated lane list, e.g. 1,2,4");
        return ExitCode::from(2);
    }
    let Some(cli) = CliArgs::parse(args, |s, rate, horizon| s.requests_for(rate, horizon)) else {
        return ExitCode::from(2);
    };
    let run = cli.scenario.build(&cli.knobs);
    let trace = Trace::generate(&run.spec, cli.knobs.seed);
    println!(
        "threads sweep on scenario {} — {} requests, {} instances, rate {:.1} req/s, seed {}",
        run.name,
        trace.len(),
        run.fleet.len(),
        cli.knobs.rate,
        cli.knobs.seed,
    );
    println!(
        "{:>7} {:>6} {:>9} {:>9} {:>12} {:>8} {:>18}",
        "threads", "slo%", "thr r/s", "sched ms", "ms/invocation", "wall s", "digest"
    );
    let mut digests: Vec<(usize, u64)> = Vec::new();
    for &threads in &sweep {
        let mut cfg = cli.sim_config(&run, Policy::qlm());
        cfg.threads = threads;
        let wall = std::time::Instant::now();
        let m = Simulation::new(cfg, &trace).run(&trace);
        let wall_s = wall.elapsed().as_secs_f64();
        let d = m.digest();
        let digest_hex = format!("{d:016x}");
        println!(
            "{:>7} {:>6.1} {:>9.2} {:>9.1} {:>12.3} {:>8.1} {digest_hex:>18}",
            threads,
            100.0 * m.slo_attainment(),
            m.throughput_rps(),
            1000.0 * m.scheduler_wall_s,
            1000.0 * m.scheduler_wall_s / m.scheduler_invocations.max(1) as f64,
            wall_s,
        );
        digests.push((threads, d));
    }
    let (_, first) = digests[0];
    if digests.iter().any(|&(_, d)| d != first) {
        eprintln!(
            "digest divergence across lane counts: {digests:?} — threads must be invisible"
        );
        return ExitCode::FAILURE;
    }
    println!("digest equality across lane counts: OK");
    ExitCode::SUCCESS
}

/// Offline capacity planning: what fleet does this workload need?
fn cmd_plan(args: &Args) -> ExitCode {
    let Some(cli) = CliArgs::parse(args, |s, rate, horizon| s.requests_for(rate, horizon)) else {
        return ExitCode::from(2);
    };
    let run = cli.scenario.build(&cli.knobs);
    let mut tiers = vec![TierSpec {
        gpu: GpuKind::A100,
        max: args.get_usize("max-a100", 64) as u32,
    }];
    let a10_max = args.get_usize("max-a10", 0) as u32;
    if a10_max > 0 {
        tiers.push(TierSpec {
            gpu: GpuKind::A10,
            max: a10_max,
        });
    }
    let cfg = PlannerConfig {
        tiers,
        utilization: args.get_f64("util", PlannerConfig::default().utilization),
        ..Default::default()
    };
    println!(
        "capacity plan for scenario {} (rate {:.1} req/s, {} requests, horizon {:.0}s)",
        run.name,
        cli.knobs.rate,
        cli.knobs.requests,
        cli.horizon_s,
    );
    let planner = CapacityPlanner::from_spec(&run.spec, run.catalog, cfg, cli.knobs.seed);
    let plan = planner.plan();
    print!("{}", planner.render(&plan));
    if !plan.feasible {
        println!(
            "NOT FEASIBLE at the allowed maximum — raise --max-a100/--max-a10, or \
             run with admission control (`qlm sim --scenario autoscale` sheds \
             hopeless batch traffic at submit time)"
        );
        // Nonzero so scripts (and the CI smoke step) can detect an
        // unplannable workload, as with bad input.
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

fn cmd_simulate(args: &Args) -> ExitCode {
    let policy = match parse_policy(args.get("policy").unwrap_or("qlm")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy");
            return ExitCode::from(2);
        }
    };
    let rate = args.get_f64("rate", 20.0);
    let requests = args.get_usize("requests", 1500);
    let fleet_n = args.get_usize("fleet", 4) as u32;
    let seed = args.get_usize("seed", 42) as u64;
    let (catalog, spec) = if args.has("multi-model") {
        (
            ModelCatalog::paper_multi_model(),
            WorkloadSpec::w_b(
                vec![ModelId(3), ModelId(4)],
                vec![ModelId(5), ModelId(6)],
                rate,
                requests,
            ),
        )
    } else {
        (
            ModelCatalog::paper(),
            WorkloadSpec::w_a(ModelId(1), rate, requests),
        )
    };
    let trace = Trace::generate(&spec, seed);
    let mut cfg = SimConfig::new(fleet_a100(fleet_n), catalog, policy);
    cfg.seed = seed;
    (cfg.chunk_tokens, cfg.slice_tokens) = parse_token_knobs(args);
    let m = Simulation::new(cfg, &trace).run(&trace);
    println!("{}", m.summary());
    println!(
        "  completed={}/{} mean_ttft={:.2}s p50={:.2}s p99={:.2}s \
         sched_invocations={} sched_wall={:.1}ms",
        m.completed_count(),
        m.records.len(),
        m.mean_ttft(),
        m.ttft_percentile(50.0),
        m.ttft_percentile(99.0),
        m.scheduler_invocations,
        1000.0 * m.scheduler_wall_s,
    );
    ExitCode::SUCCESS
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> ExitCode {
    use qlm::runtime::{EngineConfig, EngineRequest, ServeEngine, TinyModel};
    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let n = args.get_usize("requests", 16);
    let max_new = args.get_usize("max-new", 16) as u32;
    let model = match TinyModel::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("failed to load artifacts from {dir}: {e:#}\nrun `make artifacts` first");
            return ExitCode::FAILURE;
        }
    };
    println!(
        "loaded {} params on {} (buckets {:?})",
        model.manifest.param_count,
        model.platform(),
        model
            .manifest
            .buckets
            .iter()
            .map(|b| b.batch)
            .collect::<Vec<_>>()
    );
    let mut engine = ServeEngine::new(
        model,
        EngineConfig {
            ordered: !args.has("fcfs"),
            eos: None,
        },
    );
    for i in 0..n {
        engine.submit(EngineRequest {
            id: i as u64,
            prompt: format!("request {i}: the queue management system").into_bytes(),
            max_new_tokens: max_new,
            slo_s: if i % 4 == 0 { 0.5 } else { 30.0 },
        });
    }
    let t0 = std::time::Instant::now();
    let results = match engine.run_to_completion() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let wall = t0.elapsed().as_secs_f64();
    let ttfts: Vec<f64> = results.iter().map(|r| r.ttft_s).collect();
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s, {:.0} tok/s decode)",
        results.len(),
        wall,
        results.len() as f64 / wall,
        engine.stats.decode_tokens_per_s(),
    );
    println!(
        "TTFT p50={:.3}s p99={:.3}s  batches={} prefill={:.2}s decode={:.2}s",
        qlm::util::percentile(&ttfts, 50.0),
        qlm::util::percentile(&ttfts, 99.0),
        engine.stats.batches,
        engine.stats.prefill_s,
        engine.stats.decode_s,
    );
    ExitCode::SUCCESS
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) -> ExitCode {
    eprintln!(
        "`qlm serve` needs the PJRT runtime: rebuild with `--features pjrt` \
         (see README.md, \"Real-model serving\")"
    );
    ExitCode::FAILURE
}

/// `qlm audit [--root DIR] [--list] [--explain RULE]` — run the in-repo
/// static-analysis pass (src/audit) over the crate and fail on any
/// unwaived invariant violation. Output is machine-readable: one
/// tab-separated `rule\tfile:line\tnote\tsnippet` row per violation.
fn cmd_audit(args: &Args) -> ExitCode {
    if let Some(rule_id) = args.get("explain") {
        return match qlm::audit::Rule::from_id(rule_id) {
            Some(rule) => {
                let info = rule.info();
                println!("{} [{}]", info.id, info.group);
                println!("  {}", info.summary);
                println!();
                for line in info.explain.split('\n') {
                    println!("  {}", line.trim());
                }
                ExitCode::SUCCESS
            }
            None => {
                eprintln!("unknown rule `{rule_id}`; `qlm audit --list` prints the rule table");
                ExitCode::from(2)
            }
        };
    }
    // The audited root defaults to this crate's own source tree, baked
    // in at compile time (CI and the dev loop both build in-tree).
    let default_root = env!("CARGO_MANIFEST_DIR");
    let root = std::path::PathBuf::from(args.get("root").unwrap_or(default_root));
    let report = match qlm::audit::run_report(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("audit failed to read {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if report.files_scanned == 0 {
        eprintln!("audit scanned 0 files under {} — wrong --root?", root.display());
        return ExitCode::from(2);
    }
    if args.has("list") {
        println!("{:<20} {:<12} {:>10} {:>8}  summary", "rule", "group", "violations", "waivers");
        for info in &qlm::audit::RULES {
            let violations = report.violations.iter().filter(|v| v.rule == info.rule).count();
            let waivers = report.waivers.iter().filter(|w| w.rule == info.rule).count();
            println!(
                "{:<20} {:<12} {:>10} {:>8}  {}",
                info.id, info.group, violations, waivers, info.summary
            );
        }
        println!(
            "{} files scanned, {} violations, {} waivers",
            report.files_scanned,
            report.violations.len(),
            report.waivers.len()
        );
        return if report.violations.is_empty() {
            ExitCode::SUCCESS
        } else {
            ExitCode::FAILURE
        };
    }
    for v in &report.violations {
        println!("{v}");
    }
    if report.violations.is_empty() {
        println!(
            "audit clean: {} files, 0 violations ({} waivers in force)",
            report.files_scanned,
            report.waivers.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "audit: {} violation(s); `qlm audit --explain <rule>` documents each rule, \
             `// audit:allow(<rule>): <reason>` waives a judged site",
            report.violations.len()
        );
        ExitCode::FAILURE
    }
}

fn cmd_bench_scheduler(args: &Args) -> ExitCode {
    let _ = args;
    match run_figure(20, Scale::Quick) {
        Some(f) => println!("{}", f.render()),
        None => unreachable!(),
    }
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    match args.positional.first().map(String::as_str) {
        Some("sim") => cmd_sim(&args),
        Some("report") => cmd_report(&args),
        Some("compare") => cmd_compare(&args),
        Some("plan") => cmd_plan(&args),
        Some("figures") => cmd_figures(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("audit") => cmd_audit(&args),
        Some("bench-scheduler") => cmd_bench_scheduler(&args),
        _ => usage(),
    }
}
