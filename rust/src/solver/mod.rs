//! Optimization substrate for the global scheduler (§7): a dense
//! two-phase simplex LP solver and a branch-and-bound MILP layer for the
//! binary assignment variables x_{g,i,j}, with the big-M linearization of
//! the model-switch indicator (Eq. 9).
//!
//! Built from scratch — the offline environment has no LP crates, and the
//! paper's Design Principle #1 (scalability) is exactly about when an
//! exact solver is affordable; owning the solver lets Fig. 20 measure it.

pub mod simplex;
pub mod milp;

pub use milp::{Milp, MilpResult};
pub use simplex::{Cmp, Lp, LpResult};
