//! Branch-and-bound MILP over the simplex relaxation.
//!
//! The global scheduler's formulation (§7) has binary assignment
//! variables x_{g,i,j} and switch indicators t_{g,j}; everything else is
//! continuous. Depth-first branch and bound with best-bound pruning on
//! the LP relaxation is exact and fast at request-group granularity —
//! which is precisely the paper's Design Principle #1 argument for
//! groups: they shrink the integer dimension.

use crate::solver::simplex::{solve, Cmp, Lp, LpResult};

/// A mixed-integer LP: `lp` plus the indices of binary variables
/// (bounded to [0,1] automatically).
#[derive(Debug, Clone)]
pub struct Milp {
    pub lp: Lp,
    pub binaries: Vec<usize>,
    /// Node budget; exceeded ⇒ best-so-far is returned with `proven: false`.
    pub node_limit: usize,
}

/// MILP outcome.
#[derive(Debug, Clone)]
pub enum MilpResult {
    Optimal {
        x: Vec<f64>,
        obj: f64,
        nodes: usize,
        /// False if the node budget expired before proving optimality.
        proven: bool,
    },
    Infeasible,
}

impl Milp {
    pub fn new(lp: Lp, binaries: Vec<usize>) -> Self {
        Milp {
            lp,
            binaries,
            node_limit: 100_000,
        }
    }

    pub fn solve(&self) -> MilpResult {
        // Root LP with binary bounds.
        let mut root = self.lp.clone();
        for &b in &self.binaries {
            root.add_upper(b, 1.0);
        }
        let mut best: Option<(Vec<f64>, f64)> = None;
        let mut nodes = 0usize;
        let mut proven = true;

        // Stack of (extra fixings) — each entry fixes var to 0 or 1.
        let mut stack: Vec<Vec<(usize, f64)>> = vec![Vec::new()];
        while let Some(fixings) = stack.pop() {
            nodes += 1;
            if nodes > self.node_limit {
                proven = false;
                break;
            }
            let mut lp = root.clone();
            for &(v, val) in &fixings {
                let mut row = vec![0.0; lp.n];
                row[v] = 1.0;
                lp.add(row, Cmp::Eq, val);
            }
            let sol = match solve(&lp) {
                LpResult::Optimal { x, obj } => (x, obj),
                LpResult::Infeasible => continue,
                LpResult::Unbounded => {
                    // Binary box makes the integer problem bounded in the
                    // binaries; an unbounded relaxation means a continuous
                    // direction — treat as no useful bound and skip.
                    continue;
                }
            };
            // Prune by bound.
            if let Some((_, best_obj)) = &best {
                if sol.1 <= *best_obj + 1e-9 {
                    continue;
                }
            }
            // Find most fractional binary.
            let mut frac_var = None;
            let mut frac_dist = 1e-6;
            for &b in &self.binaries {
                let v = sol.0[b];
                let d = (v - v.round()).abs();
                if d > frac_dist {
                    frac_dist = d;
                    frac_var = Some(b);
                }
            }
            match frac_var {
                None => {
                    // Integral — candidate incumbent.
                    if best.as_ref().map(|(_, o)| sol.1 > *o).unwrap_or(true) {
                        best = Some(sol);
                    }
                }
                Some(v) => {
                    let frac = sol.0[v] - sol.0[v].floor();
                    // Branch on the nearer side first (DFS dives greedily).
                    let (first, second) = if frac > 0.5 { (1.0, 0.0) } else { (0.0, 1.0) };
                    let mut f1 = fixings.clone();
                    f1.push((v, second));
                    stack.push(f1);
                    let mut f0 = fixings;
                    f0.push((v, first));
                    stack.push(f0);
                }
            }
        }
        match best {
            Some((x, obj)) => MilpResult::Optimal {
                x,
                obj,
                nodes,
                proven,
            },
            None => MilpResult::Infeasible,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opt(r: MilpResult) -> (Vec<f64>, f64) {
        match r {
            MilpResult::Optimal { x, obj, .. } => (x, obj),
            MilpResult::Infeasible => panic!("infeasible"),
        }
    }

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c st 3a + 4b + 2c ≤ 6, binaries → a+c (17).
        let mut lp = Lp::new(3);
        lp.set_objective(vec![10.0, 13.0, 7.0]);
        lp.add(vec![3.0, 4.0, 2.0], Cmp::Le, 6.0);
        let (x, obj) = opt(Milp::new(lp, vec![0, 1, 2]).solve());
        assert!((obj - 20.0).abs() < 1e-6, "obj={obj} x={x:?}"); // b + c = 20
    }

    #[test]
    fn forces_integrality_where_lp_is_fractional() {
        // max x + y st 2x + 2y ≤ 3, binaries → LP gives 1.5, MILP gives 1.
        let mut lp = Lp::new(2);
        lp.set_objective(vec![1.0, 1.0]);
        lp.add(vec![2.0, 2.0], Cmp::Le, 3.0);
        let (x, obj) = opt(Milp::new(lp, vec![0, 1]).solve());
        assert!((obj - 1.0).abs() < 1e-6);
        for &v in &x {
            assert!(v.abs() < 1e-6 || (v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn infeasible_binary_system() {
        // x = 0.5 with x binary.
        let mut lp = Lp::new(1);
        lp.set_objective(vec![1.0]);
        lp.add(vec![1.0], Cmp::Eq, 0.5);
        assert!(matches!(
            Milp::new(lp, vec![0]).solve(),
            MilpResult::Infeasible
        ));
    }

    #[test]
    fn assignment_with_switch_cost_big_m() {
        // Two items (models 1 and 2) into two slots; switch indicator t
        // must be 1 iff slot models differ: t ≥ (m1-m0)/M, t ≥ (m0-m1)/M.
        // Objective rewards keeping same model: max -t + placement value.
        // Items: both model 1 available (x0 slot0, x1 slot1 for item A m=1;
        // x2 slot0, x3 slot1 for item B m=2). Slots take exactly one item.
        // vars: x0..x3, m0, m1, t
        let big_m = 10.0;
        let mut lp = Lp::new(7);
        lp.set_objective(vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, -1.0]);
        // each item in exactly one slot
        lp.add(vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0], Cmp::Eq, 1.0);
        lp.add(vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 0.0], Cmp::Eq, 1.0);
        // each slot exactly one item
        lp.add(vec![1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0], Cmp::Eq, 1.0);
        lp.add(vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0], Cmp::Eq, 1.0);
        // slot model values: m0 = 1*x0 + 2*x2 ; m1 = 1*x1 + 2*x3
        lp.add(vec![1.0, 0.0, 2.0, 0.0, -1.0, 0.0, 0.0], Cmp::Eq, 0.0);
        lp.add(vec![0.0, 1.0, 0.0, 2.0, 0.0, -1.0, 0.0], Cmp::Eq, 0.0);
        // big-M switch: m1 - m0 ≤ M t ; m0 - m1 ≤ M t
        lp.add(vec![0.0, 0.0, 0.0, 0.0, -1.0, 1.0, -big_m], Cmp::Le, 0.0);
        lp.add(vec![0.0, 0.0, 0.0, 0.0, 1.0, -1.0, -big_m], Cmp::Le, 0.0);
        let (x, _) = opt(Milp::new(lp, vec![0, 1, 2, 3, 6]).solve());
        // Different models must be placed, so t must be 1.
        assert!((x[6] - 1.0).abs() < 1e-6, "t={}", x[6]);
    }

    #[test]
    fn respects_node_limit() {
        // A 12-var knapsack; tiny node limit still yields some incumbent
        // or proves nothing but terminates.
        let n = 12;
        let mut lp = Lp::new(n);
        lp.set_objective((0..n).map(|i| (i % 5) as f64 + 1.0).collect());
        lp.add(vec![1.0; n], Cmp::Le, 4.0);
        let mut m = Milp::new(lp, (0..n).collect());
        m.node_limit = 5;
        match m.solve() {
            MilpResult::Optimal { nodes, .. } => assert!(nodes <= 6),
            MilpResult::Infeasible => {}
        }
    }

    #[test]
    fn matches_exhaustive_on_random_knapsacks() {
        let mut rng = crate::util::Rng::new(99);
        for trial in 0..20 {
            let n = 8;
            let w: Vec<f64> = (0..n).map(|_| 1.0 + rng.f64() * 9.0).collect();
            let v: Vec<f64> = (0..n).map(|_| 1.0 + rng.f64() * 9.0).collect();
            let cap = w.iter().sum::<f64>() * 0.4;
            let mut lp = Lp::new(n);
            lp.set_objective(v.clone());
            lp.add(w.clone(), Cmp::Le, cap);
            let (_, obj) = opt(Milp::new(lp, (0..n).collect()).solve());
            // Exhaustive.
            let mut best = 0.0f64;
            for mask in 0u32..(1 << n) {
                let (mut tw, mut tv) = (0.0, 0.0);
                for i in 0..n {
                    if mask & (1 << i) != 0 {
                        tw += w[i];
                        tv += v[i];
                    }
                }
                if tw <= cap + 1e-9 {
                    best = best.max(tv);
                }
            }
            assert!(
                (obj - best).abs() < 1e-5,
                "trial {trial}: milp {obj} vs brute {best}"
            );
        }
    }
}
