//! Dense two-phase primal simplex.
//!
//! Solves  maximize c·x  subject to  A x {≤,=,≥} b,  x ≥ 0.
//! Phase 1 drives artificial variables out of the basis; phase 2
//! optimizes the real objective. Bland's rule breaks ties to guarantee
//! termination. Sizes here are small (scheduler instances), so a dense
//! tableau is the right tool.

const EPS: f64 = 1e-9;

/// Constraint comparator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// A linear program in natural form.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    /// Number of decision variables (all ≥ 0).
    pub n: usize,
    /// Objective coefficients (maximize).
    pub objective: Vec<f64>,
    /// (row coefficients, comparator, rhs).
    pub constraints: Vec<(Vec<f64>, Cmp, f64)>,
}

impl Lp {
    pub fn new(n: usize) -> Self {
        Lp {
            n,
            objective: vec![0.0; n],
            constraints: Vec::new(),
        }
    }

    pub fn set_objective(&mut self, c: Vec<f64>) {
        assert_eq!(c.len(), self.n);
        self.objective = c;
    }

    pub fn add(&mut self, row: Vec<f64>, cmp: Cmp, rhs: f64) {
        assert_eq!(row.len(), self.n);
        self.constraints.push((row, cmp, rhs));
    }

    /// Convenience: bound x_i ≤ ub.
    pub fn add_upper(&mut self, i: usize, ub: f64) {
        let mut row = vec![0.0; self.n];
        row[i] = 1.0;
        self.add(row, Cmp::Le, ub);
    }
}

/// Solver outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

struct Tableau {
    /// m rows × (cols) coefficients; last column is rhs.
    a: Vec<Vec<f64>>,
    basis: Vec<usize>,
    n_total: usize,
}

impl Tableau {
    fn pivot(&mut self, row: usize, col: usize) {
        let m = self.a.len();
        let piv = self.a[row][col];
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for v in self.a[row].iter_mut() {
            *v *= inv;
        }
        for r in 0..m {
            if r != row {
                let f = self.a[r][col];
                if f.abs() > EPS {
                    let (head, tail) = self.a.split_at_mut(row.max(r));
                    let (src, dst) = if r < row {
                        (&tail[0], &mut head[r])
                    } else {
                        (&head[row], &mut tail[0])
                    };
                    for (d, s) in dst.iter_mut().zip(src.iter()) {
                        *d -= f * s;
                    }
                }
            }
        }
        self.basis[row] = col;
    }

    /// One simplex run on reduced costs `z` (maximize). Returns false if
    /// unbounded.
    fn optimize(&mut self, z: &mut Vec<f64>) -> bool {
        let m = self.a.len();
        let rhs = self.n_total;
        loop {
            // Entering variable: Bland — smallest index with positive
            // reduced cost.
            let Some(col) = (0..self.n_total).find(|&j| z[j] > EPS) else {
                return true;
            };
            // Leaving variable: min ratio, Bland tie-break.
            let mut best: Option<(usize, f64)> = None;
            for r in 0..m {
                let a = self.a[r][col];
                if a > EPS {
                    let ratio = self.a[r][rhs] / a;
                    match best {
                        None => best = Some((r, ratio)),
                        Some((br, bratio)) => {
                            if ratio < bratio - EPS
                                || (ratio < bratio + EPS && self.basis[r] < self.basis[br])
                            {
                                best = Some((r, ratio));
                            }
                        }
                    }
                }
            }
            let Some((row, _)) = best else {
                return false; // unbounded
            };
            self.pivot(row, col);
            // Update reduced costs: z -= z[col] * (pivot row).
            let f = z[col];
            for j in 0..=self.n_total {
                z[j] -= f * self.a[row][j];
            }
        }
    }
}

/// Solve the LP. O(m·n) memory, dense pivots.
pub fn solve(lp: &Lp) -> LpResult {
    let m = lp.constraints.len();
    let n = lp.n;

    // Column layout: [x (n)] [slack/surplus (s)] [artificial (t)] [rhs].
    // Rows with negative rhs are flipped first; counts happen after.
    let mut rows: Vec<(Vec<f64>, Cmp, f64)> = lp.constraints.clone();
    for (row, cmp, rhs) in rows.iter_mut() {
        if *rhs < 0.0 {
            for v in row.iter_mut() {
                *v = -*v;
            }
            *rhs = -*rhs;
            *cmp = match *cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
        }
    }
    let mut n_slack = 0;
    let mut n_art = 0;
    for (_, cmp, _) in &rows {
        match cmp {
            Cmp::Le => n_slack += 1,
            Cmp::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Cmp::Eq => n_art += 1,
        }
    }
    // A ≤-row with rhs ≥ 0 can seed the basis with its slack; others need
    // artificials.
    let n_total = n + n_slack + n_art;
    let mut a = vec![vec![0.0; n_total + 1]; m];
    let mut basis = vec![0usize; m];
    let mut s_idx = n;
    let mut t_idx = n + n_slack;
    let mut art_cols = Vec::new();
    for (r, (row, cmp, rhs)) in rows.iter().enumerate() {
        a[r][..n].copy_from_slice(row);
        a[r][n_total] = *rhs;
        match cmp {
            Cmp::Le => {
                a[r][s_idx] = 1.0;
                basis[r] = s_idx;
                s_idx += 1;
            }
            Cmp::Ge => {
                a[r][s_idx] = -1.0;
                s_idx += 1;
                a[r][t_idx] = 1.0;
                basis[r] = t_idx;
                art_cols.push(t_idx);
                t_idx += 1;
            }
            Cmp::Eq => {
                a[r][t_idx] = 1.0;
                basis[r] = t_idx;
                art_cols.push(t_idx);
                t_idx += 1;
            }
        }
    }

    let mut tab = Tableau { a, basis, n_total };

    // Phase 1: maximize -Σ artificials → reduced costs start as the sum of
    // rows whose basis is artificial.
    if !art_cols.is_empty() {
        let mut z = vec![0.0; n_total + 1];
        for r in 0..m {
            if art_cols.contains(&tab.basis[r]) {
                for j in 0..=n_total {
                    z[j] += tab.a[r][j];
                }
            }
        }
        // Zero out artificial columns in z (they're basic).
        for &c in &art_cols {
            z[c] = 0.0;
        }
        if !tab.optimize(&mut z) {
            return LpResult::Infeasible; // phase 1 can't be unbounded, defensive
        }
        if z[n_total] > 1e-6 {
            return LpResult::Infeasible;
        }
        // Pivot any artificial still in the basis out (degenerate rows).
        for r in 0..m {
            if art_cols.contains(&tab.basis[r]) {
                if let Some(col) = (0..n + n_slack).find(|&j| tab.a[r][j].abs() > EPS) {
                    tab.pivot(r, col);
                }
            }
        }
    }

    // Phase 2: real objective. Build reduced costs z = c - c_B B⁻¹ A in
    // tableau form: start with c, then eliminate basic columns.
    let mut z = vec![0.0; n_total + 1];
    z[..n].copy_from_slice(&lp.objective);
    // Artificials must never re-enter.
    for &c in &art_cols {
        z[c] = f64::NEG_INFINITY;
    }
    for r in 0..m {
        let b = tab.basis[r];
        let f = z[b];
        if f != 0.0 && f.is_finite() {
            for j in 0..=n_total {
                if z[j].is_finite() {
                    z[j] -= f * tab.a[r][j];
                }
            }
        }
    }
    // Replace -inf with a strongly negative cost so they are never chosen.
    for &c in &art_cols {
        z[c] = -1e30;
    }
    if !tab.optimize(&mut z) {
        return LpResult::Unbounded;
    }

    let mut x = vec![0.0; n];
    for r in 0..m {
        if tab.basis[r] < n {
            x[tab.basis[r]] = tab.a[r][n_total];
        }
    }
    let obj = lp
        .objective
        .iter()
        .zip(&x)
        .map(|(c, v)| c * v)
        .sum::<f64>();
    LpResult::Optimal { x, obj }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opt(r: LpResult) -> (Vec<f64>, f64) {
        match r {
            LpResult::Optimal { x, obj } => (x, obj),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_max() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → obj 36 at (2,6).
        let mut lp = Lp::new(2);
        lp.set_objective(vec![3.0, 5.0]);
        lp.add(vec![1.0, 0.0], Cmp::Le, 4.0);
        lp.add(vec![0.0, 2.0], Cmp::Le, 12.0);
        lp.add(vec![3.0, 2.0], Cmp::Le, 18.0);
        let (x, obj) = opt(solve(&lp));
        assert!((obj - 36.0).abs() < 1e-6);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn ge_and_eq_constraints() {
        // max x + y s.t. x + y ≤ 10, x ≥ 2, y = 3 → (7,3), obj 10.
        let mut lp = Lp::new(2);
        lp.set_objective(vec![1.0, 1.0]);
        lp.add(vec![1.0, 1.0], Cmp::Le, 10.0);
        lp.add(vec![1.0, 0.0], Cmp::Ge, 2.0);
        lp.add(vec![0.0, 1.0], Cmp::Eq, 3.0);
        let (x, obj) = opt(solve(&lp));
        assert!((obj - 10.0).abs() < 1e-6);
        assert!((x[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        // x ≤ 1 and x ≥ 2.
        let mut lp = Lp::new(1);
        lp.set_objective(vec![1.0]);
        lp.add(vec![1.0], Cmp::Le, 1.0);
        lp.add(vec![1.0], Cmp::Ge, 2.0);
        assert_eq!(solve(&lp), LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(1);
        lp.set_objective(vec![1.0]);
        lp.add(vec![-1.0], Cmp::Le, 5.0); // -x ≤ 5 doesn't bound x above
        assert_eq!(solve(&lp), LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // max -x s.t. -x ≤ -3  (i.e. x ≥ 3) → x = 3.
        let mut lp = Lp::new(1);
        lp.set_objective(vec![-1.0]);
        lp.add(vec![-1.0], Cmp::Le, -3.0);
        let (x, obj) = opt(solve(&lp));
        assert!((x[0] - 3.0).abs() < 1e-6);
        assert!((obj + 3.0).abs() < 1e-6);
    }

    #[test]
    fn minimization_via_negated_objective() {
        // min 2x + 3y s.t. x + y ≥ 4, x ≤ 3 → (3,1) obj 9.
        let mut lp = Lp::new(2);
        lp.set_objective(vec![-2.0, -3.0]);
        lp.add(vec![1.0, 1.0], Cmp::Ge, 4.0);
        lp.add(vec![1.0, 0.0], Cmp::Le, 3.0);
        let (x, obj) = opt(solve(&lp));
        assert!((-obj - 9.0).abs() < 1e-6, "obj {obj} x {x:?}");
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Multiple redundant constraints meeting at a vertex.
        let mut lp = Lp::new(2);
        lp.set_objective(vec![1.0, 1.0]);
        lp.add(vec![1.0, 0.0], Cmp::Le, 1.0);
        lp.add(vec![1.0, 0.0], Cmp::Le, 1.0);
        lp.add(vec![0.0, 1.0], Cmp::Le, 1.0);
        lp.add(vec![1.0, 1.0], Cmp::Le, 2.0);
        let (_, obj) = opt(solve(&lp));
        assert!((obj - 2.0).abs() < 1e-6);
    }

    #[test]
    fn assignment_relaxation_is_integral() {
        // 2×2 assignment: max Σ w_ij x_ij, rows/cols sum to 1 — the LP
        // relaxation of an assignment problem has an integral optimum.
        let w = [[3.0, 1.0], [2.0, 4.0]];
        let mut lp = Lp::new(4); // x00 x01 x10 x11
        lp.set_objective(vec![w[0][0], w[0][1], w[1][0], w[1][1]]);
        lp.add(vec![1.0, 1.0, 0.0, 0.0], Cmp::Eq, 1.0);
        lp.add(vec![0.0, 0.0, 1.0, 1.0], Cmp::Eq, 1.0);
        lp.add(vec![1.0, 0.0, 1.0, 0.0], Cmp::Eq, 1.0);
        lp.add(vec![0.0, 1.0, 0.0, 1.0], Cmp::Eq, 1.0);
        let (x, obj) = opt(solve(&lp));
        assert!((obj - 7.0).abs() < 1e-6);
        for v in x {
            assert!(v.abs() < 1e-6 || (v - 1.0).abs() < 1e-6);
        }
    }
}
