//! Capacity planning and runtime autoscaling — the provisioning side of
//! QLM's RWT estimator (§Estimator; Fig. 1's over/under-provisioning
//! discussion: "how many devices does this workload need to meet its
//! SLOs?").
//!
//! Three cooperating pieces:
//!
//! * [`CapacityPlanner`] — an *offline what-if engine*: given a
//!   [`crate::workload::WorkloadSpec`] and a heterogeneous device
//!   catalog, it prices the workload with the RWT estimator against
//!   candidate fleets (no live instances) and binary-searches the
//!   minimal per-tier device counts that keep every SLO class's
//!   predicted waiting under its deadline. Drives the `qlm plan` CLI.
//! * [`Autoscaler`] — a *runtime* local serving operation: each
//!   scheduler pass the engine feeds it per-class backlog pressure; it
//!   decides, with hysteresis, whether to provision a new instance
//!   (paying a realistic cold-start: weight staging priced by
//!   [`crate::backend::PerfModel`]) or to drain one (no mid-flight
//!   kills — the instance finishes its running batch, then leaves).
//! * [`AdmissionController`] — the last resort: when even the maximal
//!   fleet cannot meet a class's SLO, batch-class requests are shed at
//!   submit time instead of poisoning the scheduler's penalty signal,
//!   and groups no instance can serve are retired through the same
//!   accounting path.

pub mod admission;
pub mod autoscaler;
pub mod planner;

pub use admission::{AdmissionConfig, AdmissionController};
pub use autoscaler::{AutoscaleConfig, Autoscaler, ClassPressure, ScaleDecision};
pub use planner::{CapacityPlan, CapacityPlanner, ClassPrediction, PlannerConfig, TierSpec};
