//! Offline capacity planner: "how many devices does this workload need
//! to meet its SLOs?" (QLM §Estimator — the RWT estimator is pitched
//! for exactly this what-if question, not just queue ordering).
//!
//! The planner never builds live instances. It prices each (model, SLO
//! class) demand stream with the same machinery the runtime uses — the
//! profiled Θ from [`ThetaCache`] and the [`RwtEstimator`]'s service
//! model — and asks, for a candidate per-tier device count, whether the
//! fleet can (a) sustain the offered token load and (b) keep each
//! class's predicted completion inside its SLO. Both conditions are
//! monotone in every tier count, so the minimal fleet falls out of a
//! per-tier binary search (coordinate descent, least-preferred tier
//! shrunk first so the preferred tier absorbs the workload).
//!
//! Sizing model, per demand stream `d` on model `m` and tier `t`:
//!
//! * service seconds per request: `s_d = P(m,t) + μ_out(d) / Θ(m,t)` —
//!   prefill is additive per request, decode is the request's share of
//!   the batched throughput (Appendix A.1);
//! * device-time load: `L_m(t) = Σ_d rate_eff(d) · s_d`, where
//!   latency-bound classes (SLO ≤ `peak_slo_cutoff_s`) are sized at
//!   their peak arrival rate and relaxed classes at their mean;
//! * a device sustains `utilization` effective device-seconds per
//!   second (scheduling gaps, swap stalls, batch ramp).
//!
//! The per-class check then walks a synthetic per-device virtual queue
//! (classes in deadline order, one SLO-window of sized-rate arrivals
//! each) through [`RwtEstimator::estimate_queue`] and compares the mean
//! completion against each deadline — the same signal the global
//! scheduler's penalty acts on. (The estimator's *bound* adds a
//! max-output decode term that is per-request conservative; charging it
//! to whole planning windows would reject every fleet.)

use std::cell::RefCell;

use crate::backend::perf::PROFILE_MEAN_PROMPT_TOKENS;
use crate::backend::{GpuKind, ModelCatalog, ModelId, PerfModel};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::rwt::{ProfileTable, RwtEstimator, WorkloadProfile};
use crate::sim::ThetaCache;
use crate::workload::{SloClass, Trace, WorkloadSpec};

/// One device tier available to the planner.
#[derive(Debug, Clone, Copy)]
pub struct TierSpec {
    pub gpu: GpuKind,
    /// Maximum devices of this tier the operator can provision.
    pub max: u32,
}

/// Planner knobs.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Device tiers in *preference order* (most preferred first); the
    /// planner shrinks the least-preferred tier's count first.
    pub tiers: Vec<TierSpec>,
    /// Effective fraction of profiled Θ a device sustains end to end.
    pub utilization: f64,
    /// Classes with SLO at or below this are sized at peak arrival
    /// rate; relaxed classes average over the arrival process.
    pub peak_slo_cutoff_s: f64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            tiers: vec![TierSpec {
                gpu: GpuKind::A100,
                max: 64,
            }],
            utilization: 0.85,
            peak_slo_cutoff_s: 120.0,
        }
    }
}

/// One demand stream: a (model, class, mega) slice of the workload.
#[derive(Debug, Clone, Copy)]
struct ClassDemand {
    model: ModelId,
    class: SloClass,
    mega: bool,
    mean_rate: f64,
    peak_rate: f64,
    profile: WorkloadProfile,
}

impl ClassDemand {
    /// The rate the planner sizes for: peak for latency-bound classes.
    fn rate_eff(&self, cutoff_s: f64) -> f64 {
        if self.class.target().ttft_s <= cutoff_s {
            self.peak_rate
        } else {
            self.mean_rate
        }
    }
}

/// Devices granted to one model, by tier (parallel to `cfg.tiers`).
#[derive(Debug, Clone)]
pub struct ModelAllocation {
    pub model: ModelId,
    pub per_tier: Vec<u32>,
}

impl ModelAllocation {
    pub fn total(&self) -> u32 {
        self.per_tier.iter().sum()
    }
}

/// Predicted outcome for one (model, class) demand under the plan.
#[derive(Debug, Clone, Copy)]
pub struct ClassPrediction {
    pub model: ModelId,
    pub class: SloClass,
    pub mega: bool,
    /// The sizing rate (req/s) this class was planned at.
    pub rate: f64,
    /// Mean predicted completion of one SLO-window of arrivals
    /// (infinite when the model cannot be placed at all).
    pub predicted_s: f64,
    /// The class's TTFT bound (the deadline the drain prediction is
    /// judged against; TPOT is a runtime property the planner can't see).
    pub slo_s: f64,
    /// Prediction within the deadline?
    pub ok: bool,
}

/// Planner output: per-tier counts + per-model allocation + per-class
/// predicted attainment.
#[derive(Debug, Clone)]
pub struct CapacityPlan {
    /// (tier, recommended count), parallel to `PlannerConfig::tiers`.
    pub tiers: Vec<(GpuKind, u32)>,
    /// Every demand placed and every class predicted inside its SLO.
    pub feasible: bool,
    pub allocations: Vec<ModelAllocation>,
    /// Models no allowed tier can host or absorb (admission control /
    /// catalog change territory, §9).
    pub unplaced: Vec<ModelId>,
    pub classes: Vec<ClassPrediction>,
}

impl CapacityPlan {
    pub fn total_devices(&self) -> u32 {
        self.tiers.iter().map(|&(_, n)| n).sum()
    }

    pub fn count(&self, gpu: GpuKind) -> u32 {
        self.tiers
            .iter()
            .filter(|&&(g, _)| g == gpu)
            .map(|&(_, n)| n)
            .sum()
    }
}

/// Greedy placement of per-model loads onto a candidate fleet.
#[derive(Debug, Clone)]
struct Placement {
    allocations: Vec<ModelAllocation>,
    unplaced: Vec<ModelId>,
}

/// The offline what-if engine.
#[derive(Debug)]
pub struct CapacityPlanner {
    catalog: ModelCatalog,
    cfg: PlannerConfig,
    demands: Vec<ClassDemand>,
    estimator: RwtEstimator,
    /// Profiled Θ per (gpu, model) — the same cache the simulator's
    /// scheduler views use, so plan and run price service identically.
    thetas: RefCell<ThetaCache>,
}

impl CapacityPlanner {
    /// Derive demand streams from a workload spec: arrival moments from
    /// the process definition, token moments from workload profiling
    /// over a generated trace (§6 Offline Profiling — the trace stands
    /// in for the request history dataset).
    pub fn from_spec(
        spec: &WorkloadSpec,
        catalog: ModelCatalog,
        cfg: PlannerConfig,
        seed: u64,
    ) -> Self {
        let trace = Trace::generate(spec, seed);
        let estimator = RwtEstimator::new(ProfileTable::from_trace(&trace));
        let mut demands = Vec::new();
        for s in &spec.streams {
            if s.count == 0 {
                continue;
            }
            // `Dump` has no finite rate: size it so the standing queue
            // of `count` requests drains within the stream's own SLO —
            // the deadline the dump is judged by.
            let dump_rate = s.count as f64 / s.class.target().ttft_s.max(1.0);
            let mean = s.arrivals.mean_rate().unwrap_or(dump_rate);
            let peak = s.arrivals.peak_rate().unwrap_or(mean).max(mean);
            let share = 1.0 / s.models.len().max(1) as f64;
            for &m in &s.models {
                for (mega, frac) in [(false, 1.0 - s.mega_fraction), (true, s.mega_fraction)] {
                    if frac <= 1e-12 {
                        continue;
                    }
                    demands.push(ClassDemand {
                        model: m,
                        class: s.class,
                        mega,
                        mean_rate: mean * share * frac,
                        peak_rate: peak * share * frac,
                        profile: estimator.profiles.get(m, s.class, mega),
                    });
                }
            }
        }
        CapacityPlanner {
            catalog,
            cfg,
            demands,
            estimator,
            thetas: RefCell::new(ThetaCache::new()),
        }
    }

    pub fn config(&self) -> &PlannerConfig {
        &self.cfg
    }

    /// Profiled perf for (tier, model) with measured Θ attached; `None`
    /// when the model does not fit the tier.
    fn perf(&self, gpu: GpuKind, model: ModelId) -> Option<PerfModel> {
        self.thetas
            .borrow_mut()
            .perf(gpu, model, &self.catalog, PROFILE_MEAN_PROMPT_TOKENS)
    }

    /// Mean service seconds one request of `d` consumes on `perf`.
    fn service_s(&self, d: &ClassDemand, perf: &PerfModel) -> f64 {
        perf.prefill_s + d.profile.mu_out / self.estimator.throughput(perf, &d.profile)
    }

    /// Device-time load (device-seconds per second) model `m` offers if
    /// served entirely on tier `gpu`; `None` when it can't run there.
    fn model_load(&self, m: ModelId, gpu: GpuKind) -> Option<f64> {
        let perf = self.perf(gpu, m)?;
        Some(
            self.demands
                .iter()
                .filter(|d| d.model == m)
                .map(|d| d.rate_eff(self.cfg.peak_slo_cutoff_s) * self.service_s(d, &perf))
                .sum(),
        )
    }

    /// Models carrying demand, most-constrained first (fewest compatible
    /// tiers, then heaviest preferred-tier load) so scarce tiers go to
    /// the models that have no alternative.
    fn demand_models(&self) -> Vec<ModelId> {
        let mut models: Vec<ModelId> = self.demands.iter().map(|d| d.model).collect();
        models.sort_unstable();
        models.dedup();
        let key = |&m: &ModelId| {
            let compat = self
                .cfg
                .tiers
                .iter()
                .filter(|t| self.perf(t.gpu, m).is_some())
                .count();
            let load = self
                .cfg
                .tiers
                .iter()
                .find_map(|t| self.model_load(m, t.gpu))
                .unwrap_or(0.0);
            (compat, load, m)
        };
        models.sort_by(|a, b| {
            let (ca, la, ia) = key(a);
            let (cb, lb, ib) = key(b);
            ca.cmp(&cb)
                .then(lb.partial_cmp(&la).unwrap())
                .then(ia.cmp(&ib))
        });
        models
    }

    /// Greedily place every model's load onto `counts` devices per tier
    /// (tier preference order). A model may straddle tiers; whatever
    /// fraction cannot be absorbed leaves the model in `unplaced`.
    fn place(&self, counts: &[u32]) -> Placement {
        let util = self.cfg.utilization.max(1e-6);
        let mut free: Vec<u32> = counts.to_vec();
        let mut allocations = Vec::new();
        let mut unplaced = Vec::new();
        for m in self.demand_models() {
            let mut remaining = 1.0_f64; // fraction of the model's load unserved
            let mut per_tier = vec![0u32; self.cfg.tiers.len()];
            for (t, tier) in self.cfg.tiers.iter().enumerate() {
                if remaining <= 1e-9 {
                    break;
                }
                let Some(load) = self.model_load(m, tier.gpu) else {
                    continue;
                };
                if load <= 1e-12 {
                    remaining = 0.0;
                    break;
                }
                let want = (remaining * load / util - 1e-9).ceil().max(0.0) as u32;
                let take = want.min(free[t]);
                if take == 0 {
                    continue;
                }
                free[t] -= take;
                per_tier[t] += take;
                remaining -= take as f64 * util / load;
            }
            if remaining > 1e-9 {
                unplaced.push(m);
            }
            if per_tier.iter().any(|&k| k > 0) {
                allocations.push(ModelAllocation { model: m, per_tier });
            }
        }
        Placement {
            allocations,
            unplaced,
        }
    }

    /// The tier holding most of an allocation's devices (ties break
    /// toward the preferred tier) — its perf represents the model.
    fn representative_tier(&self, alloc: &ModelAllocation) -> GpuKind {
        let mut best_t = 0usize;
        let mut best_k = 0u32;
        for (t, &k) in alloc.per_tier.iter().enumerate() {
            if k > best_k {
                best_k = k;
                best_t = t;
            }
        }
        self.cfg.tiers[best_t].gpu
    }

    /// Per-class predictions for a placement: a synthetic per-device
    /// virtual queue (deadline order, one SLO-window of sized-rate
    /// arrivals per class) priced by the RWT estimator. Returns the
    /// rows plus whether every placed class meets its deadline.
    fn predict(&self, placement: &Placement) -> (Vec<ClassPrediction>, bool) {
        let mut classes = Vec::new();
        let mut all_ok = true;
        for alloc in &placement.allocations {
            let n = alloc.total().max(1);
            let Some(perf) = self.perf(self.representative_tier(alloc), alloc.model) else {
                continue;
            };
            let mut ds: Vec<&ClassDemand> = self
                .demands
                .iter()
                .filter(|d| d.model == alloc.model)
                .collect();
            ds.sort_by(|a, b| a.class.cmp(&b.class).then(a.mega.cmp(&b.mega)));
            let groups: Vec<RequestGroup> = ds
                .iter()
                .enumerate()
                .map(|(i, d)| {
                    let rate = d.rate_eff(self.cfg.peak_slo_cutoff_s);
                    let len =
                        ((rate * d.class.target().ttft_s / n as f64).ceil() as usize).max(1);
                    RequestGroup {
                        id: GroupId(i as u64),
                        model: d.model,
                        class: d.class,
                        slo: d.class.target(),
                        earliest_arrival_s: 0.0,
                        members: (0..len as u64).collect(),
                        mega: d.mega,
                    }
                })
                .collect();
            let refs: Vec<&RequestGroup> = groups.iter().collect();
            let est = self.estimator.estimate_queue(&refs, &perf, Some(alloc.model), |_| 0.0);
            for ((d, g), e) in ds.iter().zip(&groups).zip(&est) {
                let ok = e.completion_mean_s <= g.slo.ttft_s;
                all_ok &= ok;
                classes.push(ClassPrediction {
                    model: d.model,
                    class: d.class,
                    mega: d.mega,
                    rate: d.rate_eff(self.cfg.peak_slo_cutoff_s),
                    predicted_s: e.completion_mean_s,
                    slo_s: g.slo.ttft_s,
                    ok,
                });
            }
        }
        for &m in &placement.unplaced {
            for d in self.demands.iter().filter(|d| d.model == m) {
                all_ok = false;
                classes.push(ClassPrediction {
                    model: d.model,
                    class: d.class,
                    mega: d.mega,
                    rate: d.rate_eff(self.cfg.peak_slo_cutoff_s),
                    predicted_s: f64::INFINITY,
                    slo_s: d.class.target().ttft_s,
                    ok: false,
                });
            }
        }
        (classes, all_ok)
    }

    /// Can `counts` devices per tier absorb the load *and* keep every
    /// class's predicted completion inside its SLO? Monotone in each
    /// count: more devices only shrink per-device backlog windows.
    fn feasible(&self, counts: &[u32]) -> bool {
        let placement = self.place(counts);
        if !placement.unplaced.is_empty() {
            return false;
        }
        self.predict(&placement).1
    }

    /// Minimal count for tier `t` holding every other tier at `counts`
    /// (feasibility is monotone in each coordinate, so binary search).
    fn min_count_for_tier(&self, counts: &[u32], t: usize) -> u32 {
        let feas = |c: u32| {
            let mut v = counts.to_vec();
            v[t] = c;
            self.feasible(&v)
        };
        if feas(0) {
            return 0;
        }
        let (mut lo, mut hi) = (0u32, counts[t]);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if feas(mid) {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }

    /// Binary-search the minimal fleet (per tier) that absorbs the
    /// workload, then report predicted per-class attainment on it.
    pub fn plan(&self) -> CapacityPlan {
        let max: Vec<u32> = self.cfg.tiers.iter().map(|t| t.max).collect();
        if !self.feasible(&max) {
            // Even the maximal fleet cannot meet every SLO: report it
            // as-is — `qlm plan` points the operator at admission
            // control (shed batch classes) or a catalog change.
            return self.render_plan(max);
        }
        let mut counts = max;
        loop {
            let before = counts.clone();
            for t in (0..counts.len()).rev() {
                counts[t] = self.min_count_for_tier(&counts, t);
            }
            if counts == before {
                break;
            }
        }
        self.render_plan(counts)
    }

    fn render_plan(&self, counts: Vec<u32>) -> CapacityPlan {
        let placement = self.place(&counts);
        let (classes, classes_ok) = self.predict(&placement);
        CapacityPlan {
            tiers: self
                .cfg
                .tiers
                .iter()
                .zip(&counts)
                .map(|(t, &n)| (t.gpu, n))
                .collect(),
            feasible: placement.unplaced.is_empty() && classes_ok,
            allocations: placement.allocations,
            unplaced: placement.unplaced,
            classes,
        }
    }

    /// Human-readable plan for the `qlm plan` CLI.
    pub fn render(&self, plan: &CapacityPlan) -> String {
        let mut out = String::new();
        let fleet: Vec<String> = plan
            .tiers
            .iter()
            .map(|&(g, n)| format!("{n}x {}", g.name()))
            .collect();
        out.push_str(&format!(
            "recommended fleet: {} ({} devices total)\n",
            fleet.join(" + "),
            plan.total_devices()
        ));
        for a in &plan.allocations {
            let per: Vec<String> = a
                .per_tier
                .iter()
                .zip(&self.cfg.tiers)
                .filter(|(&k, _)| k > 0)
                .map(|(&k, t)| format!("{k}x {}", t.gpu.name()))
                .collect();
            out.push_str(&format!(
                "  {:<20} {}\n",
                self.catalog.get(a.model).name,
                per.join(" + ")
            ));
        }
        out.push_str("predicted attainment (mean completion vs SLO):\n");
        for c in &plan.classes {
            out.push_str(&format!(
                "  {:<20} {:<12} {:6.2} req/s  predicted {:8.2}s / slo {:6.0}s  {}\n",
                self.catalog.get(c.model).name,
                c.class.name(),
                c.rate,
                c.predicted_s,
                c.slo_s,
                if c.ok { "ok" } else { "VIOLATED" },
            ));
        }
        for &m in &plan.unplaced {
            out.push_str(&format!(
                "  {}: no allowed tier can absorb this model — enable admission \
                 control (shed batch classes) or extend the device catalog\n",
                self.catalog.get(m).name
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ModelCatalog;
    use crate::workload::WorkloadSpec;

    fn planner_for(rate: f64, tiers: Vec<TierSpec>) -> CapacityPlanner {
        let spec = WorkloadSpec::w_a(ModelId(1), rate, 2000);
        CapacityPlanner::from_spec(
            &spec,
            ModelCatalog::paper(),
            PlannerConfig {
                tiers,
                ..Default::default()
            },
            7,
        )
    }

    fn a100(max: u32) -> TierSpec {
        TierSpec {
            gpu: GpuKind::A100,
            max,
        }
    }

    fn a10(max: u32) -> TierSpec {
        TierSpec {
            gpu: GpuKind::A10,
            max,
        }
    }

    #[test]
    fn plan_feasible_and_minimal_shape() {
        let p = planner_for(10.0, vec![a100(16)]);
        let plan = p.plan();
        assert!(plan.feasible, "{plan:?}");
        let n = plan.total_devices();
        assert!(n >= 1 && n < 16, "n={n}");
        // Minimality: one device fewer must be infeasible.
        assert!(n == 1 || !p.feasible(&[n - 1]));
        assert!(plan.unplaced.is_empty());
        assert!(plan.classes.iter().all(|c| c.ok), "{:?}", plan.classes);
    }

    #[test]
    fn plan_monotone_in_rate() {
        let mut last = 0;
        for rate in [2.0, 6.0, 12.0, 24.0, 48.0] {
            let n = planner_for(rate, vec![a100(64)]).plan().total_devices();
            assert!(n >= last, "rate {rate}: {n} < {last}");
            last = n;
        }
        assert!(last >= 2, "48 req/s on Vicuna-13B needs a real fleet");
    }

    #[test]
    fn vicuna_cannot_be_planned_on_a10_alone() {
        // Vicuna-13B (24.2 GiB) exceeds an A10's usable 21.6 GiB.
        let p = planner_for(5.0, vec![a10(32)]);
        let plan = p.plan();
        assert!(!plan.feasible);
        assert_eq!(plan.unplaced, vec![ModelId(1)]);
        assert!(plan.classes.iter().all(|c| !c.ok && c.predicted_s.is_infinite()));
    }

    #[test]
    fn scarce_preferred_tier_spills_to_secondary() {
        // Mistral-7B fits both tiers; capping A100s at 1 under heavy
        // load must spill onto A10s rather than fail.
        let spec = WorkloadSpec::w_a(ModelId(0), 60.0, 2000);
        let p = CapacityPlanner::from_spec(
            &spec,
            ModelCatalog::paper(),
            PlannerConfig {
                tiers: vec![a100(1), a10(64)],
                ..Default::default()
            },
            9,
        );
        let plan = p.plan();
        assert!(plan.feasible, "{plan:?}");
        assert!(plan.count(GpuKind::A10) >= 1, "{plan:?}");
        let alloc = &plan.allocations[0];
        assert_eq!(alloc.model, ModelId(0));
        assert_eq!(alloc.total(), plan.total_devices());
    }

    #[test]
    fn multi_model_demand_partitions_devices() {
        let spec = WorkloadSpec::w_b(vec![ModelId(3)], vec![ModelId(5)], 8.0, 2000);
        let p = CapacityPlanner::from_spec(
            &spec,
            ModelCatalog::paper_multi_model(),
            PlannerConfig {
                tiers: vec![a100(32)],
                ..Default::default()
            },
            11,
        );
        let plan = p.plan();
        assert!(plan.feasible, "{plan:?}");
        assert_eq!(plan.allocations.len(), 2);
        let total: u32 = plan.allocations.iter().map(|a| a.total()).sum();
        assert_eq!(total, plan.total_devices());
    }

    #[test]
    fn plan_is_deterministic() {
        let a = planner_for(12.0, vec![a100(32), a10(32)]).plan();
        let b = planner_for(12.0, vec![a100(32), a10(32)]).plan();
        assert_eq!(a.tiers, b.tiers);
        assert_eq!(a.total_devices(), b.total_devices());
    }
}
