//! Runtime autoscaler — a local serving operation that turns the RWT
//! estimator's pressure signal into fleet-size actions. The engine
//! evaluates it once per global-scheduler pass: per-class backlog is
//! converted to a predicted drain time (pending output tokens over the
//! fleet's aggregate Θ, classes served in deadline order), and the
//! autoscaler decides — with hysteresis on both edges plus a cooldown —
//! whether to provision a new instance or drain one.
//!
//! Scale-up pays a realistic cold start (weight staging priced by the
//! perf model; the engine wires the delay), so the breach streak keeps
//! one transient spike from over-provisioning. Scale-down only ever
//! *drains*: the victim stops receiving work and leaves once its
//! running batch completes — no mid-flight kills, no lost requests.

use crate::backend::{GpuKind, ModelId};
use crate::workload::SloClass;

/// Autoscaler knobs (hysteresis lives here, wired from `SimConfig`).
#[derive(Debug, Clone, Copy)]
pub struct AutoscaleConfig {
    /// Never drain below this many active instances.
    pub min_instances: u32,
    /// Never provision beyond this many (active + warming).
    pub max_instances: u32,
    /// Device tier provisioned instances use.
    pub gpu: GpuKind,
    /// Scale up when some class's predicted drain time exceeds
    /// `up_frac` × its SLO for `breach_passes` consecutive evaluations.
    pub up_frac: f64,
    /// Scale down when *every* class's drain time sits below
    /// `down_frac` × its SLO for `calm_passes` evaluations and an
    /// instance is idle.
    pub down_frac: f64,
    pub breach_passes: u32,
    pub calm_passes: u32,
    /// Minimum simulated seconds between any two scale actions.
    pub cooldown_s: f64,
    /// Instances provisioned per scale-up action.
    pub step: u32,
}

impl AutoscaleConfig {
    pub fn bounded(min_instances: u32, max_instances: u32, gpu: GpuKind) -> Self {
        AutoscaleConfig {
            min_instances: min_instances.max(1),
            max_instances: max_instances.max(min_instances.max(1)),
            gpu,
            up_frac: 0.5,
            down_frac: 0.1,
            breach_passes: 3,
            calm_passes: 40,
            cooldown_s: 30.0,
            step: 1,
        }
    }
}

/// One SLO class's backlog pressure, computed by the engine each pass.
#[derive(Debug, Clone, Copy)]
pub struct ClassPressure {
    pub class: SloClass,
    /// Waiting (+ evicted) requests of this class.
    pub waiting: usize,
    /// Predicted seconds to drain this class's pending output tokens —
    /// including every tighter-deadline class served ahead of it — at
    /// the fleet's aggregate Θ.
    pub drain_s: f64,
    /// The class's most-backlogged model (scale-up warms this one).
    pub hottest_model: Option<ModelId>,
}

/// What the engine should do this pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScaleDecision {
    /// Provision `count` instances, pre-staging `model`'s weights.
    Up { count: u32, model: ModelId },
    /// Drain one instance (no mid-flight kills).
    Down,
    Hold,
}

/// The autoscaler state machine.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    breach_streak: u32,
    calm_streak: u32,
    last_action_t: f64,
    pub scale_ups: u64,
    pub scale_downs: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Autoscaler {
            cfg,
            breach_streak: 0,
            calm_streak: 0,
            last_action_t: f64::NEG_INFINITY,
            scale_ups: 0,
            scale_downs: 0,
        }
    }

    /// Evaluate one scheduler pass. `active` counts alive non-draining
    /// instances; `warming` counts provisioned-but-not-ready ones (they
    /// gate further scale-ups so a cold-start window isn't treated as
    /// persistent under-capacity); `draining` counts still-powered
    /// instances finishing their last batch — they occupy the
    /// `max_instances` budget until they actually leave, so the
    /// powered-on fleet never exceeds the configured cap.
    pub fn decide(
        &mut self,
        now: f64,
        pressures: &[ClassPressure],
        active: u32,
        warming: u32,
        draining: u32,
        any_idle: bool,
    ) -> ScaleDecision {
        let breached = pressures
            .iter()
            .any(|p| p.waiting > 0 && p.drain_s > p.class.target().ttft_s * self.cfg.up_frac);
        let calm = pressures
            .iter()
            .all(|p| p.drain_s < p.class.target().ttft_s * self.cfg.down_frac);
        if breached {
            self.breach_streak += 1;
            self.calm_streak = 0;
        } else {
            self.breach_streak = 0;
            if calm {
                self.calm_streak += 1;
            } else {
                self.calm_streak = 0;
            }
        }
        if now - self.last_action_t < self.cfg.cooldown_s {
            return ScaleDecision::Hold;
        }
        let powered = active + warming + draining;
        if self.breach_streak >= self.cfg.breach_passes
            && warming == 0
            && powered < self.cfg.max_instances
        {
            // Warm the model of the tightest breaching class *with a
            // tier-hostable backlog* — `drain_s` is cumulative down the
            // deadline order, so a max-by-drain pick would always name
            // the loosest class; and a class whose backlog cannot fit
            // the provisioned tier (hottest_model == None) must not
            // block relief for one that can. Only when *no* backlogged
            // class has a hostable model does provisioning hold —
            // capacity cannot help, and admission control takes over.
            let model = pressures
                .iter()
                .filter(|p| p.waiting > 0 && p.hottest_model.is_some())
                .find(|p| p.drain_s > p.class.target().ttft_s * self.cfg.up_frac)
                .or_else(|| {
                    pressures
                        .iter()
                        .find(|p| p.waiting > 0 && p.hottest_model.is_some())
                })
                .and_then(|p| p.hottest_model);
            if let Some(model) = model {
                let count = self.cfg.step.min(self.cfg.max_instances - powered);
                self.breach_streak = 0;
                self.last_action_t = now;
                self.scale_ups += count as u64;
                return ScaleDecision::Up { count, model };
            }
        }
        if self.calm_streak >= self.cfg.calm_passes
            && any_idle
            && warming == 0
            && draining == 0
            && active > self.cfg.min_instances
        {
            self.calm_streak = 0;
            self.last_action_t = now;
            self.scale_downs += 1;
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pressure(class: SloClass, waiting: usize, drain_s: f64) -> ClassPressure {
        ClassPressure {
            class,
            waiting,
            drain_s,
            hottest_model: Some(ModelId(3)),
        }
    }

    fn hot() -> Vec<ClassPressure> {
        vec![pressure(SloClass::Interactive, 50, 15.0)] // 15 > 0.5 × 20
    }

    fn cold() -> Vec<ClassPressure> {
        vec![pressure(SloClass::Interactive, 0, 0.0)]
    }

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            breach_passes: 3,
            calm_passes: 2,
            cooldown_s: 10.0,
            ..AutoscaleConfig::bounded(1, 4, GpuKind::A100)
        }
    }

    #[test]
    fn scale_up_needs_consecutive_breaches() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(0.0, &hot(), 1, 0, 0, false), ScaleDecision::Hold);
        assert_eq!(a.decide(1.0, &hot(), 1, 0, 0, false), ScaleDecision::Hold);
        match a.decide(2.0, &hot(), 1, 0, 0, false) {
            ScaleDecision::Up { count: 1, model } => assert_eq!(model, ModelId(3)),
            other => panic!("expected Up, got {other:?}"),
        }
        assert_eq!(a.scale_ups, 1);
    }

    #[test]
    fn breach_streak_resets_on_quiet_pass() {
        let mut a = Autoscaler::new(cfg());
        a.decide(0.0, &hot(), 1, 0, 0, false);
        a.decide(1.0, &hot(), 1, 0, 0, false);
        a.decide(2.0, &cold(), 1, 0, 0, false); // resets the streak
        assert_eq!(a.decide(3.0, &hot(), 1, 0, 0, false), ScaleDecision::Hold);
        assert_eq!(a.decide(4.0, &hot(), 1, 0, 0, false), ScaleDecision::Hold);
        assert!(matches!(a.decide(5.0, &hot(), 1, 0, 0, false), ScaleDecision::Up { .. }));
    }

    #[test]
    fn cooldown_blocks_back_to_back_actions() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..3 {
            a.decide(t as f64, &hot(), 1, 0, 0, false);
        }
        assert_eq!(a.scale_ups, 1);
        // Immediately hot again: cooldown (10 s) holds the line.
        for t in 3..10 {
            assert_eq!(a.decide(t as f64, &hot(), 2, 0, 0, false), ScaleDecision::Hold);
        }
        // Past the cooldown the accumulated streak may fire again.
        assert!(matches!(a.decide(13.0, &hot(), 2, 0, 0, false), ScaleDecision::Up { .. }));
    }

    #[test]
    fn warming_instances_gate_scale_up() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(
                a.decide(t as f64, &hot(), 1, 1, 0, false),
                ScaleDecision::Hold,
                "a warming instance must absorb the breach first"
            );
        }
    }

    #[test]
    fn max_instances_caps_growth() {
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(a.decide(t as f64, &hot(), 4, 0, 0, false), ScaleDecision::Hold);
        }
    }

    #[test]
    fn scale_up_warms_the_tightest_breaching_class() {
        // drain_s is cumulative, so Batch2 always carries the largest
        // drain; the pick must still follow the class actually past its
        // own threshold (interactive here: 15 > 0.5×20; batch-2's 500 is
        // well under 0.5×3600).
        let mut a = Autoscaler::new(cfg());
        let p = vec![
            ClassPressure {
                class: SloClass::Interactive,
                waiting: 50,
                drain_s: 15.0,
                hottest_model: Some(ModelId(0)),
            },
            ClassPressure {
                class: SloClass::Batch2,
                waiting: 10,
                drain_s: 500.0,
                hottest_model: Some(ModelId(5)),
            },
        ];
        a.decide(0.0, &p, 1, 0, 0, false);
        a.decide(1.0, &p, 1, 0, 0, false);
        match a.decide(2.0, &p, 1, 0, 0, false) {
            ScaleDecision::Up { model, .. } => assert_eq!(model, ModelId(0)),
            other => panic!("expected Up, got {other:?}"),
        }
    }

    #[test]
    fn no_tier_hostable_backlog_means_hold() {
        // hottest_model is None when nothing backlogged fits the
        // provisionable tier: capacity cannot relieve the breach, so the
        // autoscaler must not burn devices on it.
        let mut a = Autoscaler::new(cfg());
        let p = vec![ClassPressure {
            class: SloClass::Interactive,
            waiting: 50,
            drain_s: 15.0,
            hottest_model: None,
        }];
        for t in 0..10 {
            assert_eq!(a.decide(t as f64, &p, 1, 0, 0, false), ScaleDecision::Hold);
        }
        assert_eq!(a.scale_ups, 0);
    }

    #[test]
    fn draining_instances_occupy_the_cap_and_block_further_drains() {
        // 3 active + 1 draining = 4 powered: a new breach must not push
        // the powered-on fleet past max_instances.
        let mut a = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(a.decide(t as f64, &hot(), 3, 0, 1, false), ScaleDecision::Hold);
        }
        // And one drain at a time: calm with a drain in flight holds.
        let mut b = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(b.decide(t as f64, &cold(), 3, 0, 1, true), ScaleDecision::Hold);
        }
    }

    #[test]
    fn scale_down_needs_calm_idle_and_floor() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(0.0, &cold(), 2, 0, 0, true), ScaleDecision::Hold);
        assert_eq!(a.decide(1.0, &cold(), 2, 0, 0, true), ScaleDecision::Down);
        assert_eq!(a.scale_downs, 1);
        // At the floor: never drain.
        let mut b = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(b.decide(t as f64, &cold(), 1, 0, 0, true), ScaleDecision::Hold);
        }
        // No idle instance: hold even when calm.
        let mut c = Autoscaler::new(cfg());
        for t in 0..10 {
            assert_eq!(c.decide(t as f64, &cold(), 3, 0, 0, false), ScaleDecision::Hold);
        }
    }
}
