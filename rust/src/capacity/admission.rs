//! Admission control — the last resort when even the maximal fleet
//! cannot meet a class's SLO (§9: beyond this point the paper's answer
//! is "add GPUs"; when there are none to add, the only honest move is
//! to shed load). Batch-class requests are refused at *submit* time —
//! they are recorded as shed, never grouped, and never reach the global
//! scheduler, so a hopeless backlog cannot poison `total_penalty_s` for
//! the requests that still have a chance. Interactive traffic is never
//! shed.
//!
//! The controller is also the single accounting path for *unservable*
//! groups (`Assignment::unservable` — no instance can serve the model):
//! the engine retires their waiting members through the same shed
//! bookkeeping, so a request is counted exactly once no matter which
//! path refused it.

use crate::workload::SloClass;

/// Admission-control knobs (wired from `SimConfig`).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Master switch for submit-time shedding. Unservable-group
    /// accounting is always on — a group no instance can serve has no
    /// other exit.
    pub enabled: bool,
    /// Start shedding a batch class when its predicted drain time
    /// exceeds `shed_frac` × its SLO while the fleet cannot grow.
    pub shed_frac: f64,
    /// Stop shedding once the drain time falls back below
    /// `resume_frac` × SLO (hysteresis gap keeps the gate from
    /// chattering).
    pub resume_frac: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            enabled: false,
            shed_frac: 2.0,
            resume_frac: 1.0,
        }
    }
}

impl AdmissionConfig {
    pub fn enabled() -> Self {
        AdmissionConfig {
            enabled: true,
            ..Default::default()
        }
    }
}

/// Per-class shed gate + shared shed accounting.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    pub cfg: AdmissionConfig,
    /// Gate per SLO class (indexed by [`SloClass::index`]).
    shedding: [bool; SloClass::ALL.len()],
    /// Requests refused at submit time.
    pub shed_submits: u64,
    /// Requests retired because their group was unservable.
    pub shed_unservable: u64,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController {
            cfg,
            shedding: [false; SloClass::ALL.len()],
            shed_submits: 0,
            shed_unservable: 0,
        }
    }

    /// Re-evaluate the gates from this pass's per-class drain estimates.
    /// `fleet_maxed` is true when the fleet cannot grow (no autoscaler,
    /// or the autoscaler is at `max_instances`) — shedding while
    /// capacity could still be added would throw work away early.
    pub fn update(&mut self, drains: &[(SloClass, f64)], fleet_maxed: bool) {
        if !self.cfg.enabled {
            return;
        }
        for &(class, drain_s) in drains {
            if class == SloClass::Interactive {
                continue; // interactive traffic is never shed
            }
            let slo = class.target().ttft_s;
            let gate = &mut self.shedding[class.index()];
            if fleet_maxed && drain_s > self.cfg.shed_frac * slo {
                *gate = true;
            } else if drain_s < self.cfg.resume_frac * slo {
                *gate = false;
            }
        }
    }

    /// Should a request of `class` be refused right now?
    pub fn should_shed(&self, class: SloClass) -> bool {
        self.cfg.enabled && self.shedding[class.index()]
    }

    pub fn note_shed_submit(&mut self) {
        self.shed_submits += 1;
    }

    pub fn note_shed_unservable(&mut self, n: u64) {
        self.shed_unservable += n;
    }

    pub fn total_shed(&self) -> u64 {
        self.shed_submits + self.shed_unservable
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> AdmissionController {
        AdmissionController::new(AdmissionConfig::enabled())
    }

    #[test]
    fn disabled_controller_never_sheds() {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        c.update(&[(SloClass::Batch1, 1e9)], true);
        assert!(!c.should_shed(SloClass::Batch1));
    }

    #[test]
    fn sheds_batch_class_only_when_fleet_maxed() {
        let mut c = ctl();
        let hopeless = [(SloClass::Batch1, 10_000.0)]; // ≫ 2 × 60 s
        c.update(&hopeless, false);
        assert!(!c.should_shed(SloClass::Batch1), "fleet can still grow");
        c.update(&hopeless, true);
        assert!(c.should_shed(SloClass::Batch1));
        assert!(!c.should_shed(SloClass::Batch2), "other classes untouched");
    }

    #[test]
    fn interactive_is_never_shed() {
        let mut c = ctl();
        c.update(&[(SloClass::Interactive, 1e9)], true);
        assert!(!c.should_shed(SloClass::Interactive));
    }

    #[test]
    fn hysteresis_gap_between_shed_and_resume() {
        let mut c = ctl();
        c.update(&[(SloClass::Batch2, 3.0 * 3600.0)], true);
        assert!(c.should_shed(SloClass::Batch2));
        // Between resume (1×) and shed (2×) thresholds: gate holds.
        c.update(&[(SloClass::Batch2, 1.5 * 3600.0)], true);
        assert!(c.should_shed(SloClass::Batch2));
        // Below the resume threshold: gate opens again.
        c.update(&[(SloClass::Batch2, 0.5 * 3600.0)], true);
        assert!(!c.should_shed(SloClass::Batch2));
    }

    #[test]
    fn shed_accounting_sums() {
        let mut c = ctl();
        c.note_shed_submit();
        c.note_shed_submit();
        c.note_shed_unservable(3);
        assert_eq!(c.total_shed(), 5);
        assert_eq!((c.shed_submits, c.shed_unservable), (2, 3));
    }
}
