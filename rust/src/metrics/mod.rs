//! Run metrics: SLO attainment (TTFT ∧ TPOT), request throughput,
//! latency percentiles, device utilization — the quantities every
//! evaluation figure reports.

use std::collections::BTreeMap;

use crate::backend::{Instance, ModelId};
use crate::coordinator::request::{Request, RequestState};
use crate::coordinator::GlobalQueue;
use crate::workload::{SloClass, SloTarget};

/// A per-request latency dimension the run can be summarized over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Time to first token (queueing + prefill).
    Ttft,
    /// Time per output token after the first (decode cadence).
    Tpot,
    /// End-to-end latency, arrival to completion.
    E2e,
}

/// Final record for one request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub model: ModelId,
    pub class: SloClass,
    pub slo: SloTarget,
    pub arrival_s: f64,
    pub first_token_s: Option<f64>,
    pub completed_s: Option<f64>,
    /// Output tokens actually produced.
    pub generated: u32,
    pub mega: bool,
    /// Refused by admission control (or retired as unservable): never
    /// served, counted as an SLO violation like any unserved request.
    pub shed: bool,
}

impl RequestRecord {
    pub fn from_request(r: &Request) -> Self {
        RequestRecord {
            id: r.id,
            model: r.model,
            class: r.class,
            slo: r.slo,
            arrival_s: r.arrival_s,
            first_token_s: r.first_token_s,
            completed_s: r.completed_s,
            generated: r.generated,
            mega: r.mega,
            shed: r.state == RequestState::Shed,
        }
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }

    /// Mean time per output token after the first; defined only for
    /// completed requests.
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_s, self.completed_s) {
            (Some(first), Some(done)) => {
                Some((done - first) / self.generated.saturating_sub(1).max(1) as f64)
            }
            _ => None,
        }
    }

    /// End-to-end latency; defined only for completed requests.
    pub fn e2e(&self) -> Option<f64> {
        self.completed_s.map(|t| t - self.arrival_s)
    }

    pub fn metric(&self, m: Metric) -> Option<f64> {
        match m {
            Metric::Ttft => self.ttft(),
            Metric::Tpot => self.tpot(),
            Metric::E2e => self.e2e(),
        }
    }

    /// First token within the TTFT bound. Requests that never produced a
    /// first token are violations.
    pub fn ttft_met(&self) -> bool {
        self.ttft().map(|t| t <= self.slo.ttft_s).unwrap_or(false)
    }

    /// Decode cadence within the TPOT bound. Requests that never
    /// completed are violations.
    pub fn tpot_met(&self) -> bool {
        self.tpot().map(|t| t <= self.slo.tpot_s).unwrap_or(false)
    }

    /// SLO met ⇔ both latency dimensions within bound (TTFT ∧ TPOT).
    pub fn slo_met(&self) -> bool {
        self.ttft_met() && self.tpot_met()
    }
}

/// Aggregated per-instance counters.
#[derive(Debug, Clone, Default)]
pub struct InstanceMetrics {
    pub id: u32,
    pub busy_s: f64,
    pub idle_s: f64,
    pub swap_s: f64,
    pub tokens_generated: u64,
    pub requests_completed: u64,
    pub internal_preemptions: u64,
    pub lso_evictions: u64,
    pub model_swaps: u64,
    pub mean_batch: f64,
}

/// O(1)-memory completion accounting for compact-records runs: when the
/// broker runs in compact mode (gigascale benches), acked requests are
/// dropped instead of archived, so the engine folds each completion
/// into this tally before the ack. Aggregates only — per-request
/// percentiles need full records.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CompactTally {
    pub completed: usize,
    /// Completions whose TTFT met the request's bound.
    pub ttft_met: usize,
    pub ttft_sum_s: f64,
    pub tokens_generated: u64,
}

impl CompactTally {
    /// Fold one completion (called with the request's fields *before*
    /// the ack removes it from the broker).
    pub fn note(
        &mut self,
        arrival_s: f64,
        first_token_s: Option<f64>,
        ttft_slo_s: f64,
        generated: u32,
    ) {
        self.completed += 1;
        self.tokens_generated += generated as u64;
        if let Some(ft) = first_token_s {
            let ttft = ft - arrival_s;
            self.ttft_sum_s += ttft;
            if ttft <= ttft_slo_s {
                self.ttft_met += 1;
            }
        }
    }

    pub fn mean_ttft(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.ttft_sum_s / self.completed as f64
        }
    }

    /// TTFT attainment over completions (vacuously 1.0 when empty).
    pub fn ttft_attainment(&self) -> f64 {
        if self.completed == 0 {
            1.0
        } else {
            self.ttft_met as f64 / self.completed as f64
        }
    }
}

/// Complete metrics for one simulated (or real) run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub policy: String,
    pub records: Vec<RequestRecord>,
    pub instances: Vec<InstanceMetrics>,
    pub duration_s: f64,
    /// Wall-clock spent inside the global scheduler (overhead, Fig. 20).
    pub scheduler_wall_s: f64,
    pub scheduler_invocations: u64,
    /// Σ over instances of (decommission − commission) simulated time —
    /// the provisioning cost an autoscaled run is judged by. For a
    /// static fleet this is `fleet size × duration`.
    pub device_seconds: f64,
    /// Autoscaler actions taken during the run.
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Completion aggregates for compact-records runs (`None` on normal
    /// runs, where `records` holds every completion individually).
    pub compact: Option<CompactTally>,
    /// Per-model shard passes the scheduler actually scanned vs. skipped
    /// as provably clean (per-shard dirt tracking). Overhead telemetry,
    /// deterministic but excluded from the digest — like
    /// `scheduler_wall_s`, it describes how the run was computed, not
    /// what was served.
    pub shards_scanned: u64,
    pub shards_skipped: u64,
}

impl RunMetrics {
    /// Fraction of requests meeting both SLO dimensions, over all
    /// requests.
    pub fn slo_attainment(&self) -> f64 {
        self.attainment_where(|r| r.slo_met(), |_| true)
    }

    /// SLO attainment restricted to one class.
    pub fn slo_attainment_class(&self, class: SloClass) -> f64 {
        self.attainment_where(|r| r.slo_met(), |r| r.class == class)
    }

    /// Fraction of requests whose first token met the TTFT bound.
    pub fn ttft_attainment(&self) -> f64 {
        self.attainment_where(|r| r.ttft_met(), |_| true)
    }

    pub fn ttft_attainment_class(&self, class: SloClass) -> f64 {
        self.attainment_where(|r| r.ttft_met(), |r| r.class == class)
    }

    /// Fraction of requests whose decode cadence met the TPOT bound.
    pub fn tpot_attainment(&self) -> f64 {
        self.attainment_where(|r| r.tpot_met(), |_| true)
    }

    pub fn tpot_attainment_class(&self, class: SloClass) -> f64 {
        self.attainment_where(|r| r.tpot_met(), |r| r.class == class)
    }

    fn attainment_where(
        &self,
        met: impl Fn(&RequestRecord) -> bool,
        scope: impl Fn(&RequestRecord) -> bool,
    ) -> f64 {
        let mut total = 0usize;
        let mut ok = 0usize;
        for r in self.records.iter().filter(|r| scope(r)) {
            total += 1;
            if met(r) {
                ok += 1;
            }
        }
        if total == 0 {
            return 1.0;
        }
        ok as f64 / total as f64
    }

    /// Completed requests per second over the run.
    pub fn throughput_rps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.completed_count() as f64 / self.duration_s
    }

    /// Generated tokens per second (cluster aggregate).
    pub fn token_throughput(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.instances
            .iter()
            .map(|i| i.tokens_generated)
            .sum::<u64>() as f64
            / self.duration_s
    }

    /// Percentile of a latency dimension over requests where it is
    /// defined (TTFT: first token produced; TPOT/E2E: completed).
    pub fn percentile(&self, m: Metric, p: f64) -> f64 {
        let ts: Vec<f64> = self.records.iter().filter_map(|r| r.metric(m)).collect();
        crate::util::percentile(&ts, p)
    }

    /// Percentile of a latency dimension restricted to one class.
    pub fn percentile_class(&self, m: Metric, p: f64, class: SloClass) -> f64 {
        let ts: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.class == class)
            .filter_map(|r| r.metric(m))
            .collect();
        crate::util::percentile(&ts, p)
    }

    /// Mean of a latency dimension over requests where it is defined.
    pub fn mean(&self, m: Metric) -> f64 {
        let ts: Vec<f64> = self.records.iter().filter_map(|r| r.metric(m)).collect();
        crate::util::mean(&ts)
    }

    /// TTFT percentile over requests that produced a first token.
    pub fn ttft_percentile(&self, p: f64) -> f64 {
        self.percentile(Metric::Ttft, p)
    }

    pub fn mean_ttft(&self) -> f64 {
        self.mean(Metric::Ttft)
    }

    /// Mean device utilization (busy / wall) across instances.
    pub fn mean_utilization(&self) -> f64 {
        if self.instances.is_empty() {
            return 0.0;
        }
        let us: Vec<f64> = self
            .instances
            .iter()
            .map(|i| {
                let t = i.busy_s + i.idle_s + i.swap_s;
                if t > 0.0 {
                    i.busy_s / t
                } else {
                    0.0
                }
            })
            .collect();
        crate::util::mean(&us)
    }

    pub fn total_model_swaps(&self) -> u64 {
        self.instances.iter().map(|i| i.model_swaps).sum()
    }

    pub fn total_evictions(&self) -> u64 {
        self.instances.iter().map(|i| i.lso_evictions).sum()
    }

    /// KV-overflow preemptions inside instances (vLLM-internal recompute
    /// /swap events) — the preemption column of the `qlm compare` table.
    pub fn total_internal_preemptions(&self) -> u64 {
        self.instances.iter().map(|i| i.internal_preemptions).sum()
    }

    /// Completions: per-request records plus (in compact mode) the
    /// tally of acked-and-dropped requests.
    pub fn completed_count(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.completed_s.is_some())
            .count()
            + self.compact.as_ref().map_or(0, |t| t.completed)
    }

    /// Requests refused by admission control / unservable retirement.
    pub fn shed_count(&self) -> usize {
        self.records.iter().filter(|r| r.shed).count()
    }

    /// Device-hours consumed (provisioning cost, Fig. 1's axis).
    pub fn device_hours(&self) -> f64 {
        self.device_seconds / 3600.0
    }

    /// Mean TTFT per model — used by heterogeneity analyses. `BTreeMap`
    /// so callers that iterate (figures, reports) see model-id order.
    pub fn ttft_by_model(&self) -> BTreeMap<ModelId, f64> {
        let mut acc: BTreeMap<ModelId, Vec<f64>> = BTreeMap::new();
        for r in &self.records {
            if let Some(t) = r.ttft() {
                acc.entry(r.model).or_default().push(t);
            }
        }
        acc.into_iter()
            .map(|(m, v)| (m, crate::util::mean(&v)))
            .collect()
    }

    /// FNV-1a over every deterministic field of the run: per-request
    /// outcomes (records are sorted by id in the engine's `finish`),
    /// autoscaler actions, the device-seconds ledger, and the scheduler
    /// invocation count. Wall-clock fields (`scheduler_wall_s`) are
    /// excluded; everything the paper's figures are computed from is
    /// included. The one digest behind the golden-equivalence suite and
    /// the `qlm compare --threads-sweep` equality check — two runs with
    /// equal digests served identical traffic identically.
    pub fn digest(&self) -> u64 {
        const PRIME: u64 = 0x100000001b3;
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(PRIME);
        };
        for r in &self.records {
            mix(r.id);
            mix(r.model.0 as u64);
            mix(r.arrival_s.to_bits());
            mix(r.first_token_s.map(f64::to_bits).unwrap_or(u64::MAX));
            mix(r.completed_s.map(f64::to_bits).unwrap_or(u64::MAX));
            mix(r.generated as u64);
            mix(r.shed as u64);
        }
        mix(self.records.len() as u64);
        mix(self.duration_s.to_bits());
        mix(self.device_seconds.to_bits());
        mix(self.scale_ups);
        mix(self.scale_downs);
        mix(self.scheduler_invocations);
        // Compact runs carry their completions here instead of in
        // `records`; absent on normal runs, so their digests are
        // unchanged by the field's existence.
        if let Some(t) = &self.compact {
            mix(t.completed as u64);
            mix(t.ttft_met as u64);
            mix(t.ttft_sum_s.to_bits());
            mix(t.tokens_generated);
        }
        h
    }

    /// One-line summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "{}: slo={:.1}% thr={:.1} req/s tok/s={:.0} p99_ttft={:.2}s util={:.1}% swaps={} evictions={}",
            self.policy,
            100.0 * self.slo_attainment(),
            self.throughput_rps(),
            self.token_throughput(),
            self.ttft_percentile(99.0),
            100.0 * self.mean_utilization(),
            self.total_model_swaps(),
            self.total_evictions(),
        )
    }
}

/// Close the books on a run: one [`RequestRecord`] per request, exactly
/// once, sorted by id — completed requests, still-waiting requests
/// (violations), running-but-unfinished sequences *including* internally
/// preempted ones parked in CPU swap (Running in the broker but absent
/// from both `waiting_ids()` and `running()`, which used to vanish from
/// the records entirely, undercounting violations), and shed requests
/// (admission control / unservable retirement).
pub fn collect_records(queue: &GlobalQueue, instances: &[Instance]) -> Vec<RequestRecord> {
    let mut records: Vec<RequestRecord> = queue
        .completed
        .iter()
        .map(RequestRecord::from_request)
        .collect();
    for id in queue.waiting_ids() {
        if let Some(r) = queue.get(id) {
            records.push(RequestRecord::from_request(r));
        }
    }
    for inst in instances {
        for s in inst.running().iter().chain(inst.swapped()) {
            if let Some(r) = queue.get(s.req_id) {
                records.push(RequestRecord::from_request(r));
            }
        }
    }
    for &id in queue.shed_ids() {
        if let Some(r) = queue.get(id) {
            records.push(RequestRecord::from_request(r));
        }
    }
    records.sort_by_key(|r| r.id);
    records.dedup_by_key(|r| r.id);
    records
}

/// Convert a finished instance into metrics.
pub fn instance_metrics(inst: &crate::backend::Instance) -> InstanceMetrics {
    InstanceMetrics {
        id: inst.config.id.0,
        busy_s: inst.stats.busy_s,
        idle_s: inst.stats.idle_s,
        swap_s: inst.stats.swap_s,
        tokens_generated: inst.stats.tokens_generated,
        requests_completed: inst.stats.requests_completed,
        internal_preemptions: inst.stats.internal_preemptions,
        lso_evictions: inst.stats.lso_evictions,
        model_swaps: inst.registry().swaps_to_gpu,
        mean_batch: inst.mean_batch(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, first: Option<f64>, ttft_slo: f64, class: SloClass) -> RequestRecord {
        RequestRecord {
            id: 0,
            model: ModelId(0),
            class,
            slo: SloTarget::new(ttft_slo, 0.25),
            arrival_s: arrival,
            first_token_s: first,
            completed_s: first.map(|f| f + 1.0),
            generated: 50,
            mega: false,
            shed: false,
        }
    }

    #[test]
    fn slo_attainment_counts_unserved_as_violations() {
        let m = RunMetrics {
            records: vec![
                rec(0.0, Some(5.0), 20.0, SloClass::Interactive), // met
                rec(0.0, Some(30.0), 20.0, SloClass::Interactive), // missed
                rec(0.0, None, 20.0, SloClass::Interactive),      // never served
            ],
            duration_s: 100.0,
            ..Default::default()
        };
        assert!((m.slo_attainment() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn class_filtering() {
        let m = RunMetrics {
            records: vec![
                rec(0.0, Some(5.0), 20.0, SloClass::Interactive),
                rec(0.0, Some(3600.0), 60.0, SloClass::Batch1),
            ],
            ..Default::default()
        };
        assert_eq!(m.slo_attainment_class(SloClass::Interactive), 1.0);
        assert_eq!(m.slo_attainment_class(SloClass::Batch1), 0.0);
        assert_eq!(m.slo_attainment_class(SloClass::Batch2), 1.0); // vacuous
    }

    #[test]
    fn throughput_counts_completed_only() {
        let m = RunMetrics {
            records: vec![
                rec(0.0, Some(1.0), 20.0, SloClass::Interactive),
                rec(0.0, None, 20.0, SloClass::Interactive),
            ],
            duration_s: 2.0,
            ..Default::default()
        };
        assert!((m.throughput_rps() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_over_served() {
        let mut records = Vec::new();
        for i in 0..100 {
            records.push(rec(0.0, Some(i as f64), 20.0, SloClass::Interactive));
        }
        let m = RunMetrics {
            records,
            ..Default::default()
        };
        assert!((m.ttft_percentile(50.0) - 49.5).abs() < 1.0);
        assert!(m.ttft_percentile(99.0) > 95.0);
    }

    #[test]
    fn utilization_mean() {
        let m = RunMetrics {
            instances: vec![
                InstanceMetrics {
                    busy_s: 50.0,
                    idle_s: 50.0,
                    ..Default::default()
                },
                InstanceMetrics {
                    busy_s: 100.0,
                    idle_s: 0.0,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert!((m.mean_utilization() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_policy() {
        let m = RunMetrics {
            policy: "qlm".into(),
            ..Default::default()
        };
        assert!(m.summary().starts_with("qlm:"));
    }

    #[test]
    fn tpot_is_per_token_after_the_first() {
        let mut r = rec(0.0, Some(2.0), 20.0, SloClass::Interactive);
        r.completed_s = Some(2.0 + 49.0 * 0.1); // 49 decode gaps at 100 ms
        r.generated = 50;
        assert!((r.tpot().unwrap() - 0.1).abs() < 1e-12);
        assert!((r.e2e().unwrap() - 6.9).abs() < 1e-12);
        // Single-token output: no decode gap, TPOT 0 by convention.
        r.generated = 1;
        r.completed_s = Some(2.0);
        assert_eq!(r.tpot().unwrap(), 0.0);
    }

    #[test]
    fn slo_met_requires_both_dimensions() {
        // Fast first token, slow decode: TTFT met, TPOT violated.
        let mut r = rec(0.0, Some(1.0), 20.0, SloClass::Interactive);
        r.generated = 11;
        r.completed_s = Some(1.0 + 10.0 * 0.5); // 500 ms/token > 250 ms
        assert!(r.ttft_met());
        assert!(!r.tpot_met());
        assert!(!r.slo_met());
        // Unfinished request: first token in time but never completed.
        let mut u = rec(0.0, Some(1.0), 20.0, SloClass::Interactive);
        u.completed_s = None;
        assert!(u.ttft_met());
        assert!(!u.tpot_met());
        assert!(!u.slo_met());
    }

    #[test]
    fn compact_tally_aggregates_completions() {
        let mut t = CompactTally::default();
        t.note(0.0, Some(5.0), 20.0, 50); // met
        t.note(0.0, Some(30.0), 20.0, 10); // missed
        t.note(0.0, None, 20.0, 1); // completed without a first token
        assert_eq!(t.completed, 3);
        assert_eq!(t.ttft_met, 1);
        assert_eq!(t.tokens_generated, 61);
        assert!((t.ttft_attainment() - 1.0 / 3.0).abs() < 1e-12);
        assert!((t.mean_ttft() - 35.0 / 3.0).abs() < 1e-12);
        let m = RunMetrics {
            compact: Some(t),
            duration_s: 10.0,
            ..Default::default()
        };
        assert_eq!(m.completed_count(), 3);
        assert!((m.throughput_rps() - 0.3).abs() < 1e-12);
        let bare = RunMetrics {
            duration_s: 10.0,
            ..Default::default()
        };
        assert_ne!(m.digest(), bare.digest(), "the tally must reach the digest");
    }

    #[test]
    fn per_dimension_attainment_and_percentiles() {
        let mut slow_decode = rec(0.0, Some(1.0), 20.0, SloClass::Interactive);
        slow_decode.generated = 11;
        slow_decode.completed_s = Some(1.0 + 10.0 * 0.5);
        let m = RunMetrics {
            records: vec![
                rec(0.0, Some(5.0), 20.0, SloClass::Interactive), // both met
                slow_decode,                                      // ttft only
                rec(0.0, None, 20.0, SloClass::Interactive),      // neither
            ],
            ..Default::default()
        };
        assert!((m.ttft_attainment() - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.tpot_attainment() - 1.0 / 3.0).abs() < 1e-12);
        assert!((m.slo_attainment() - 1.0 / 3.0).abs() < 1e-12);
        // Percentiles are computed over defined values only.
        assert!(m.percentile(Metric::Tpot, 99.0) > 0.0);
        assert!(m.mean(Metric::E2e) > 0.0);
        assert_eq!(m.ttft_percentile(50.0), m.percentile(Metric::Ttft, 50.0));
    }
}
