//! The compiled tiny model: loads HLO text per batch bucket, compiles on
//! the PJRT CPU client, and exposes typed prefill/decode calls.
//!
//! HLO text is the interchange format — jax ≥ 0.5 emits HloModuleProto
//! with 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::manifest::Manifest;

/// Compiled executables for one batch bucket.
struct BucketExe {
    prefill: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
}

/// KV cache state for a batch, as host-side literals round-tripped
/// through PJRT between steps.
pub struct BatchState {
    pub batch: u32,
    pub k: xla::Literal,
    pub v: xla::Literal,
    pub lengths: Vec<i32>,
}

/// The runtime model.
pub struct TinyModel {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<u32, BucketExe>,
}

impl TinyModel {
    /// Load every bucket's executables from the artifact directory.
    pub fn load(artifacts_dir: &str) -> Result<TinyModel> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        let mut exes = HashMap::new();
        for b in &manifest.buckets {
            let load = |p: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(
                    p.to_str().context("path utf8")?,
                )
                .map_err(|e| anyhow!("loading {}: {e:?}", p.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e:?}", p.display()))
            };
            exes.insert(
                b.batch,
                BucketExe {
                    prefill: load(&b.prefill)?,
                    decode: load(&b.decode)?,
                },
            );
        }
        Ok(TinyModel {
            manifest,
            client,
            exes,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Prefill a batch of prompts (byte tokens). Prompts longer than
    /// max_seq−1 are truncated. Returns per-sequence logits and the KV
    /// state for subsequent decode steps.
    pub fn prefill(&self, prompts: &[&[u8]]) -> Result<(Vec<Vec<f32>>, BatchState)> {
        let n = prompts.len() as u32;
        let bucket = self.manifest.bucket_for(n).batch;
        let exe = &self.exes[&bucket];
        let s = self.manifest.max_seq as usize;
        let b = bucket as usize;

        let mut tokens = vec![0i32; b * s];
        let mut lengths = vec![1i32; b]; // pad rows decode garbage len 1
        for (i, p) in prompts.iter().enumerate() {
            let l = p.len().min(s - 1).max(1);
            for (j, &byte) in p[..l].iter().enumerate() {
                tokens[i * s + j] = byte as i32;
            }
            lengths[i] = l as i32;
        }
        let tok_lit = xla::Literal::vec1(&tokens)
            .reshape(&[b as i64, s as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let len_lit = xla::Literal::vec1(&lengths);

        let result = exe
            .prefill
            .execute::<xla::Literal>(&[tok_lit, len_lit])
            .map_err(|e| anyhow!("prefill exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (logits, k, v) = result.to_tuple3().map_err(|e| anyhow!("{e:?}"))?;
        let logits_flat: Vec<f32> = logits.to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let vsize = self.manifest.vocab as usize;
        let out = prompts
            .iter()
            .enumerate()
            .map(|(i, _)| logits_flat[i * vsize..(i + 1) * vsize].to_vec())
            .collect();
        Ok((
            out,
            BatchState {
                batch: bucket,
                k,
                v,
                lengths,
            },
        ))
    }

    /// One decode step: feed each sequence's latest token; returns
    /// per-sequence logits and advances the KV state in place.
    pub fn decode_step(&self, state: &mut BatchState, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let b = state.batch as usize;
        let exe = &self.exes[&state.batch];
        let mut toks = vec![0i32; b];
        toks[..tokens.len().min(b)].copy_from_slice(&tokens[..tokens.len().min(b)]);
        let tok_lit = xla::Literal::vec1(&toks);
        let len_lit = xla::Literal::vec1(&state.lengths);
        // §Perf: the caches from to_tuple3 already carry the right shape;
        // reshaping cloned ~16 MiB per step. Pass them by reference.
        let result = exe
            .decode
            .execute::<&xla::Literal>(&[&tok_lit, &state.k, &state.v, &len_lit])
            .map_err(|e| anyhow!("decode exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (logits, nk, nv) = result.to_tuple3().map_err(|e| anyhow!("{e:?}"))?;
        state.k = nk;
        state.v = nv;
        for l in state.lengths.iter_mut() {
            *l = (*l + 1).min(self.manifest.max_seq as i32 - 1);
        }
        let logits_flat: Vec<f32> = logits.to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let vsize = self.manifest.vocab as usize;
        Ok((0..b)
            .map(|i| logits_flat[i * vsize..(i + 1) * vsize].to_vec())
            .collect())
    }

    /// Greedy argmax sampling.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in logits.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<TinyModel> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(TinyModel::load(dir).expect("load artifacts"))
    }

    #[test]
    fn prefill_decode_roundtrip() {
        let Some(model) = artifacts() else { return };
        let prompts: Vec<&[u8]> = vec![b"hello qlm", b"queue management"];
        let (logits, mut state) = model.prefill(&prompts).unwrap();
        assert_eq!(logits.len(), 2);
        assert_eq!(logits[0].len(), 256);
        assert!(logits[0].iter().all(|v| v.is_finite()));
        let toks: Vec<i32> = logits.iter().map(|l| TinyModel::argmax(l)).collect();
        let l0 = state.lengths.clone();
        let out = model.decode_step(&mut state, &toks).unwrap();
        assert_eq!(out.len(), state.batch as usize);
        assert!(out[0].iter().all(|v| v.is_finite()));
        assert_eq!(state.lengths[0], l0[0] + 1);
    }

    #[test]
    fn deterministic_generation() {
        let Some(model) = artifacts() else { return };
        let gen = || {
            let (logits, mut st) = model.prefill(&[b"abc"]).unwrap();
            let mut t = TinyModel::argmax(&logits[0]);
            let mut seq = vec![t];
            for _ in 0..4 {
                let out = model.decode_step(&mut st, &[t]).unwrap();
                t = TinyModel::argmax(&out[0]);
                seq.push(t);
            }
            seq
        };
        assert_eq!(gen(), gen());
    }

    #[test]
    fn different_prompts_differ() {
        let Some(model) = artifacts() else { return };
        let (la, _) = model.prefill(&[b"aaaa"]).unwrap();
        let (lb, _) = model.prefill(&[b"zzzz"]).unwrap();
        let diff: f32 = la[0]
            .iter()
            .zip(&lb[0])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-4);
    }
}
