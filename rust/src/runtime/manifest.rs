//! Artifact manifest parsing (the plain-text twin of manifest.json that
//! `python/compile/aot.py` emits — no JSON dependency needed).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One (batch bucket → executables) entry.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub batch: u32,
    pub prefill: PathBuf,
    pub decode: PathBuf,
}

/// Parsed artifacts/manifest.txt.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub vocab: u32,
    pub d_model: u32,
    pub n_layers: u32,
    pub n_heads: u32,
    pub head_dim: u32,
    pub max_seq: u32,
    pub param_count: u64,
    pub seed: u64,
    pub buckets: Vec<Bucket>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut kv: BTreeMap<&str, &str> = BTreeMap::new();
        let mut buckets = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let key = parts.next().context("empty manifest line")?;
            if key == "bucket" {
                let batch: u32 = parts.next().context("bucket batch")?.parse()?;
                let prefill = dir.join(parts.next().context("bucket prefill")?);
                let decode = dir.join(parts.next().context("bucket decode")?);
                buckets.push(Bucket {
                    batch,
                    prefill,
                    decode,
                });
            } else {
                let val = parts.next().with_context(|| format!("value for {key}"))?;
                kv.insert(key, val);
            }
        }
        let get = |k: &str| -> Result<u64> {
            kv.get(k)
                .with_context(|| format!("manifest missing key {k}"))?
                .parse::<u64>()
                .with_context(|| format!("parsing {k}"))
        };
        if buckets.is_empty() {
            bail!("manifest has no buckets");
        }
        buckets.sort_by_key(|b| b.batch);
        Ok(Manifest {
            vocab: get("vocab")? as u32,
            d_model: get("d_model")? as u32,
            n_layers: get("n_layers")? as u32,
            n_heads: get("n_heads")? as u32,
            head_dim: get("head_dim")? as u32,
            max_seq: get("max_seq")? as u32,
            param_count: get("param_count")?,
            seed: get("seed")?,
            buckets,
            dir,
        })
    }

    /// Smallest bucket that fits `n` concurrent sequences, else the
    /// largest bucket.
    pub fn bucket_for(&self, n: u32) -> &Bucket {
        self.buckets
            .iter()
            .find(|b| b.batch >= n)
            .unwrap_or_else(|| self.buckets.last().unwrap())
    }

    pub fn max_bucket(&self) -> u32 {
        self.buckets.last().map(|b| b.batch).unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
vocab 256
d_model 64
n_layers 4
n_heads 4
head_dim 16
max_seq 256
param_count 229952
seed 20240711
bucket 1 prefill_b1.hlo.txt decode_b1.hlo.txt
bucket 8 prefill_b8.hlo.txt decode_b8.hlo.txt
bucket 4 prefill_b4.hlo.txt decode_b4.hlo.txt
";

    #[test]
    fn parses_and_sorts_buckets() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.param_count, 229_952);
        let batches: Vec<u32> = m.buckets.iter().map(|b| b.batch).collect();
        assert_eq!(batches, vec![1, 4, 8]);
        assert_eq!(m.buckets[0].prefill, PathBuf::from("/a/prefill_b1.hlo.txt"));
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.bucket_for(1).batch, 1);
        assert_eq!(m.bucket_for(2).batch, 4);
        assert_eq!(m.bucket_for(5).batch, 8);
        assert_eq!(m.bucket_for(100).batch, 8, "clamped to largest");
        assert_eq!(m.max_bucket(), 8);
    }

    #[test]
    fn missing_key_is_error() {
        assert!(Manifest::parse("vocab 1\nbucket 1 a b\n", PathBuf::new()).is_err());
    }

    #[test]
    fn no_buckets_is_error() {
        let text = SAMPLE
            .lines()
            .filter(|l| !l.starts_with("bucket"))
            .collect::<Vec<_>>()
            .join("\n");
        assert!(Manifest::parse(&text, PathBuf::new()).is_err());
    }
}
