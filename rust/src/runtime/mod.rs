//! The PJRT runtime: loads the AOT-compiled HLO artifacts (Layer-2 JAX
//! model with Layer-1 Pallas kernels baked in) and executes them from the
//! rust request path. Python never runs at serving time.

pub mod manifest;
pub mod model;
pub mod engine;

pub use engine::{EngineConfig, EngineRequest, EngineResult, ServeEngine};
pub use manifest::Manifest;
pub use model::TinyModel;
