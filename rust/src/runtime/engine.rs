//! Real serving engine over the PJRT runtime: batched prefill + decode
//! with QLM-style deadline ordering of the waiting queue. This is the
//! end-to-end proof that L3 (queue management) composes with L2/L1 (the
//! AOT-compiled model): examples/e2e_serve.rs drives it and reports
//! latency/throughput (EXPERIMENTS.md §E2E).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::model::TinyModel;

/// One serving request.
#[derive(Debug, Clone)]
pub struct EngineRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: u32,
    /// TTFT SLO in seconds (used for deadline ordering).
    pub slo_s: f64,
}

/// Completed request with measured latencies.
#[derive(Debug, Clone)]
pub struct EngineResult {
    pub id: u64,
    pub output: Vec<i32>,
    pub ttft_s: f64,
    pub total_s: f64,
    pub queue_s: f64,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// QLM ordering (deadline-sorted waiting queue) vs plain FCFS.
    pub ordered: bool,
    /// Stop token (generation also stops at max_new_tokens).
    pub eos: Option<i32>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            ordered: true,
            eos: None,
        }
    }
}

struct Waiting {
    req: EngineRequest,
    enqueued: Instant,
}

/// Aggregate engine statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub requests: u64,
    pub tokens_generated: u64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub batches: u64,
}

impl EngineStats {
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.tokens_generated as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// Batched serving engine.
pub struct ServeEngine {
    model: TinyModel,
    cfg: EngineConfig,
    waiting: VecDeque<Waiting>,
    pub stats: EngineStats,
}

impl ServeEngine {
    pub fn new(model: TinyModel, cfg: EngineConfig) -> Self {
        ServeEngine {
            model,
            cfg,
            waiting: VecDeque::new(),
            stats: EngineStats::default(),
        }
    }

    pub fn model(&self) -> &TinyModel {
        &self.model
    }

    pub fn submit(&mut self, req: EngineRequest) {
        self.waiting.push_back(Waiting {
            req,
            enqueued: Instant::now(),
        });
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    /// Serve one batch (up to the largest compiled bucket). Returns the
    /// completed results, or None if the queue is empty.
    pub fn serve_batch(&mut self) -> Result<Option<Vec<EngineResult>>> {
        if self.waiting.is_empty() {
            return Ok(None);
        }
        if self.cfg.ordered {
            // QLM request pulling: tightest TTFT budget first (the
            // virtual-queue order for a single instance, single model).
            let mut v: Vec<Waiting> = self.waiting.drain(..).collect();
            v.sort_by(|a, b| {
                let da = a.req.slo_s - a.enqueued.elapsed().as_secs_f64();
                let db = b.req.slo_s - b.enqueued.elapsed().as_secs_f64();
                da.partial_cmp(&db).unwrap()
            });
            self.waiting = v.into();
        }
        let take = (self.model.manifest.max_bucket() as usize).min(self.waiting.len());
        let batch: Vec<Waiting> = self.waiting.drain(..take).collect();

        let t0 = Instant::now();
        let prompts: Vec<&[u8]> = batch.iter().map(|w| w.req.prompt.as_slice()).collect();
        let (logits, mut state) = self.model.prefill(&prompts)?;
        let prefill_s = t0.elapsed().as_secs_f64();
        self.stats.prefill_s += prefill_s;

        let n = batch.len();
        let mut tokens: Vec<i32> = logits.iter().map(|l| TinyModel::argmax(l)).collect();
        tokens.resize(state.batch as usize, 0);
        let mut outputs: Vec<Vec<i32>> = (0..n).map(|i| vec![tokens[i]]).collect();
        let ttft: Vec<f64> = batch
            .iter()
            .map(|w| w.enqueued.elapsed().as_secs_f64())
            .collect();
        let mut done = vec![false; n];
        for (i, w) in batch.iter().enumerate() {
            if w.req.max_new_tokens <= 1 || self.cfg.eos == Some(outputs[i][0]) {
                done[i] = true;
            }
        }

        let td = Instant::now();
        let max_iters = batch
            .iter()
            .map(|w| w.req.max_new_tokens)
            .max()
            .unwrap_or(1)
            .min(self.model.manifest.max_seq - 1);
        for _ in 1..max_iters {
            if done.iter().all(|&d| d) {
                break;
            }
            let step = self.model.decode_step(&mut state, &tokens)?;
            for i in 0..n {
                if done[i] {
                    continue;
                }
                let t = TinyModel::argmax(&step[i]);
                tokens[i] = t;
                outputs[i].push(t);
                self.stats.tokens_generated += 1;
                if outputs[i].len() as u32 >= batch[i].req.max_new_tokens
                    || self.cfg.eos == Some(t)
                {
                    done[i] = true;
                }
            }
        }
        self.stats.decode_s += td.elapsed().as_secs_f64();
        self.stats.batches += 1;
        self.stats.requests += n as u64;

        let results = batch
            .into_iter()
            .enumerate()
            .map(|(i, w)| EngineResult {
                id: w.req.id,
                output: std::mem::take(&mut outputs[i]),
                ttft_s: ttft[i],
                total_s: w.enqueued.elapsed().as_secs_f64(),
                queue_s: ttft[i] - prefill_s,
            })
            .collect();
        Ok(Some(results))
    }

    /// Drain the whole queue; returns all results.
    pub fn run_to_completion(&mut self) -> Result<Vec<EngineResult>> {
        let mut all = Vec::new();
        while let Some(mut rs) = self.serve_batch()? {
            all.append(&mut rs);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(ordered: bool) -> Option<ServeEngine> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let model = TinyModel::load(dir).unwrap();
        Some(ServeEngine::new(model, EngineConfig { ordered, eos: None }))
    }

    #[test]
    fn serves_batch_with_outputs() {
        let Some(mut e) = engine(true) else { return };
        for i in 0..3 {
            e.submit(EngineRequest {
                id: i,
                prompt: format!("request number {i}").into_bytes(),
                max_new_tokens: 6,
                slo_s: 10.0,
            });
        }
        let results = e.run_to_completion().unwrap();
        assert_eq!(results.len(), 3);
        for r in &results {
            assert_eq!(r.output.len(), 6);
            assert!(r.ttft_s >= 0.0 && r.total_s >= r.ttft_s);
        }
        assert_eq!(e.stats.requests, 3);
        assert!(e.stats.tokens_generated >= 15);
    }

    #[test]
    fn ordered_queue_serves_tight_slo_first() {
        let Some(mut e) = engine(true) else { return };
        // More requests than one bucket: the relaxed one should come last.
        for i in 0..9 {
            e.submit(EngineRequest {
                id: i,
                prompt: vec![b'a'; 8],
                max_new_tokens: 2,
                slo_s: if i == 8 { 0.001 } else { 100.0 },
            });
        }
        let first = e.serve_batch().unwrap().unwrap();
        let ids: Vec<u64> = first.iter().map(|r| r.id).collect();
        assert!(ids.contains(&8), "tightest SLO in first batch: {ids:?}");
    }

    #[test]
    fn fcfs_preserves_arrival_order() {
        let Some(mut e) = engine(false) else { return };
        for i in 0..9 {
            e.submit(EngineRequest {
                id: i,
                prompt: vec![b'b'; 4],
                max_new_tokens: 2,
                slo_s: if i == 8 { 0.001 } else { 100.0 },
            });
        }
        let first = e.serve_batch().unwrap().unwrap();
        let ids: Vec<u64> = first.iter().map(|r| r.id).collect();
        assert!(!ids.contains(&8), "FCFS must not jump the queue: {ids:?}");
    }
}
