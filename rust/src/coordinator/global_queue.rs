//! The global request queue (§3.1, §4 "Fault Tolerance in Queue
//! Management").
//!
//! QLM stores a *single replica* of each request and its metadata in a
//! distributed broker (RabbitMQ in the paper); virtual queues hold only
//! references. We reproduce the broker's semantics in-process: submit /
//! ack (complete) / requeue-on-eviction, plus the consistency property
//! that virtual queues can be rebuilt from the global queue alone after
//! an instance failure.

use std::collections::HashMap;

use crate::coordinator::request::{Request, RequestState};

/// The single-replica request store + waiting set.
#[derive(Debug, Default)]
pub struct GlobalQueue {
    store: HashMap<u64, Request>,
    /// Waiting request ids in arrival order (FCFS base ordering).
    waiting: Vec<u64>,
    next_id: u64,
    pub completed: Vec<Request>,
}

impl GlobalQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a new request; returns its broker id.
    pub fn submit(&mut self, mut req: Request) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        req.id = id;
        req.state = RequestState::Waiting;
        self.waiting.push(id);
        self.store.insert(id, req);
        id
    }

    pub fn len_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn len_total(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn get(&self, id: u64) -> Option<&Request> {
        self.store.get(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Request> {
        self.store.get_mut(&id)
    }

    /// Ids currently waiting (arrival order).
    pub fn waiting_ids(&self) -> &[u64] {
        &self.waiting
    }

    /// Mark a request as pulled into a running batch (Request Pulling LSO).
    /// Removes it from the waiting set; the broker keeps the data until ack.
    pub fn mark_running(&mut self, id: u64) {
        if let Some(r) = self.store.get_mut(&id) {
            r.state = RequestState::Running;
        }
        self.waiting.retain(|&x| x != id);
    }

    /// Re-queue an evicted request (Request Eviction LSO): it returns to
    /// the waiting set, retaining progress metadata.
    pub fn requeue_evicted(
        &mut self,
        id: u64,
        generated: u32,
        evicted_from: crate::backend::InstanceId,
    ) {
        if let Some(r) = self.store.get_mut(&id) {
            r.state = RequestState::Evicted;
            r.generated = generated;
            r.evicted_from = Some(evicted_from);
            if !self.waiting.contains(&id) {
                self.waiting.push(id);
            }
        }
    }

    /// Ack a completed request: removed from the broker, archived for
    /// metrics.
    pub fn complete(&mut self, id: u64, first_token_s: Option<f64>, completed_s: f64) {
        if let Some(mut r) = self.store.remove(&id) {
            r.state = RequestState::Completed;
            if r.first_token_s.is_none() {
                r.first_token_s = first_token_s;
            }
            r.completed_s = Some(completed_s);
            self.completed.push(r);
        }
        self.waiting.retain(|&x| x != id);
    }

    /// Record a first-token event.
    pub fn record_first_token(&mut self, id: u64, t: f64) {
        if let Some(r) = self.store.get_mut(&id) {
            if r.first_token_s.is_none() {
                r.first_token_s = Some(t);
            }
        }
    }

    /// Instance failure (§4 Fault Isolation): every request that was
    /// running on the lost instance reverts to Waiting; evicted-KV
    /// references to that instance are invalidated (the KV is gone, so
    /// generation restarts from the prompt). Returns affected ids.
    pub fn fail_instance(&mut self, inst: crate::backend::InstanceId, running_ids: &[u64]) -> Vec<u64> {
        let mut affected = Vec::new();
        for &id in running_ids {
            if let Some(r) = self.store.get_mut(&id) {
                r.state = RequestState::Waiting;
                r.generated = 0;
                r.evicted_from = None;
                if !self.waiting.contains(&id) {
                    self.waiting.push(id);
                }
                affected.push(id);
            }
        }
        // Invalidate stale eviction pointers into the dead instance.
        for r in self.store.values_mut() {
            if r.evicted_from == Some(inst) {
                r.evicted_from = None;
                r.generated = 0;
            }
        }
        affected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{InstanceId, ModelId};
    use crate::workload::{SloClass, TraceRequest};

    fn trace_req(arrival: f64) -> TraceRequest {
        TraceRequest {
            arrival_s: arrival,
            model: ModelId(0),
            class: SloClass::Interactive,
            slo_s: 20.0,
            input_tokens: 100,
            output_tokens: 50,
            mega: false,
        }
    }

    fn submit_one(q: &mut GlobalQueue, arrival: f64) -> u64 {
        q.submit(Request::from_trace(0, &trace_req(arrival)))
    }

    #[test]
    fn submit_assigns_ids_in_order() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        assert_eq!(b, a + 1);
        assert_eq!(q.waiting_ids(), &[a, b]);
        assert_eq!(q.len_waiting(), 2);
    }

    #[test]
    fn pull_then_complete_lifecycle() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.mark_running(id);
        assert_eq!(q.len_waiting(), 0);
        assert_eq!(q.get(id).unwrap().state, RequestState::Running);
        q.record_first_token(id, 3.0);
        q.complete(id, None, 10.0);
        assert!(q.get(id).is_none());
        assert_eq!(q.completed.len(), 1);
        assert_eq!(q.completed[0].ttft(), Some(3.0));
    }

    #[test]
    fn eviction_requeues_with_progress() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.mark_running(id);
        q.requeue_evicted(id, 17, InstanceId(3));
        let r = q.get(id).unwrap();
        assert_eq!(r.state, RequestState::Evicted);
        assert_eq!(r.generated, 17);
        assert_eq!(r.evicted_from, Some(InstanceId(3)));
        assert!(q.waiting_ids().contains(&id));
    }

    #[test]
    fn instance_failure_restores_waiting_state() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        q.mark_running(a);
        q.mark_running(b);
        // b was evicted earlier, its KV parked on the failed instance.
        q.requeue_evicted(b, 9, InstanceId(1));
        let affected = q.fail_instance(InstanceId(1), &[a]);
        assert_eq!(affected, vec![a]);
        let ra = q.get(a).unwrap();
        assert_eq!(ra.state, RequestState::Waiting);
        let rb = q.get(b).unwrap();
        assert_eq!(rb.evicted_from, None, "stale KV pointer invalidated");
        assert_eq!(rb.generated, 0);
        // No request was lost: broker holds the single replica.
        assert_eq!(q.len_total(), 2);
    }

    #[test]
    fn first_token_recorded_once() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.record_first_token(id, 5.0);
        q.record_first_token(id, 9.0);
        assert_eq!(q.get(id).unwrap().first_token_s, Some(5.0));
    }
}
