//! The global request queue (§3.1, §4 "Fault Tolerance in Queue
//! Management").
//!
//! QLM stores a *single replica* of each request and its metadata in a
//! distributed broker (RabbitMQ in the paper); virtual queues hold only
//! references. We reproduce the broker's semantics in-process: submit /
//! ack (complete) / requeue-on-eviction, plus the consistency property
//! that virtual queues can be rebuilt from the global queue alone after
//! an instance failure.
//!
//! §Perf: the broker is **sharded by model** ([`QueueShard`]): each
//! model gets its own slot-recycling slab, waiting bitset, and
//! open-group index, behind this thin routing façade. The public API
//! and the global id semantics are unchanged from the flat-slab
//! implementation — broker ids are dense and monotonically increasing
//! across the whole fleet (`route.len()` at submit), and ids are never
//! reused. A `route` table (one u64 per all-time id, packing shard +
//! local slot; `u64::MAX` once acked) resolves every id in O(1).
//! Shards are disjoint by construction — a request never changes model
//! — which is what makes the per-shard scheduler fan-out sound, and
//! per-shard dirty flags let a scheduler pass skip shards whose
//! requests haven't changed since the last pass ([`Self::begin_pass`]).
//!
//! Every per-request operation on the simulator hot path (submit,
//! mark_running, requeue, ack) is O(1) with no per-request allocation
//! in steady state; the waiting-set union iterates shards' bitset words
//! OR-ed per index, preserving the ascending-global-id (FCFS) order of
//! the flat bitset at the same cost for a single model.

use std::collections::BTreeMap;

use crate::backend::{InstanceId, ModelId};
use crate::coordinator::request::{Request, RequestState};
use crate::coordinator::request_group::GroupId;
use crate::coordinator::shard::QueueShard;
use crate::workload::SloClass;

/// Route-table sentinel: the id has been acked and its slot recycled.
const RETIRED: u64 = u64::MAX;

fn pack(shard: usize, slot: u32) -> u64 {
    ((shard as u64) << 32) | slot as u64
}

/// The single-replica request store + waiting set, sharded by model.
#[derive(Debug, Default)]
pub struct GlobalQueue {
    /// Per-model shards, in first-seen order.
    shards: Vec<QueueShard>,
    shard_of_model: BTreeMap<ModelId, usize>,
    /// Broker id → packed (shard, slot); [`RETIRED`] once acked. Grows
    /// with the all-time submit count (8 B/request) — the only O(total)
    /// state a streamed, compact-records run keeps per request.
    route: Vec<u64>,
    /// Number of resident (un-acked) requests across all shards.
    live: usize,
    /// Acked requests, archived for metrics. Empty in compact mode.
    pub completed: Vec<Request>,
    /// Acks so far — equals `completed.len()` unless compact.
    completed_count: usize,
    /// Compact-records mode (gigascale benches): drop acked requests
    /// instead of archiving them; callers fold their own tallies.
    compact: bool,
    /// Ids refused by admission control (state `Shed`). The requests
    /// stay resident (they must appear in the final records as
    /// violations) but leave the waiting set for good.
    shed: Vec<u64>,
    /// Cumulative scheduler-pass dirt counters (see [`Self::begin_pass`]).
    shards_scanned: u64,
    shards_skipped: u64,
}

impl GlobalQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Compact-records mode: acked requests are dropped instead of
    /// archived, keeping residency O(in-flight) at any request count.
    /// The engine folds completion tallies before calling
    /// [`Self::complete`]; `metrics::collect_records` sees no
    /// completed requests, so this is for bench/scale runs only.
    pub fn set_compact(&mut self, on: bool) {
        self.compact = on;
    }

    pub fn is_compact(&self) -> bool {
        self.compact
    }

    fn ensure_shard(&mut self, model: ModelId) -> usize {
        if let Some(&i) = self.shard_of_model.get(&model) {
            return i;
        }
        self.shards.push(QueueShard::new(model));
        let i = self.shards.len() - 1;
        self.shard_of_model.insert(model, i);
        i
    }

    /// Resolve a live broker id to its shard + local slot.
    fn locate(&self, id: u64) -> Option<(usize, u32)> {
        let packed = *self.route.get(id as usize)?;
        if packed == RETIRED {
            return None;
        }
        Some(((packed >> 32) as usize, packed as u32))
    }

    /// Enqueue a new request; returns its broker id. Ids are global and
    /// dense across shards: submit order *is* id order fleet-wide.
    pub fn submit(&mut self, mut req: Request) -> u64 {
        let id = self.route.len() as u64;
        req.id = id;
        req.state = RequestState::Waiting;
        let si = self.ensure_shard(req.model);
        let shard = &mut self.shards[si];
        let slot = shard.place(req);
        shard.waiting.insert(id);
        self.route.push(pack(si, slot));
        self.live += 1;
        id
    }

    pub fn len_waiting(&self) -> usize {
        self.shards.iter().map(|s| s.waiting.len()).sum()
    }

    pub fn len_total(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Acks so far. Use this (not `completed.len()`) for termination
    /// checks — in compact mode the archive stays empty.
    pub fn len_completed(&self) -> usize {
        self.completed_count
    }

    pub fn get(&self, id: u64) -> Option<&Request> {
        let (si, slot) = self.locate(id)?;
        self.shards[si].get(slot)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Request> {
        let (si, slot) = self.locate(id)?;
        self.shards[si].get_mut(slot)
    }

    /// Ids currently waiting, in arrival order (FCFS base ordering).
    /// Shards hold disjoint global ids, so OR-ing their bitset words
    /// per index walks the exact union, ascending.
    pub fn waiting_ids(&self) -> impl Iterator<Item = u64> + '_ {
        let words = self
            .shards
            .iter()
            .map(|s| s.waiting.words().len())
            .max()
            .unwrap_or(0);
        (0..words).flat_map(move |w| {
            let word = self
                .shards
                .iter()
                .fold(0u64, |or, s| or | s.waiting.words().get(w).copied().unwrap_or(0));
            std::iter::successors((word != 0).then_some(word), |&bits| {
                let rest = bits & (bits - 1);
                (rest != 0).then_some(rest)
            })
            .map(move |bits| (w as u64) * 64 + bits.trailing_zeros() as u64)
        })
    }

    /// Is `id` in the waiting set?
    pub fn is_waiting(&self, id: u64) -> bool {
        self.locate(id)
            .is_some_and(|(si, _)| self.shards[si].waiting.contains(id))
    }

    /// Mark a request as pulled into a running batch (Request Pulling LSO).
    /// Removes it from the waiting set; the broker keeps the data until ack.
    /// Returns the state the request was pulled *from* — `Waiting` means
    /// this was the first pull (the waiting→running edge the RWT-accuracy
    /// ledger joins on), `Evicted` a re-pull after eviction.
    pub fn mark_running(&mut self, id: u64) -> Option<RequestState> {
        let (si, slot) = self.locate(id)?;
        let shard = &mut self.shards[si];
        let r = shard.get_mut(slot)?;
        let prior = r.state;
        r.state = RequestState::Running;
        shard.waiting.remove(id);
        shard.dirty = true;
        Some(prior)
    }

    /// Re-queue an evicted request (Request Eviction LSO): it returns to
    /// the waiting set, retaining progress metadata.
    pub fn requeue_evicted(&mut self, id: u64, generated: u32, evicted_from: InstanceId) {
        if let Some((si, slot)) = self.locate(id) {
            let shard = &mut self.shards[si];
            if let Some(r) = shard.get_mut(slot) {
                r.state = RequestState::Evicted;
                r.generated = generated;
                r.evicted_from = Some(evicted_from);
                shard.waiting.insert(id);
                shard.dirty = true;
            }
        }
    }

    /// Ack a completed request: removed from the broker, archived for
    /// metrics (dropped in compact mode), its shard slot recycled, its
    /// route entry retired — so the id keeps resolving to nothing and a
    /// second ack is a no-op. `generated` is the final decode-token
    /// count — TPOT accounting needs it alongside the first-token
    /// timestamp.
    pub fn complete(&mut self, id: u64, first_token_s: Option<f64>, completed_s: f64, generated: u32) {
        let Some((si, slot)) = self.locate(id) else {
            return;
        };
        let shard = &mut self.shards[si];
        let Some(mut r) = shard.take(slot) else {
            return;
        };
        shard.waiting.remove(id);
        // A completion shrinks the request's group, which the engine
        // marks dirty — the shard must go dirty with it or a pass would
        // skip a shard holding re-priceable work.
        shard.dirty = true;
        self.route[id as usize] = RETIRED;
        self.live -= 1;
        r.state = RequestState::Completed;
        if r.first_token_s.is_none() {
            r.first_token_s = first_token_s;
        }
        r.completed_s = Some(completed_s);
        r.generated = generated;
        self.completed_count += 1;
        if !self.compact {
            self.completed.push(r);
        }
    }

    /// Shed a request (admission control / unservable-group retirement):
    /// it leaves the waiting set permanently but stays in the broker so
    /// the final records count it exactly once, as a violation. Only
    /// unserved requests can be shed; returns whether the state changed.
    pub fn shed(&mut self, id: u64) -> bool {
        let Some((si, slot)) = self.locate(id) else {
            return false;
        };
        let shard = &mut self.shards[si];
        let Some(r) = shard.get_mut(slot) else {
            return false;
        };
        if !matches!(r.state, RequestState::Waiting | RequestState::Evicted) {
            return false;
        }
        r.state = RequestState::Shed;
        shard.waiting.remove(id);
        shard.dirty = true;
        self.shed.push(id);
        true
    }

    /// Ids shed so far (submit-time refusals + unservable retirements).
    pub fn shed_ids(&self) -> &[u64] {
        &self.shed
    }

    pub fn len_shed(&self) -> usize {
        self.shed.len()
    }

    /// Record a first-token event.
    pub fn record_first_token(&mut self, id: u64, t: f64) {
        if let Some(r) = self.get_mut(id) {
            if r.first_token_s.is_none() {
                r.first_token_s = Some(t);
            }
        }
    }

    /// Instance failure (§4 Fault Isolation): every request that was
    /// running on the lost instance reverts to Waiting; evicted-KV
    /// references to that instance are invalidated (the KV is gone, so
    /// generation restarts from the prompt). Returns affected ids.
    ///
    /// Evicted-KV pointers are *instance*-scoped, not model-scoped: a
    /// model swap parks the displaced requests of the instance's
    /// **previous** model on it, so a failed instance can hold KV for
    /// models other than the one it was last serving. The invalidation
    /// sweep therefore crosses every shard, never just the shard of the
    /// instance's current model.
    pub fn fail_instance(&mut self, inst: InstanceId, running_ids: &[u64]) -> Vec<u64> {
        let mut affected = Vec::new();
        for &id in running_ids {
            if let Some((si, slot)) = self.locate(id) {
                let shard = &mut self.shards[si];
                if let Some(r) = shard.get_mut(slot) {
                    r.state = RequestState::Waiting;
                    r.generated = 0;
                    r.evicted_from = None;
                    shard.waiting.insert(id);
                    shard.dirty = true;
                    affected.push(id);
                }
            }
        }
        // Invalidate stale eviction pointers into the dead instance.
        for shard in &mut self.shards {
            let mut touched = false;
            for r in shard.iter_mut() {
                if r.evicted_from == Some(inst) {
                    r.evicted_from = None;
                    r.generated = 0;
                    touched = true;
                }
            }
            if touched {
                shard.dirty = true;
            }
        }
        affected
    }

    // ----- open-group index (shard-resident; engine-facing) -----

    /// Lowest-id open (below-capacity) group for the key, if any — the
    /// group new arrivals of that key should join first.
    pub fn open_group_first(&self, model: ModelId, class: SloClass, mega: bool) -> Option<GroupId> {
        let &si = self.shard_of_model.get(&model)?;
        self.shards[si]
            .open_groups
            .get(&(class, mega))?
            .iter()
            .next()
            .copied()
    }

    /// Register `gid` as open for the key.
    pub fn open_group_insert(&mut self, model: ModelId, class: SloClass, mega: bool, gid: GroupId) {
        let si = self.ensure_shard(model);
        self.shards[si]
            .open_groups
            .entry((class, mega))
            .or_default()
            .insert(gid);
    }

    /// Remove `gid` from the key's open set (group filled or retired).
    pub fn open_group_remove(&mut self, model: ModelId, class: SloClass, mega: bool, gid: GroupId) {
        if let Some(&si) = self.shard_of_model.get(&model) {
            let shard = &mut self.shards[si];
            if let Some(set) = shard.open_groups.get_mut(&(class, mega)) {
                set.remove(&gid);
                if set.is_empty() {
                    shard.open_groups.remove(&(class, mega));
                }
            }
        }
    }

    /// Test-facing snapshot of the open-group index, sorted by key.
    #[doc(hidden)]
    pub fn open_groups_debug(&self) -> Vec<((ModelId, SloClass, bool), Vec<GroupId>)> {
        let mut out: Vec<((ModelId, SloClass, bool), Vec<GroupId>)> = Vec::new();
        for s in &self.shards {
            for (&(class, mega), set) in &s.open_groups {
                out.push(((s.model, class, mega), set.iter().copied().collect()));
            }
        }
        out.sort_by_key(|&((m, c, mg), _)| (m, c, mg));
        out
    }

    // ----- per-shard dirt (scheduler-pass skipping) -----

    /// Start a scheduler pass: returns `(dirty, clean)` shard counts
    /// and clears the flags. The scheduler's queue reads in a pass are
    /// confined to dirty groups' members, and every mutation that
    /// dirties a group dirties its model's shard (drains use
    /// [`Self::touch_model`]), so dirty groups' shards ⊆ the dirty set
    /// — the clean count is work the pass provably skips.
    pub fn begin_pass(&mut self) -> (usize, usize) {
        let mut scanned = 0usize;
        for s in &mut self.shards {
            if s.dirty {
                scanned += 1;
                s.dirty = false;
            }
        }
        let skipped = self.shards.len() - scanned;
        self.shards_scanned += scanned as u64;
        self.shards_skipped += skipped as u64;
        (scanned, skipped)
    }

    /// Cumulative `(scanned, skipped)` shard counts across passes.
    pub fn shard_stats(&self) -> (u64, u64) {
        (self.shards_scanned, self.shards_skipped)
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Mark a model's shard dirty without a request mutation — for
    /// engine events (e.g. drains) that re-dirty groups directly.
    pub fn touch_model(&mut self, model: ModelId) {
        if let Some(&si) = self.shard_of_model.get(&model) {
            self.shards[si].dirty = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{InstanceId, ModelId};
    use crate::workload::{SloClass, SloTarget, TraceRequest};

    fn trace_req(arrival: f64) -> TraceRequest {
        TraceRequest {
            arrival_s: arrival,
            model: ModelId(0),
            class: SloClass::Interactive,
            slo: SloTarget::new(20.0, 0.25),
            input_tokens: 100,
            output_tokens: 50,
            mega: false,
        }
    }

    fn submit_one(q: &mut GlobalQueue, arrival: f64) -> u64 {
        q.submit(Request::from_trace(0, &trace_req(arrival)))
    }

    fn submit_model(q: &mut GlobalQueue, arrival: f64, model: ModelId) -> u64 {
        let mut t = trace_req(arrival);
        t.model = model;
        q.submit(Request::from_trace(0, &t))
    }

    fn waiting_vec(q: &GlobalQueue) -> Vec<u64> {
        q.waiting_ids().collect()
    }

    #[test]
    fn submit_assigns_ids_in_order() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        assert_eq!(b, a + 1);
        assert_eq!(waiting_vec(&q), vec![a, b]);
        assert_eq!(q.len_waiting(), 2);
    }

    #[test]
    fn pull_then_complete_lifecycle() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.mark_running(id);
        assert_eq!(q.len_waiting(), 0);
        assert_eq!(q.get(id).unwrap().state, RequestState::Running);
        q.record_first_token(id, 3.0);
        q.complete(id, None, 10.0, 50);
        assert!(q.get(id).is_none());
        assert_eq!(q.completed.len(), 1);
        assert_eq!(q.len_completed(), 1);
        assert_eq!(q.completed[0].ttft(), Some(3.0));
    }

    #[test]
    fn eviction_requeues_with_progress() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.mark_running(id);
        q.requeue_evicted(id, 17, InstanceId(3));
        let r = q.get(id).unwrap();
        assert_eq!(r.state, RequestState::Evicted);
        assert_eq!(r.generated, 17);
        assert_eq!(r.evicted_from, Some(InstanceId(3)));
        assert!(q.is_waiting(id));
    }

    #[test]
    fn requeue_restores_arrival_position() {
        // The waiting set's FCFS base ordering is by arrival: an evicted
        // request re-enters at its arrival rank, not at the back.
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        let c = submit_one(&mut q, 2.0);
        q.mark_running(b);
        q.requeue_evicted(b, 4, InstanceId(0));
        assert_eq!(waiting_vec(&q), vec![a, b, c]);
    }

    #[test]
    fn instance_failure_restores_waiting_state() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        q.mark_running(a);
        q.mark_running(b);
        // b was evicted earlier, its KV parked on the failed instance.
        q.requeue_evicted(b, 9, InstanceId(1));
        let affected = q.fail_instance(InstanceId(1), &[a]);
        assert_eq!(affected, vec![a]);
        let ra = q.get(a).unwrap();
        assert_eq!(ra.state, RequestState::Waiting);
        let rb = q.get(b).unwrap();
        assert_eq!(rb.evicted_from, None, "stale KV pointer invalidated");
        assert_eq!(rb.generated, 0);
        // No request was lost: broker holds the single replica.
        assert_eq!(q.len_total(), 2);
    }

    #[test]
    fn first_token_recorded_once() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.record_first_token(id, 5.0);
        q.record_first_token(id, 9.0);
        assert_eq!(q.get(id).unwrap().first_token_s, Some(5.0));
    }

    #[test]
    fn acked_ids_never_reused() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        q.mark_running(a);
        q.complete(a, Some(1.0), 2.0, 50);
        let b = submit_one(&mut q, 3.0);
        assert!(b > a, "retired broker id must not be recycled");
        assert!(q.get(a).is_none());
        assert_eq!(q.len_total(), 1);
        // The recycled *slot* now holds b; the stale id a still resolves
        // to nothing — route retirement, not slot identity, is the
        // liveness authority.
        assert_eq!(q.get(b).unwrap().id, b);
        assert!(!q.is_waiting(a));
        assert!(q.mark_running(a).is_none());
    }

    #[test]
    fn shed_leaves_waiting_but_stays_recorded() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        assert!(q.shed(a));
        assert!(!q.shed(a), "double shed is a no-op");
        assert_eq!(q.get(a).unwrap().state, RequestState::Shed);
        assert_eq!(waiting_vec(&q), vec![b]);
        assert_eq!(q.shed_ids(), &[a]);
        assert_eq!(q.len_shed(), 1);
        // Running requests cannot be shed (no mid-flight kills).
        q.mark_running(b);
        assert!(!q.shed(b));
        // The shed request still lives in the broker for the records.
        assert_eq!(q.len_total(), 2);
    }

    #[test]
    fn double_complete_is_idempotent() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        q.mark_running(a);
        q.complete(a, Some(1.0), 2.0, 50);
        q.complete(a, Some(5.0), 6.0, 50);
        assert_eq!(q.completed.len(), 1);
        assert_eq!(q.len_completed(), 1);
        assert_eq!(q.len_total(), 0);
    }

    #[test]
    fn multi_model_waiting_order_is_global_fcfs() {
        // Requests interleaved across three models: the merged waiting
        // scan must yield ascending global ids, not shard-major order.
        let mut q = GlobalQueue::new();
        let mut ids = Vec::new();
        for i in 0..9 {
            ids.push(submit_model(&mut q, i as f64, ModelId(i % 3)));
        }
        assert_eq!(q.shard_count(), 3);
        assert_eq!(waiting_vec(&q), ids);
        // Pull one per model; the rest keep global arrival order.
        q.mark_running(ids[0]);
        q.mark_running(ids[4]);
        q.mark_running(ids[8]);
        let expect: Vec<u64> = ids
            .iter()
            .copied()
            .filter(|i| ![ids[0], ids[4], ids[8]].contains(i))
            .collect();
        assert_eq!(waiting_vec(&q), expect);
        assert_eq!(q.len_waiting(), 6);
    }

    #[test]
    fn cross_shard_eviction_pointers_invalidated_on_failure() {
        // A request of model 1 parked its KV on instance 7, which last
        // served model 0: the failure sweep must cross shards.
        let mut q = GlobalQueue::new();
        let a = submit_model(&mut q, 0.0, ModelId(0));
        let b = submit_model(&mut q, 1.0, ModelId(1));
        q.mark_running(b);
        q.requeue_evicted(b, 12, InstanceId(7));
        let affected = q.fail_instance(InstanceId(7), &[]);
        assert!(affected.is_empty());
        let rb = q.get(b).unwrap();
        assert_eq!(rb.evicted_from, None, "other-shard KV pointer must be swept");
        assert_eq!(rb.generated, 0);
        assert_eq!(q.get(a).unwrap().state, RequestState::Waiting);
    }

    #[test]
    fn begin_pass_skips_clean_shards() {
        let mut q = GlobalQueue::new();
        submit_model(&mut q, 0.0, ModelId(0));
        submit_model(&mut q, 0.0, ModelId(1));
        assert_eq!(q.begin_pass(), (2, 0), "both shards saw submits");
        // No mutations: everything is skippable.
        assert_eq!(q.begin_pass(), (0, 2));
        // Touch only model 0.
        let c = submit_model(&mut q, 1.0, ModelId(0));
        assert_eq!(q.begin_pass(), (1, 1));
        // Reads never dirty.
        let _ = q.get(c);
        let _ = q.is_waiting(c);
        assert_eq!(q.begin_pass(), (0, 2));
        q.touch_model(ModelId(1));
        assert_eq!(q.begin_pass(), (1, 1));
        assert_eq!(q.shard_stats(), (4, 6));
    }

    #[test]
    fn compact_mode_counts_without_archiving() {
        let mut q = GlobalQueue::new();
        q.set_compact(true);
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        q.mark_running(a);
        q.complete(a, Some(1.0), 2.0, 50);
        q.complete(a, Some(9.0), 9.5, 50);
        assert!(q.completed.is_empty(), "compact mode drops acked requests");
        assert_eq!(q.len_completed(), 1);
        assert_eq!(q.len_total(), 1);
        assert_eq!(q.get(b).unwrap().id, b);
    }

    #[test]
    fn open_group_index_is_per_shard_lowest_id_first() {
        use crate::coordinator::request_group::GroupId;
        let mut q = GlobalQueue::new();
        let key = (SloClass::Interactive, false);
        q.open_group_insert(ModelId(0), key.0, key.1, GroupId(5));
        q.open_group_insert(ModelId(0), key.0, key.1, GroupId(2));
        q.open_group_insert(ModelId(1), key.0, key.1, GroupId(9));
        assert_eq!(q.open_group_first(ModelId(0), key.0, key.1), Some(GroupId(2)));
        assert_eq!(q.open_group_first(ModelId(1), key.0, key.1), Some(GroupId(9)));
        assert_eq!(q.open_group_first(ModelId(2), key.0, key.1), None);
        q.open_group_remove(ModelId(0), key.0, key.1, GroupId(2));
        assert_eq!(q.open_group_first(ModelId(0), key.0, key.1), Some(GroupId(5)));
        q.open_group_remove(ModelId(0), key.0, key.1, GroupId(5));
        assert_eq!(q.open_group_first(ModelId(0), key.0, key.1), None);
        let dbg = q.open_groups_debug();
        assert_eq!(dbg.len(), 1);
        assert_eq!(dbg[0], ((ModelId(1), SloClass::Interactive, false), vec![GroupId(9)]));
    }
}
