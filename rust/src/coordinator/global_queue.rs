//! The global request queue (§3.1, §4 "Fault Tolerance in Queue
//! Management").
//!
//! QLM stores a *single replica* of each request and its metadata in a
//! distributed broker (RabbitMQ in the paper); virtual queues hold only
//! references. We reproduce the broker's semantics in-process: submit /
//! ack (complete) / requeue-on-eviction, plus the consistency property
//! that virtual queues can be rebuilt from the global queue alone after
//! an instance failure.
//!
//! §Perf: broker ids are dense and monotonically increasing, so the
//! store is a slab (`Vec<Option<Request>>` indexed by id) rather than a
//! keyed map, and the waiting set is a dense [`IdBitSet`] over the same
//! indices rather than a keyed set. Every per-request operation on the
//! simulator hot path (submit, mark_running, requeue, ack) is O(1) with
//! no per-node allocation; the seed implementation paid an O(n)
//! `Vec::retain` per pull and per ack, which dominated profiles at tens
//! of thousands of queued requests, and the `BTreeSet` that replaced it
//! still paid a node allocation and a pointer-chasing O(log n) walk per
//! membership change — measurable at the million-request scale of
//! `--scenario megascale`.

use crate::coordinator::request::{Request, RequestState};

/// Ordered set of dense slab ids: one bit per slot. Insert / remove /
/// contains are O(1); iteration is an ascending word scan, so — ids
/// being assigned in submit order — iteration order *is* arrival order,
/// exactly like the `BTreeSet<u64>` this replaces.
#[derive(Debug, Default)]
struct IdBitSet {
    words: Vec<u64>,
    len: usize,
}

impl IdBitSet {
    fn insert(&mut self, id: u64) {
        let (w, b) = ((id / 64) as usize, id % 64);
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let mask = 1u64 << b;
        if self.words[w] & mask == 0 {
            self.words[w] |= mask;
            self.len += 1;
        }
    }

    fn remove(&mut self, id: u64) {
        let (w, b) = ((id / 64) as usize, id % 64);
        if let Some(word) = self.words.get_mut(w) {
            let mask = 1u64 << b;
            if *word & mask != 0 {
                *word &= !mask;
                self.len -= 1;
            }
        }
    }

    fn contains(&self, id: u64) -> bool {
        let (w, b) = ((id / 64) as usize, id % 64);
        self.words.get(w).is_some_and(|word| word & (1 << b) != 0)
    }

    fn len(&self) -> usize {
        self.len
    }

    /// Set ids, ascending. Per word, peel set bits lowest-first
    /// (`trailing_zeros` + clear-lowest) — allocation-free.
    fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.words.iter().enumerate().flat_map(|(w, &word)| {
            std::iter::successors((word != 0).then_some(word), |&bits| {
                let rest = bits & (bits - 1);
                (rest != 0).then_some(rest)
            })
            .map(move |bits| (w as u64) * 64 + bits.trailing_zeros() as u64)
        })
    }
}

/// The single-replica request store + waiting set.
#[derive(Debug, Default)]
pub struct GlobalQueue {
    /// Slab of live requests, indexed by broker id. Acked requests leave
    /// a `None` tombstone so ids are never reused.
    slots: Vec<Option<Request>>,
    /// Number of `Some` entries in `slots`.
    live: usize,
    /// Waiting request ids. Ids are assigned in submit order, so the
    /// set's natural ordering *is* arrival order (FCFS base ordering).
    waiting: IdBitSet,
    pub completed: Vec<Request>,
    /// Ids refused by admission control (state `Shed`). The requests
    /// stay in the slab (they must appear in the final records as
    /// violations) but leave the waiting set for good.
    shed: Vec<u64>,
}

impl GlobalQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a new request; returns its broker id.
    pub fn submit(&mut self, mut req: Request) -> u64 {
        let id = self.slots.len() as u64;
        req.id = id;
        req.state = RequestState::Waiting;
        self.slots.push(Some(req));
        self.live += 1;
        self.waiting.insert(id);
        id
    }

    pub fn len_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn len_total(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn get(&self, id: u64) -> Option<&Request> {
        self.slots.get(id as usize).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Request> {
        self.slots.get_mut(id as usize).and_then(|s| s.as_mut())
    }

    /// Ids currently waiting, in arrival order (FCFS base ordering).
    pub fn waiting_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.waiting.iter()
    }

    /// Is `id` in the waiting set?
    pub fn is_waiting(&self, id: u64) -> bool {
        self.waiting.contains(id)
    }

    /// Mark a request as pulled into a running batch (Request Pulling LSO).
    /// Removes it from the waiting set; the broker keeps the data until ack.
    /// Returns the state the request was pulled *from* — `Waiting` means
    /// this was the first pull (the waiting→running edge the RWT-accuracy
    /// ledger joins on), `Evicted` a re-pull after eviction.
    pub fn mark_running(&mut self, id: u64) -> Option<RequestState> {
        let prior = match self.get_mut(id) {
            Some(r) => {
                let prior = r.state;
                r.state = RequestState::Running;
                Some(prior)
            }
            None => None,
        };
        self.waiting.remove(id);
        prior
    }

    /// Re-queue an evicted request (Request Eviction LSO): it returns to
    /// the waiting set, retaining progress metadata.
    pub fn requeue_evicted(
        &mut self,
        id: u64,
        generated: u32,
        evicted_from: crate::backend::InstanceId,
    ) {
        if let Some(r) = self.get_mut(id) {
            r.state = RequestState::Evicted;
            r.generated = generated;
            r.evicted_from = Some(evicted_from);
            self.waiting.insert(id);
        }
    }

    /// Ack a completed request: removed from the broker, archived for
    /// metrics. `generated` is the final decode-token count — TPOT
    /// accounting needs it alongside the first-token timestamp.
    pub fn complete(&mut self, id: u64, first_token_s: Option<f64>, completed_s: f64, generated: u32) {
        if let Some(slot) = self.slots.get_mut(id as usize) {
            if let Some(mut r) = slot.take() {
                self.live -= 1;
                r.state = RequestState::Completed;
                if r.first_token_s.is_none() {
                    r.first_token_s = first_token_s;
                }
                r.completed_s = Some(completed_s);
                r.generated = generated;
                self.completed.push(r);
            }
        }
        self.waiting.remove(id);
    }

    /// Shed a request (admission control / unservable-group retirement):
    /// it leaves the waiting set permanently but stays in the broker so
    /// the final records count it exactly once, as a violation. Only
    /// unserved requests can be shed; returns whether the state changed.
    pub fn shed(&mut self, id: u64) -> bool {
        let Some(r) = self.get_mut(id) else {
            return false;
        };
        if !matches!(r.state, RequestState::Waiting | RequestState::Evicted) {
            return false;
        }
        r.state = RequestState::Shed;
        self.waiting.remove(id);
        self.shed.push(id);
        true
    }

    /// Ids shed so far (submit-time refusals + unservable retirements).
    pub fn shed_ids(&self) -> &[u64] {
        &self.shed
    }

    pub fn len_shed(&self) -> usize {
        self.shed.len()
    }

    /// Record a first-token event.
    pub fn record_first_token(&mut self, id: u64, t: f64) {
        if let Some(r) = self.get_mut(id) {
            if r.first_token_s.is_none() {
                r.first_token_s = Some(t);
            }
        }
    }

    /// Instance failure (§4 Fault Isolation): every request that was
    /// running on the lost instance reverts to Waiting; evicted-KV
    /// references to that instance are invalidated (the KV is gone, so
    /// generation restarts from the prompt). Returns affected ids.
    pub fn fail_instance(
        &mut self,
        inst: crate::backend::InstanceId,
        running_ids: &[u64],
    ) -> Vec<u64> {
        let mut affected = Vec::new();
        for &id in running_ids {
            if let Some(r) = self.get_mut(id) {
                r.state = RequestState::Waiting;
                r.generated = 0;
                r.evicted_from = None;
                self.waiting.insert(id);
                affected.push(id);
            }
        }
        // Invalidate stale eviction pointers into the dead instance.
        for r in self.slots.iter_mut().filter_map(|s| s.as_mut()) {
            if r.evicted_from == Some(inst) {
                r.evicted_from = None;
                r.generated = 0;
            }
        }
        affected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{InstanceId, ModelId};
    use crate::workload::{SloClass, SloTarget, TraceRequest};

    fn trace_req(arrival: f64) -> TraceRequest {
        TraceRequest {
            arrival_s: arrival,
            model: ModelId(0),
            class: SloClass::Interactive,
            slo: SloTarget::new(20.0, 0.25),
            input_tokens: 100,
            output_tokens: 50,
            mega: false,
        }
    }

    fn submit_one(q: &mut GlobalQueue, arrival: f64) -> u64 {
        q.submit(Request::from_trace(0, &trace_req(arrival)))
    }

    fn waiting_vec(q: &GlobalQueue) -> Vec<u64> {
        q.waiting_ids().collect()
    }

    #[test]
    fn submit_assigns_ids_in_order() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        assert_eq!(b, a + 1);
        assert_eq!(waiting_vec(&q), vec![a, b]);
        assert_eq!(q.len_waiting(), 2);
    }

    #[test]
    fn pull_then_complete_lifecycle() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.mark_running(id);
        assert_eq!(q.len_waiting(), 0);
        assert_eq!(q.get(id).unwrap().state, RequestState::Running);
        q.record_first_token(id, 3.0);
        q.complete(id, None, 10.0, 50);
        assert!(q.get(id).is_none());
        assert_eq!(q.completed.len(), 1);
        assert_eq!(q.completed[0].ttft(), Some(3.0));
    }

    #[test]
    fn eviction_requeues_with_progress() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.mark_running(id);
        q.requeue_evicted(id, 17, InstanceId(3));
        let r = q.get(id).unwrap();
        assert_eq!(r.state, RequestState::Evicted);
        assert_eq!(r.generated, 17);
        assert_eq!(r.evicted_from, Some(InstanceId(3)));
        assert!(q.is_waiting(id));
    }

    #[test]
    fn requeue_restores_arrival_position() {
        // The waiting set's FCFS base ordering is by arrival: an evicted
        // request re-enters at its arrival rank, not at the back.
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        let c = submit_one(&mut q, 2.0);
        q.mark_running(b);
        q.requeue_evicted(b, 4, InstanceId(0));
        assert_eq!(waiting_vec(&q), vec![a, b, c]);
    }

    #[test]
    fn instance_failure_restores_waiting_state() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        q.mark_running(a);
        q.mark_running(b);
        // b was evicted earlier, its KV parked on the failed instance.
        q.requeue_evicted(b, 9, InstanceId(1));
        let affected = q.fail_instance(InstanceId(1), &[a]);
        assert_eq!(affected, vec![a]);
        let ra = q.get(a).unwrap();
        assert_eq!(ra.state, RequestState::Waiting);
        let rb = q.get(b).unwrap();
        assert_eq!(rb.evicted_from, None, "stale KV pointer invalidated");
        assert_eq!(rb.generated, 0);
        // No request was lost: broker holds the single replica.
        assert_eq!(q.len_total(), 2);
    }

    #[test]
    fn first_token_recorded_once() {
        let mut q = GlobalQueue::new();
        let id = submit_one(&mut q, 0.0);
        q.record_first_token(id, 5.0);
        q.record_first_token(id, 9.0);
        assert_eq!(q.get(id).unwrap().first_token_s, Some(5.0));
    }

    #[test]
    fn acked_ids_never_reused() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        q.mark_running(a);
        q.complete(a, Some(1.0), 2.0, 50);
        let b = submit_one(&mut q, 3.0);
        assert!(b > a, "tombstoned slot must not be recycled");
        assert!(q.get(a).is_none());
        assert_eq!(q.len_total(), 1);
    }

    #[test]
    fn shed_leaves_waiting_but_stays_recorded() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        let b = submit_one(&mut q, 1.0);
        assert!(q.shed(a));
        assert!(!q.shed(a), "double shed is a no-op");
        assert_eq!(q.get(a).unwrap().state, RequestState::Shed);
        assert_eq!(waiting_vec(&q), vec![b]);
        assert_eq!(q.shed_ids(), &[a]);
        assert_eq!(q.len_shed(), 1);
        // Running requests cannot be shed (no mid-flight kills).
        q.mark_running(b);
        assert!(!q.shed(b));
        // The shed request still lives in the broker for the records.
        assert_eq!(q.len_total(), 2);
    }

    #[test]
    fn bitset_iterates_ascending_across_word_boundaries() {
        let mut s = IdBitSet::default();
        for id in [200, 0, 63, 64, 127, 128, 5, 64] {
            s.insert(id);
        }
        assert_eq!(s.len(), 7, "duplicate insert must not double-count");
        let got: Vec<u64> = s.iter().collect();
        assert_eq!(got, vec![0, 5, 63, 64, 127, 128, 200]);
        s.remove(64);
        s.remove(64);
        s.remove(9999); // out of range: no-op
        assert_eq!(s.len(), 6, "duplicate remove must not double-count");
        assert!(!s.contains(64));
        assert!(s.contains(63));
        let got: Vec<u64> = s.iter().collect();
        assert_eq!(got, vec![0, 5, 63, 127, 128, 200]);
    }

    #[test]
    fn double_complete_is_idempotent() {
        let mut q = GlobalQueue::new();
        let a = submit_one(&mut q, 0.0);
        q.mark_running(a);
        q.complete(a, Some(1.0), 2.0, 50);
        q.complete(a, Some(5.0), 6.0, 50);
        assert_eq!(q.completed.len(), 1);
        assert_eq!(q.len_total(), 0);
    }
}
