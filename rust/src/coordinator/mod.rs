//! Layer 3 — the QLM coordinator, the paper's contribution (§3–§7).
//!
//! Requests enter the [`GlobalQueue`] (single-replica broker), are grouped
//! into [`RequestGroup`]s (§4, Algorithm 1), which are assigned and
//! ordered on per-instance [`VirtualQueue`]s by the [`GlobalScheduler`]
//! (§7) using waiting-time estimates from the [`RwtEstimator`] (§6). A
//! per-instance [`QlmAgent`] (§5) translates virtual-queue state into the
//! four LSO actions: request pulling, request eviction, load balancing
//! (implicit in assignment), and model swapping.

pub mod request;
pub mod shard;
pub mod global_queue;
pub mod request_group;
pub mod virtual_queue;
pub mod rwt;
pub mod sched;
pub mod scheduler;
pub mod lso;
pub mod agent;

pub use agent::QlmAgent;
pub use global_queue::GlobalQueue;
pub use lso::{LsoAction, LsoConfig};
pub use request::{Request, RequestState};
pub use request_group::{GroupId, Grouper, RequestGroup};
pub use rwt::{GroupEstimate, RwtEstimator, WorkloadProfile};
pub use scheduler::{GlobalScheduler, SchedulerConfig, SolverKind};
pub use virtual_queue::VirtualQueue;
