//! Per-model queue shards: the storage layer behind [`GlobalQueue`].
//!
//! The broker used to be one flat slab + one waiting bitset. Sharding it
//! by model gives each model its own slab, its own waiting set, and its
//! own open-group index, with three payoffs:
//!
//! * **Disjointness** — a request lives in exactly one shard (requests
//!   never change model), so per-shard scheduler work touches disjoint
//!   state and can fan out over worker threads without locks.
//! * **Dirt tracking** — each shard records whether any of its requests
//!   changed state since the last scheduler pass; a pass skips clean
//!   shards entirely ([`GlobalQueue::begin_pass`]).
//! * **O(in-flight) residency** — shard slots are recycled through a
//!   free list after ack, so at gigascale (10M+ requests) the resident
//!   request memory tracks the number *in flight*, not the all-time
//!   submit count. (Global ids are still never reused: the façade's
//!   route table maps each broker id to its shard slot exactly once.)
//!
//! Waiting sets are keyed by **global** broker id, so the façade's
//! merged iteration (a per-word OR across shards) yields ascending
//! global ids — the FCFS arrival order the scheduler depends on. The
//! bitset words grow with the all-time id space (1 bit per id ≈ 1.2 MB
//! per shard at 10M requests) — accepted: it is two orders of magnitude
//! below what materialized requests would cost.
//!
//! [`GlobalQueue`]: crate::coordinator::GlobalQueue
//! [`GlobalQueue::begin_pass`]: crate::coordinator::GlobalQueue::begin_pass

use std::collections::{BTreeMap, BTreeSet};

use crate::backend::ModelId;
use crate::coordinator::request::Request;
use crate::coordinator::request_group::GroupId;
use crate::workload::SloClass;

/// Ordered set of dense ids: one bit per id. Insert / remove / contains
/// are O(1); iteration is an ascending word scan, so — ids being
/// assigned in submit order — iteration order *is* arrival order,
/// exactly like the `BTreeSet<u64>` this replaced.
#[derive(Debug, Default)]
pub(crate) struct IdBitSet {
    words: Vec<u64>,
    len: usize,
}

impl IdBitSet {
    pub(crate) fn insert(&mut self, id: u64) {
        let (w, b) = ((id / 64) as usize, id % 64);
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let mask = 1u64 << b;
        if self.words[w] & mask == 0 {
            self.words[w] |= mask;
            self.len += 1;
        }
    }

    pub(crate) fn remove(&mut self, id: u64) {
        let (w, b) = ((id / 64) as usize, id % 64);
        if let Some(word) = self.words.get_mut(w) {
            let mask = 1u64 << b;
            if *word & mask != 0 {
                *word &= !mask;
                self.len -= 1;
            }
        }
    }

    pub(crate) fn contains(&self, id: u64) -> bool {
        let (w, b) = ((id / 64) as usize, id % 64);
        self.words.get(w).is_some_and(|word| word & (1 << b) != 0)
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Set ids, ascending. Per word, peel set bits lowest-first
    /// (`trailing_zeros` + clear-lowest) — allocation-free.
    pub(crate) fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.words.iter().enumerate().flat_map(|(w, &word)| {
            std::iter::successors((word != 0).then_some(word), |&bits| {
                let rest = bits & (bits - 1);
                (rest != 0).then_some(rest)
            })
            .map(move |bits| (w as u64) * 64 + bits.trailing_zeros() as u64)
        })
    }

    /// Raw word view — the façade ORs words across shards to iterate
    /// the union waiting set without materializing it.
    pub(crate) fn words(&self) -> &[u64] {
        &self.words
    }
}

/// One per-model shard: a locally-indexed, slot-recycling slab, the
/// model's waiting set (global ids), its open-group index, and a dirty
/// flag for pass skipping.
#[derive(Debug)]
pub(crate) struct QueueShard {
    pub(crate) model: ModelId,
    /// Local slab. Slots are recycled through `free` after ack, so the
    /// resident size is O(live + shed), not O(all-time submits). Safe
    /// because the façade's route table retires a broker id *before*
    /// its slot is freed — a stale id can never alias a recycled slot.
    slots: Vec<Option<Request>>,
    free: Vec<u32>,
    /// Waiting *global* broker ids (ascending = FCFS arrival order).
    pub(crate) waiting: IdBitSet,
    pub(crate) live: usize,
    /// Did any request in this shard change state since the last
    /// scheduler pass? Cleared by [`GlobalQueue::begin_pass`].
    ///
    /// [`GlobalQueue::begin_pass`]: crate::coordinator::GlobalQueue::begin_pass
    pub(crate) dirty: bool,
    /// Open (below-capacity) request groups of this shard's model,
    /// keyed by (class, mega). `BTreeSet` ⇒ the lowest (oldest) group
    /// id wins, matching the engine's historical fill order.
    pub(crate) open_groups: BTreeMap<(SloClass, bool), BTreeSet<GroupId>>,
}

impl QueueShard {
    pub(crate) fn new(model: ModelId) -> Self {
        QueueShard {
            model,
            slots: Vec::new(),
            free: Vec::new(),
            waiting: IdBitSet::default(),
            live: 0,
            dirty: false,
            open_groups: BTreeMap::new(),
        }
    }

    /// Store a request, recycling a freed slot when one is available.
    /// Returns the local slot index.
    pub(crate) fn place(&mut self, req: Request) -> u32 {
        self.live += 1;
        self.dirty = true;
        if let Some(slot) = self.free.pop() {
            debug_assert!(self.slots[slot as usize].is_none(), "free slot must be vacant");
            self.slots[slot as usize] = Some(req);
            slot
        } else {
            self.slots.push(Some(req));
            (self.slots.len() - 1) as u32
        }
    }

    /// Remove the request at `slot` and recycle the slot.
    pub(crate) fn take(&mut self, slot: u32) -> Option<Request> {
        let r = self.slots.get_mut(slot as usize)?.take()?;
        self.live -= 1;
        self.dirty = true;
        self.free.push(slot);
        Some(r)
    }

    pub(crate) fn get(&self, slot: u32) -> Option<&Request> {
        self.slots.get(slot as usize).and_then(|s| s.as_ref())
    }

    pub(crate) fn get_mut(&mut self, slot: u32) -> Option<&mut Request> {
        self.slots.get_mut(slot as usize).and_then(|s| s.as_mut())
    }

    /// Mutable walk over resident requests (instance-failure sweep).
    pub(crate) fn iter_mut(&mut self) -> impl Iterator<Item = &mut Request> {
        self.slots.iter_mut().filter_map(|s| s.as_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::RequestState;
    use crate::workload::{SloTarget, TraceRequest};

    #[test]
    fn bitset_iterates_ascending_across_word_boundaries() {
        let mut s = IdBitSet::default();
        for id in [200, 0, 63, 64, 127, 128, 5, 64] {
            s.insert(id);
        }
        assert_eq!(s.len(), 7, "duplicate insert must not double-count");
        let got: Vec<u64> = s.iter().collect();
        assert_eq!(got, vec![0, 5, 63, 64, 127, 128, 200]);
        s.remove(64);
        s.remove(64);
        s.remove(9999); // out of range: no-op
        assert_eq!(s.len(), 6, "duplicate remove must not double-count");
        assert!(!s.contains(64));
        assert!(s.contains(63));
        let got: Vec<u64> = s.iter().collect();
        assert_eq!(got, vec![0, 5, 63, 127, 128, 200]);
    }

    fn req(id: u64) -> Request {
        Request::from_trace(
            id,
            &TraceRequest {
                arrival_s: id as f64,
                model: ModelId(0),
                class: SloClass::Interactive,
                slo: SloTarget::new(20.0, 0.25),
                input_tokens: 100,
                output_tokens: 50,
                mega: false,
            },
        )
    }

    #[test]
    fn slots_are_recycled_through_the_free_list() {
        let mut s = QueueShard::new(ModelId(0));
        let a = s.place(req(10));
        let b = s.place(req(11));
        assert_eq!((a, b), (0, 1));
        assert_eq!(s.live, 2);
        let taken = s.take(a).unwrap();
        assert_eq!(taken.id, 10);
        assert_eq!(s.live, 1);
        assert!(s.get(a).is_none());
        assert!(s.take(a).is_none(), "double take is a no-op");
        assert_eq!(s.live, 1);
        // The freed slot is reused; the slab does not grow.
        let c = s.place(req(12));
        assert_eq!(c, a, "freed slot must be recycled");
        assert_eq!(s.get(c).unwrap().id, 12);
        assert_eq!(s.get(c).unwrap().state, RequestState::Waiting);
    }

    #[test]
    fn place_and_take_set_the_dirty_flag() {
        let mut s = QueueShard::new(ModelId(0));
        assert!(!s.dirty);
        let slot = s.place(req(0));
        assert!(s.dirty);
        s.dirty = false;
        s.take(slot);
        assert!(s.dirty);
    }
}
