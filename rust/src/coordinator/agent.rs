//! The QLM agent (§5): one per LLM serving instance. It monitors the
//! instance's virtual queue and converts queue state into LSO actions —
//! pull when there is spare token capacity, swap when the head group's
//! model differs from the active one, evict when the head group changed
//! and running lower-priority requests block its admission.
//!
//! The agent holds no policy: "the intelligence required to configure
//! when and which action to set comes from the virtual queue ordering set
//! by the global scheduler."

use std::collections::BTreeMap;

use crate::backend::{InstanceId, ModelId};
use crate::coordinator::lso::{LsoAction, LsoConfig};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::virtual_queue::VirtualQueue;

/// Instance state the agent can observe (decoupled from `backend` so the
/// same agent drives both the simulator and the PJRT engine).
#[derive(Debug, Clone)]
pub struct InstanceObservation {
    pub id: InstanceId,
    pub active_model: Option<ModelId>,
    pub swapping: bool,
    /// Running request ids with their group ids.
    pub running: Vec<(u64, GroupId)>,
    /// Can one more request with `prompt_tokens` be admitted right now?
    pub spare_capacity_tokens: u64,
    pub batch_slots_free: u32,
}

/// Per-instance agent. Stateless between invocations except for the LSO
/// config (ablation toggles).
#[derive(Debug, Clone)]
pub struct QlmAgent {
    pub instance: InstanceId,
    pub lso: LsoConfig,
}

impl QlmAgent {
    pub fn new(instance: InstanceId, lso: LsoConfig) -> Self {
        QlmAgent { instance, lso }
    }

    /// Decide the next LSO actions for this instance given its virtual
    /// queue and observation. Returns actions in execution order.
    ///
    /// * Head group's model ≠ active ⇒ `SwapModel` (if enabled).
    /// * Head group has waiting members and no capacity while running
    ///   requests belong to non-head groups ⇒ `Evict` the newest
    ///   non-head-group requests (if enabled).
    /// * Otherwise `Pull` waiting members of the head group (then deeper
    ///   groups of the same model) while capacity remains.
    pub fn decide(
        &self,
        vq: &VirtualQueue,
        groups: &BTreeMap<GroupId, RequestGroup>,
        waiting_of_group: impl Fn(GroupId) -> Vec<u64>,
        obs: &InstanceObservation,
        prompt_tokens_of: impl Fn(u64) -> u64,
    ) -> Vec<LsoAction> {
        let mut actions = Vec::new();
        if obs.swapping {
            return actions;
        }
        let Some(head_id) = vq.head() else {
            return actions;
        };
        let Some(head) = groups.get(&head_id) else {
            return actions;
        };

        // ④ Model swapping.
        if obs.active_model != Some(head.model) {
            if self.lso.model_swapping || obs.active_model.is_none() {
                actions.push(LsoAction::SwapModel {
                    instance: self.instance,
                    model: head.model,
                });
            }
            // Either way nothing else can happen until the model matches.
            return actions;
        }

        // ① Request pulling: head group first (FCFS within group), then
        // same-model groups deeper in the queue.
        let mut spare_tokens = obs.spare_capacity_tokens;
        let mut slots = obs.batch_slots_free;
        let mut pull_ids: Vec<u64> = Vec::new();
        if self.lso.ordered_pulling {
            // FCFS across the same-model prefix: stop at the first
            // request that doesn't fit instead of scanning deeper groups
            // for a smaller one. Skipping a blocked request would both
            // violate queue order and make every capacity-limited wake
            // walk the entire virtual queue — O(all groups) per wake,
            // which dominates at 100K-request queue scale.
            let mut blocked = false;
            for &gid in vq.groups.iter() {
                let Some(g) = groups.get(&gid) else { continue };
                if g.model != head.model {
                    break; // stop at the first model boundary
                }
                for r in waiting_of_group(gid) {
                    let need = prompt_tokens_of(r);
                    if slots == 0 || need > spare_tokens {
                        blocked = true;
                        break;
                    }
                    spare_tokens -= need;
                    slots -= 1;
                    pull_ids.push(r);
                }
                if blocked || slots == 0 {
                    break;
                }
            }
        } else {
            // Ablation: FCFS over all waiting members regardless of order.
            let mut all: Vec<u64> = vq
                .groups
                .iter()
                .filter(|gid| groups.get(gid).map(|g| g.model) == Some(head.model))
                .flat_map(|&gid| waiting_of_group(gid))
                .collect();
            all.sort_unstable();
            for r in all {
                let need = prompt_tokens_of(r);
                if slots == 0 || need > spare_tokens {
                    break;
                }
                spare_tokens -= need;
                slots -= 1;
                pull_ids.push(r);
            }
        }

        // ② Request eviction: head group members still waiting, no room,
        // and the batch is occupied by non-head groups ⇒ evict newest
        // non-head requests to clear space (§5: "requests of the head
        // request group are pulled into the running batch ... previously
        // running requests are evicted").
        let head_waiting: Vec<u64> = waiting_of_group(head_id);
        let head_blocked = !head_waiting.is_empty()
            && pull_ids.iter().filter(|r| head_waiting.contains(r)).count() == 0;
        if head_blocked && self.lso.eviction {
            let victims: Vec<u64> = obs
                .running
                .iter()
                .filter(|(_, g)| *g != head_id)
                .map(|(r, _)| *r)
                .collect();
            if !victims.is_empty() {
                // Evict enough newest victims to fit the first head request.
                let need = head_waiting
                    .first()
                    .map(|&r| prompt_tokens_of(r))
                    .unwrap_or(0);
                let mut freed = 0u64;
                let mut chosen = Vec::new();
                for &v in victims.iter().rev() {
                    chosen.push(v);
                    freed += prompt_tokens_of(v);
                    if freed + obs.spare_capacity_tokens >= need {
                        break;
                    }
                }
                actions.push(LsoAction::Evict {
                    instance: self.instance,
                    requests: chosen,
                });
            }
        }

        actions.extend(pull_ids.into_iter().map(|request| LsoAction::Pull {
            instance: self.instance,
            request,
        }));
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{SloClass, SloTarget};

    fn grp(id: u64, model: u32, members: &[u64]) -> RequestGroup {
        RequestGroup {
            id: GroupId(id),
            model: ModelId(model),
            class: SloClass::Batch1,
            slo: SloTarget::new(60.0, 1.0),
            earliest_arrival_s: 0.0,
            members: members.to_vec(),
            mega: false,
        }
    }

    fn setup(vq_groups: &[RequestGroup]) -> (VirtualQueue, BTreeMap<GroupId, RequestGroup>) {
        let mut vq = VirtualQueue::new(InstanceId(0));
        let mut map = BTreeMap::new();
        for g in vq_groups {
            vq.push_back(g.id);
            map.insert(g.id, g.clone());
        }
        (vq, map)
    }

    fn obs(active: Option<u32>, spare: u64, slots: u32) -> InstanceObservation {
        InstanceObservation {
            id: InstanceId(0),
            active_model: active.map(ModelId),
            swapping: false,
            running: vec![],
            spare_capacity_tokens: spare,
            batch_slots_free: slots,
        }
    }

    /// The waiting-members closure every test hands to `decide`.
    fn members_of(map: &BTreeMap<GroupId, RequestGroup>) -> impl Fn(GroupId) -> Vec<u64> + '_ {
        |g| map[&g].members.iter().copied().collect()
    }

    #[test]
    fn swap_issued_when_head_model_differs() {
        let agent = QlmAgent::new(InstanceId(0), LsoConfig::all());
        let (vq, map) = setup(&[grp(1, 1, &[10])]);
        let o = obs(Some(0), 1000, 8);
        let actions = agent.decide(&vq, &map, members_of(&map), &o, |_| 100);
        assert_eq!(
            actions,
            vec![LsoAction::SwapModel {
                instance: InstanceId(0),
                model: ModelId(1)
            }]
        );
    }

    #[test]
    fn swap_suppressed_by_ablation_unless_cold() {
        let agent = QlmAgent::new(InstanceId(0), LsoConfig::without_swapping());
        let (vq, map) = setup(&[grp(1, 1, &[10])]);
        // Active model present but different: no swap under ablation.
        let o = obs(Some(0), 1000, 8);
        let a = agent.decide(&vq, &map, members_of(&map), &o, |_| 100);
        assert!(a.is_empty());
        // Cold instance must still load its first model.
        let a2 = agent.decide(&vq, &map, members_of(&map), &obs(None, 1000, 8), |_| 100);
        assert_eq!(a2.len(), 1);
    }

    #[test]
    fn pulls_fcfs_from_head_group_within_capacity() {
        let agent = QlmAgent::new(InstanceId(0), LsoConfig::all());
        let (vq, map) = setup(&[grp(1, 0, &[10, 11, 12])]);
        let o = obs(Some(0), 250, 8);
        let actions = agent.decide(&vq, &map, members_of(&map), &o, |_| 100);
        // 250 tokens of space, 100 per prompt → two pulls.
        assert_eq!(
            actions,
            vec![
                LsoAction::Pull {
                    instance: InstanceId(0),
                    request: 10
                },
                LsoAction::Pull {
                    instance: InstanceId(0),
                    request: 11
                },
            ]
        );
    }

    #[test]
    fn pulls_cross_group_boundary_same_model_only() {
        let agent = QlmAgent::new(InstanceId(0), LsoConfig::all());
        let (vq, map) = setup(&[grp(1, 0, &[10]), grp(2, 0, &[20]), grp(3, 1, &[30])]);
        let o = obs(Some(0), 10_000, 8);
        let actions = agent.decide(&vq, &map, members_of(&map), &o, |_| 100);
        let pulled: Vec<u64> = actions
            .iter()
            .filter_map(|a| match a {
                LsoAction::Pull { request, .. } => Some(*request),
                _ => None,
            })
            .collect();
        assert_eq!(pulled, vec![10, 20], "model-1 group must not be pulled");
    }

    #[test]
    fn evicts_non_head_requests_when_head_blocked() {
        let agent = QlmAgent::new(InstanceId(0), LsoConfig::all());
        let (vq, map) = setup(&[grp(1, 0, &[10]), grp(2, 0, &[])]);
        let mut o = obs(Some(0), 0, 8); // no spare capacity
        o.running = vec![(20, GroupId(2)), (21, GroupId(2))];
        let actions = agent.decide(&vq, &map, members_of(&map), &o, |_| 100);
        match &actions[0] {
            LsoAction::Evict { requests, .. } => {
                assert!(requests.contains(&21), "newest victim evicted first");
            }
            other => panic!("expected eviction, got {other:?}"),
        }
    }

    #[test]
    fn no_eviction_under_ablation() {
        let agent = QlmAgent::new(InstanceId(0), LsoConfig::without_eviction());
        let (vq, map) = setup(&[grp(1, 0, &[10]), grp(2, 0, &[])]);
        let mut o = obs(Some(0), 0, 8);
        o.running = vec![(20, GroupId(2))];
        let actions = agent.decide(&vq, &map, members_of(&map), &o, |_| 100);
        assert!(actions.is_empty(), "{actions:?}");
    }

    #[test]
    fn idle_during_swap() {
        let agent = QlmAgent::new(InstanceId(0), LsoConfig::all());
        let (vq, map) = setup(&[grp(1, 0, &[10])]);
        let mut o = obs(Some(0), 1000, 8);
        o.swapping = true;
        let actions = agent.decide(&vq, &map, members_of(&map), &o, |_| 100);
        assert!(actions.is_empty());
    }
}
