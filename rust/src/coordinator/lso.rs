//! LLM Serving Operations (§5): the four backend actions the QLM agent
//! actuates from virtual-queue state. The LSOs are "merely action
//! actuators" — policy lives in the global scheduler's queue ordering.

use crate::backend::{InstanceId, ModelId};

/// One actuated backend operation.
#[derive(Debug, Clone, PartialEq)]
pub enum LsoAction {
    /// ① Dequeue a request from the virtual queue into the running batch.
    Pull {
        instance: InstanceId,
        request: u64,
    },
    /// ② Evict running requests back to the global queue (KV → CPU).
    Evict {
        instance: InstanceId,
        requests: Vec<u64>,
    },
    /// ④ Swap the active model (flushes KV, displaces running requests).
    SwapModel {
        instance: InstanceId,
        model: ModelId,
    },
}

/// Which LSOs are enabled — the knobs for the ablation studies
/// (Fig. 11 / Fig. 14 remove one LSO at a time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LsoConfig {
    /// Request pulling can't be disabled (nothing would ever run);
    /// the ablation downgrade is "pull strictly FCFS, ignore the virtual
    /// queue ordering".
    pub ordered_pulling: bool,
    /// ② Request eviction.
    pub eviction: bool,
    /// ③ Load balancing (RWT-aware assignment vs round-robin).
    pub load_balancing: bool,
    /// ④ Model swapping (off ⇒ instances are pinned to their first model).
    pub model_swapping: bool,
}

impl Default for LsoConfig {
    fn default() -> Self {
        LsoConfig {
            ordered_pulling: true,
            eviction: true,
            load_balancing: true,
            model_swapping: true,
        }
    }
}

impl LsoConfig {
    pub fn all() -> Self {
        Self::default()
    }

    pub fn without_eviction() -> Self {
        LsoConfig {
            eviction: false,
            ..Self::default()
        }
    }

    pub fn without_swapping() -> Self {
        LsoConfig {
            model_swapping: false,
            ..Self::default()
        }
    }

    pub fn without_load_balancing() -> Self {
        LsoConfig {
            load_balancing: false,
            ..Self::default()
        }
    }

    pub fn without_ordered_pulling() -> Self {
        LsoConfig {
            ordered_pulling: false,
            ..Self::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_enables_everything() {
        let c = LsoConfig::default();
        assert!(c.ordered_pulling && c.eviction && c.load_balancing && c.model_swapping);
    }

    #[test]
    fn ablation_constructors_disable_one() {
        assert!(!LsoConfig::without_eviction().eviction);
        assert!(!LsoConfig::without_swapping().model_swapping);
        assert!(!LsoConfig::without_load_balancing().load_balancing);
        assert!(!LsoConfig::without_ordered_pulling().ordered_pulling);
        // And leave the rest on.
        assert!(LsoConfig::without_eviction().model_swapping);
    }

    #[test]
    fn actions_are_comparable() {
        let a = LsoAction::Pull {
            instance: InstanceId(0),
            request: 1,
        };
        assert_eq!(
            a,
            LsoAction::Pull {
                instance: InstanceId(0),
                request: 1
            }
        );
        assert_ne!(
            a,
            LsoAction::SwapModel {
                instance: InstanceId(0),
                model: ModelId(1)
            }
        );
    }
}
