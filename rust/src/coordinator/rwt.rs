//! Request Waiting Time (RWT) estimator — §6 and Appendix A.1.
//!
//! Completion time of request q:   C_q = W_q + P + D_q            (Eq. 1)
//! Waiting time:                   W_q = Σ_{i<q} O_i / Θ          (Eq. 2)
//! Output tokens ahead:            Σ O_i ~ N((q-1)μ_o,(q-1)σ_o²)  (Eq. 3)
//! Decode time:                    D_q = O_q · ε · d              (Eq. 4)
//! Group completion:               C   = max_q C_q                (Eq. 5)
//!
//! Token generation throughput Θ = B/(δ·ε) with B set by GPU token
//! capacity over the mean per-request footprint (Appendix Eqs. 15–16).
//! O_q is unknown a priori: per-group (μ_o, σ_o) come from workload
//! profiling; the single-request decode term uses the model's max output
//! bound — conservative for short queues, with the error vanishing as the
//! queue grows and W dominates (§6, Fig. 18).

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::backend::{GpuKind, ModelId, PerfModel};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::workload::{SloClass, Trace};

/// Per-(model, class, mega) output/input token moments — the product of
/// QLM's offline *workload profiling* step (§6).
#[derive(Debug, Clone, Copy)]
pub struct WorkloadProfile {
    pub mu_in: f64,
    pub sigma_in: f64,
    pub mu_out: f64,
    pub sigma_out: f64,
    /// Maximum output tokens the model will generate (generation cap) —
    /// the conservative single-request decode bound.
    pub max_out: f64,
}

impl WorkloadProfile {
    /// Mean tokens resident per request (prompt + generated KV).
    pub fn mean_tokens_per_req(&self) -> f64 {
        self.mu_in + self.mu_out
    }
}

/// Profile table keyed by (model, class, mega).
#[derive(Debug, Clone, Default)]
pub struct ProfileTable {
    map: BTreeMap<(ModelId, SloClass, bool), WorkloadProfile>,
}

impl ProfileTable {
    /// Workload profiling: sample moments from a trace (the paper samples
    /// the request history dataset per request group).
    pub fn from_trace(trace: &Trace) -> Self {
        let mut acc: BTreeMap<(ModelId, SloClass, bool), (Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for r in &trace.requests {
            let e = acc.entry((r.model, r.class, r.mega)).or_default();
            e.0.push(r.input_tokens as f64);
            e.1.push(r.output_tokens as f64);
        }
        let mut map = BTreeMap::new();
        for (k, (ins, outs)) in acc {
            map.insert(
                k,
                WorkloadProfile {
                    mu_in: crate::util::mean(&ins),
                    sigma_in: crate::util::stddev(&ins),
                    mu_out: crate::util::mean(&outs),
                    sigma_out: crate::util::stddev(&outs),
                    max_out: outs.iter().cloned().fold(0.0, f64::max),
                },
            );
        }
        ProfileTable { map }
    }

    pub fn insert(&mut self, model: ModelId, class: SloClass, mega: bool, p: WorkloadProfile) {
        self.map.insert((model, class, mega), p);
    }

    /// Iterate the profiled (model, class, mega) keys in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = (ModelId, SloClass, bool)> + '_ {
        self.map.keys().copied()
    }

    pub fn get(&self, model: ModelId, class: SloClass, mega: bool) -> WorkloadProfile {
        if let Some(p) = self.map.get(&(model, class, mega)) {
            return *p;
        }
        // Fall back to any profile for the model, then to a generic prior.
        self.map
            .iter()
            .find(|((m, _, _), _)| *m == model)
            .map(|(_, p)| *p)
            .unwrap_or(WorkloadProfile {
                mu_in: 161.0,
                sigma_in: 200.0,
                mu_out: 338.0,
                sigma_out: 280.0,
                max_out: 2048.0,
            })
    }
}

/// Estimate for one request group's position in a virtual queue.
#[derive(Debug, Clone, Copy)]
pub struct GroupEstimate {
    /// Mean waiting time until the group reaches the head (starts serving).
    pub wait_mean_s: f64,
    /// Std of the waiting time (CLT over output tokens ahead, Eq. 3).
    pub wait_std_s: f64,
    /// Mean time until the whole group completes (Eq. 5 aggregate).
    pub completion_mean_s: f64,
    /// Conservative (upper-bound) completion incl. the max-output decode
    /// term — what the scheduler compares against SLOs.
    pub completion_bound_s: f64,
    /// Swap latency charged before this group starts, if any.
    pub swap_s: f64,
}

/// Memo key for a group-service estimate: the estimate is a pure
/// function of the group's profile identity (model, class, mega), its
/// current member count, and [`PerfKey`] — every perf constant the
/// service computation reads. The group id is included so pruning tracks
/// live groups rather than deduplicating across identically-shaped ones.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ServiceKey {
    group: GroupId,
    model: ModelId,
    class: SloClass,
    mega: bool,
    len: u32,
    perf: PerfKey,
}

/// Exact identity of the perf constants consumed by
/// [`RwtEstimator::group_service`]: Θ comes from `measured_theta` when
/// set, else from `steady_throughput` — which reads the decode floor,
/// KV-read slope, ε, token capacity, and max batch. All of them are in
/// the key so two views never share an entry unless the estimate is
/// genuinely identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PerfKey {
    gpu: GpuKind,
    tp: u32,
    theta_bits: u64,
    decode_bits: u64,
    kv_read_bits: u64,
    epsilon_bits: u64,
    token_capacity: u64,
    max_batch: u32,
}

impl PerfKey {
    fn of(perf: &PerfModel) -> Self {
        PerfKey {
            gpu: perf.gpu,
            tp: perf.tp,
            theta_bits: perf.measured_theta.map(f64::to_bits).unwrap_or(0),
            decode_bits: perf.decode_s_per_token.to_bits(),
            kv_read_bits: perf.kv_read_s_per_token.to_bits(),
            epsilon_bits: perf.epsilon.to_bits(),
            token_capacity: perf.token_capacity,
            max_batch: perf.max_batch,
        }
    }
}

/// §Perf: per-(group, instance-view) epoch memo of [`RwtEstimator::group_service`].
/// The global scheduler re-prices every (group × instance) pair on each
/// invocation; between invocations almost nothing changes — a group's
/// service estimate only moves when members complete. Entries untouched
/// for a full epoch window are pruned so the map tracks the live group
/// set instead of growing with every group ever created.
#[derive(Debug, Clone, Default)]
struct ServiceMemo {
    map: BTreeMap<ServiceKey, (f64, f64, u64)>,
    epoch: u64,
    hits: u64,
    misses: u64,
}

/// How many epochs between prune sweeps of the service memo.
const MEMO_PRUNE_INTERVAL: u64 = 256;

/// The RWT estimator: stateless over (perf, profiles); all methods are
/// pure so the global scheduler can evaluate candidate orderings cheaply.
/// The only interior state is the epoch memo above, which caches — never
/// changes — results.
#[derive(Debug, Clone)]
pub struct RwtEstimator {
    pub profiles: ProfileTable,
    memo: RefCell<ServiceMemo>,
}

impl RwtEstimator {
    pub fn new(profiles: ProfileTable) -> Self {
        RwtEstimator {
            profiles,
            memo: RefCell::new(ServiceMemo::default()),
        }
    }

    /// Advance the memo epoch (one *full* global-scheduler solve) and
    /// periodically prune entries not referenced since the last sweep.
    ///
    /// Incremental delta passes deliberately do **not** advance the
    /// epoch: a delta pass re-prices only dirty groups, so clean groups'
    /// entries would look stale after `MEMO_PRUNE_INTERVAL` passes and
    /// get evicted even though their prices are still live. Service
    /// prices therefore survive across scheduler passes; the primary
    /// cleanup path is liveness-based ([`Self::forget_group`]), with
    /// epoch pruning as a backstop across full solves.
    pub fn begin_epoch(&self) {
        let mut m = self.memo.borrow_mut();
        m.epoch += 1;
        if m.epoch % MEMO_PRUNE_INTERVAL == 0 {
            let cutoff = m.epoch.saturating_sub(MEMO_PRUNE_INTERVAL);
            m.map.retain(|_, v| v.2 >= cutoff);
        }
    }

    /// Drop every memoized service price for `g` — called when the group
    /// drains (all members complete) or is dissolved. With incremental
    /// scheduling keeping prices alive across passes indefinitely, this
    /// liveness-based eviction is what keeps the memo tracking the live
    /// group set.
    ///
    /// Cost note: the retain scans the whole memo, but both factors are
    /// *group*-granular — drains over a run ≈ requests / (δ·B), and the
    /// memo holds live-groups × instance-views entries — so even a
    /// 100K-request `scale` run does a few thousand scans of a
    /// few-thousand-entry map. A per-group key index isn't worth its
    /// bookkeeping until group counts grow orders of magnitude.
    pub fn forget_group(&self, g: GroupId) {
        self.memo.borrow_mut().map.retain(|k, _| k.group != g);
    }

    /// (hits, misses) of the group-service memo — observability for the
    /// perf tests and the bench harness.
    pub fn memo_stats(&self) -> (u64, u64) {
        let m = self.memo.borrow();
        (m.hits, m.misses)
    }

    /// Θ for a group's steady state on `perf` (Appendix Eqs. 15–16).
    pub fn throughput(&self, perf: &PerfModel, profile: &WorkloadProfile) -> f64 {
        perf.steady_throughput(profile.mean_tokens_per_req())
    }

    /// Eq. 2/3 — waiting time distribution for a request with `q_ahead`
    /// requests ahead of it in the queue: mean and std in seconds.
    ///
    /// Waiting counts *pending* output tokens (§6): the first
    /// steady-batch-worth of requests ahead are already in the running
    /// batch and do not queue, so they are excluded.
    pub fn request_wait(
        &self,
        q_ahead: usize,
        perf: &PerfModel,
        profile: &WorkloadProfile,
    ) -> (f64, f64) {
        let theta = self.throughput(perf, profile);
        let b = perf.steady_batch(profile.mean_tokens_per_req()) as usize;
        let pending = q_ahead.saturating_sub(b) as f64;
        let mean = pending * profile.mu_out / theta;
        let std = pending.sqrt() * profile.sigma_out / theta;
        (mean, std)
    }

    /// Eq. 4 — conservative decode-time bound for a single request.
    pub fn decode_bound(&self, perf: &PerfModel, profile: &WorkloadProfile) -> f64 {
        profile.max_out * perf.epsilon * perf.decode_s_per_token
    }

    /// Mean service time to drain a whole group of `n` requests: the
    /// group's total expected output tokens over Θ (waiting-time view of
    /// the group for queue positions behind it). Memoized per
    /// (group, instance-view) epoch — see [`ServiceMemo`].
    pub fn group_service(&self, group: &RequestGroup, perf: &PerfModel) -> (f64, f64) {
        let key = ServiceKey {
            group: group.id,
            model: group.model,
            class: group.class,
            mega: group.mega,
            len: group.len() as u32,
            perf: PerfKey::of(perf),
        };
        {
            let mut guard = self.memo.borrow_mut();
            let m = &mut *guard;
            if let Some(v) = m.map.get_mut(&key) {
                v.2 = m.epoch;
                m.hits += 1;
                return (v.0, v.1);
            }
        }
        let p = self.profiles.get(group.model, group.class, group.mega);
        let theta = self.throughput(perf, &p);
        let n = group.len() as f64;
        // Evicted members carry partial progress; we ignore that here —
        // conservative (overestimates remaining tokens).
        let mean = n * p.mu_out / theta;
        let std = n.sqrt() * p.sigma_out / theta;
        let mut m = self.memo.borrow_mut();
        m.misses += 1;
        let epoch = m.epoch;
        m.map.insert(key, (mean, std, epoch));
        (mean, std)
    }

    /// Walk a virtual-queue ordering and produce per-group estimates
    /// (Eq. 10's wt_{g,j} terms): accumulated waiting = service of groups
    /// ahead + swap times at model transitions; completion adds the
    /// group's own service plus prefill and the conservative decode bound.
    pub fn estimate_queue(
        &self,
        order: &[&RequestGroup],
        perf: &PerfModel,
        active_model: Option<ModelId>,
        swap_time_for: impl Fn(ModelId) -> f64,
    ) -> Vec<GroupEstimate> {
        let mut out = Vec::with_capacity(order.len());
        let mut wait_mean = 0.0;
        let mut wait_var: f64 = 0.0;
        let mut current = active_model;
        for g in order {
            let p = self.profiles.get(g.model, g.class, g.mega);
            let swap_s = if current != Some(g.model) {
                swap_time_for(g.model)
            } else {
                0.0
            };
            current = Some(g.model);
            wait_mean += swap_s;
            let (svc_mean, svc_std) = self.group_service(g, perf);
            let start_mean = wait_mean;
            let start_std = wait_var.max(0.0_f64).sqrt();
            let completion_mean = start_mean + perf.prefill_s + svc_mean;
            let completion_bound = completion_mean
                + 2.0 * (wait_var + svc_std * svc_std).sqrt()
                + self.decode_bound(perf, &p);
            out.push(GroupEstimate {
                wait_mean_s: start_mean,
                wait_std_s: start_std,
                completion_mean_s: completion_mean,
                completion_bound_s: completion_bound,
                swap_s,
            });
            wait_mean += svc_mean + perf.prefill_s;
            wait_var += svc_std * svc_std;
        }
        out
    }

    /// Does the ordering violate any group SLO *now*? (§4, Handling New
    /// Incoming Requests: the estimator triggers the global scheduler.)
    /// `now` converts group deadlines to remaining budgets.
    pub fn detect_violation(
        &self,
        order: &[&RequestGroup],
        perf: &PerfModel,
        active_model: Option<ModelId>,
        swap_time_for: impl Fn(ModelId) -> f64,
        now: f64,
    ) -> bool {
        let est = self.estimate_queue(order, perf, active_model, swap_time_for);
        order.iter().zip(&est).any(|(g, e)| {
            let budget = g.deadline() - now;
            // Conservative (§6): trigger on the upper bound, not the mean.
            e.completion_bound_s > budget
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{GpuKind, ModelCatalog};
    use crate::workload::WorkloadSpec;

    fn perf() -> PerfModel {
        let c = ModelCatalog::paper();
        PerfModel::profile(c.get(ModelId(0)), GpuKind::A100, 161.0)
    }

    fn profile() -> WorkloadProfile {
        WorkloadProfile {
            mu_in: 161.0,
            sigma_in: 150.0,
            mu_out: 338.0,
            sigma_out: 250.0,
            max_out: 2048.0,
        }
    }

    fn mk_group(id: u64, model: u32, n: usize, arrival: f64, slo: f64) -> RequestGroup {
        RequestGroup {
            id: crate::coordinator::request_group::GroupId(id),
            model: ModelId(model),
            class: SloClass::Batch1,
            slo: crate::workload::SloTarget::new(slo, 1.0),
            earliest_arrival_s: arrival,
            members: (0..n as u64).collect(),
            mega: false,
        }
    }

    #[test]
    fn wait_linear_in_pending_position() {
        // Insight #1 / Fig. 3: waiting time grows linearly with the number
        // of *pending* requests ahead (the in-flight batch doesn't queue).
        let est = RwtEstimator::new(ProfileTable::default());
        let p = perf();
        let prof = profile();
        let b = p.steady_batch(prof.mean_tokens_per_req()) as usize;
        let (w0, _) = est.request_wait(b, &p, &prof);
        assert_eq!(w0, 0.0, "requests inside the running batch don't wait");
        let (w1, _) = est.request_wait(b + 100, &p, &prof);
        let (w2, _) = est.request_wait(b + 200, &p, &prof);
        assert!((w2 / w1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn wait_std_grows_sublinearly() {
        // CLT: std ∝ √pending, so relative error shrinks with queue length.
        let est = RwtEstimator::new(ProfileTable::default());
        let p = perf();
        let prof = profile();
        let b = p.steady_batch(prof.mean_tokens_per_req()) as usize;
        let (m1, s1) = est.request_wait(b + 16, &p, &prof);
        let (m2, s2) = est.request_wait(b + 256, &p, &prof);
        assert!(s2 / m2 < s1 / m1);
        assert!((s2 / s1 - 4.0).abs() < 1e-9); // √(256/16) = 4
    }

    #[test]
    fn profiles_from_trace_reasonable() {
        let spec = WorkloadSpec::w_a(ModelId(0), 100.0, 3500);
        let trace = Trace::generate(&spec, 1);
        let t = ProfileTable::from_trace(&trace);
        let p = t.get(ModelId(0), SloClass::Interactive, false);
        assert!((100.0..260.0).contains(&p.mu_in), "{}", p.mu_in);
        assert!((250.0..430.0).contains(&p.mu_out), "{}", p.mu_out);
        assert!(p.max_out <= 2048.0);
    }

    #[test]
    fn profile_fallback_for_unknown_key() {
        let t = ProfileTable::default();
        let p = t.get(ModelId(7), SloClass::Batch2, true);
        assert!(p.mu_out > 0.0);
    }

    #[test]
    fn queue_estimates_accumulate_and_charge_swaps() {
        let spec = WorkloadSpec::w_a(ModelId(0), 100.0, 2000);
        let trace = Trace::generate(&spec, 2);
        let est = RwtEstimator::new(ProfileTable::from_trace(&trace));
        let p = perf();
        let g1 = mk_group(1, 0, 32, 0.0, 60.0);
        let g2 = mk_group(2, 1, 32, 0.0, 3600.0);
        let g3 = mk_group(3, 0, 32, 0.0, 3600.0);
        let order = [&g1, &g2, &g3];
        let swap = |_m: ModelId| 5.0;
        let es = est.estimate_queue(&order, &p, Some(ModelId(0)), swap);
        // Group 1: active model matches, no swap.
        assert_eq!(es[0].swap_s, 0.0);
        assert_eq!(es[0].wait_mean_s, 0.0);
        // Group 2: model switch charged.
        assert_eq!(es[1].swap_s, 5.0);
        assert!(es[1].wait_mean_s > es[0].wait_mean_s);
        // Group 3: switch back charged, waits behind both.
        assert_eq!(es[2].swap_s, 5.0);
        assert!(es[2].wait_mean_s > es[1].wait_mean_s);
        // Bound dominates mean (conservative).
        for e in &es {
            assert!(e.completion_bound_s > e.completion_mean_s);
        }
    }

    #[test]
    fn violation_detected_for_tight_slo_behind_long_queue() {
        let spec = WorkloadSpec::w_a(ModelId(0), 100.0, 2000);
        let trace = Trace::generate(&spec, 3);
        let est = RwtEstimator::new(ProfileTable::from_trace(&trace));
        let p = perf();
        let big = mk_group(1, 0, 256, 0.0, 3600.0);
        let tight = mk_group(2, 0, 4, 0.0, 5.0); // 5s SLO behind 256 requests
        let ok_order = [&tight, &big];
        let bad_order = [&big, &tight];
        let swap = |_m: ModelId| 0.0;
        assert!(!est.detect_violation(&ok_order, &p, Some(ModelId(0)), swap, 0.0)
            || est.detect_violation(&bad_order, &p, Some(ModelId(0)), swap, 0.0));
        assert!(est.detect_violation(&bad_order, &p, Some(ModelId(0)), swap, 0.0));
    }

    #[test]
    fn group_service_memoized_per_group_and_view() {
        let est = RwtEstimator::new(ProfileTable::default());
        let p = perf();
        let g = mk_group(1, 0, 64, 0.0, 60.0);
        let a = est.group_service(&g, &p);
        let b = est.group_service(&g, &p);
        assert_eq!(a, b);
        let (hits, misses) = est.memo_stats();
        assert_eq!((hits, misses), (1, 1), "second lookup must hit");
    }

    #[test]
    fn group_service_memo_invalidated_by_member_count() {
        let est = RwtEstimator::new(ProfileTable::default());
        let p = perf();
        let mut g = mk_group(2, 0, 64, 0.0, 60.0);
        let (full, _) = est.group_service(&g, &p);
        g.members.remove(0);
        let (smaller, _) = est.group_service(&g, &p);
        assert!(
            smaller < full,
            "shrunk group must be re-priced: {smaller} vs {full}"
        );
    }

    #[test]
    fn memo_distinguishes_perf_constants() {
        // Same gpu/tp/decode floor but different token capacity ⇒ a
        // different steady batch ⇒ a different estimate. The memo must
        // not serve the first perf's value for the second.
        let est = RwtEstimator::new(ProfileTable::default());
        let p1 = perf();
        let mut p2 = p1;
        p2.token_capacity /= 8;
        let g = mk_group(4, 0, 64, 0.0, 60.0);
        let (a, _) = est.group_service(&g, &p1);
        let (b, _) = est.group_service(&g, &p2);
        assert!(b > a, "smaller KV capacity must slow service: {a} vs {b}");
    }

    #[test]
    fn forget_group_evicts_all_entries_for_that_group() {
        let est = RwtEstimator::new(ProfileTable::default());
        let p1 = perf();
        let mut p2 = p1;
        p2.token_capacity /= 8;
        let g = mk_group(5, 0, 64, 0.0, 60.0);
        let other = mk_group(6, 0, 64, 0.0, 60.0);
        est.group_service(&g, &p1);
        est.group_service(&g, &p2);
        est.group_service(&other, &p1);
        est.forget_group(g.id);
        // Both of g's per-view entries are gone; `other` survives.
        est.group_service(&g, &p1);
        est.group_service(&other, &p1);
        let (hits, misses) = est.memo_stats();
        assert_eq!(hits, 1, "only `other` may hit after forget");
        assert_eq!(misses, 4);
    }

    #[test]
    fn memo_prunes_stale_entries_after_epoch_window() {
        let est = RwtEstimator::new(ProfileTable::default());
        let p = perf();
        let g = mk_group(3, 0, 32, 0.0, 60.0);
        est.group_service(&g, &p);
        for _ in 0..512 {
            est.begin_epoch();
        }
        est.group_service(&g, &p);
        let (hits, misses) = est.memo_stats();
        assert_eq!(hits, 0, "entry was pruned, so this is a miss");
        assert_eq!(misses, 2);
    }

    #[test]
    fn throughput_uses_steady_batch() {
        let est = RwtEstimator::new(ProfileTable::default());
        let p = perf();
        let prof = profile();
        let theta = est.throughput(&p, &prof);
        // Mistral on A100: hundreds-to-thousands of tokens/s regime.
        assert!(theta > 500.0 && theta < 50_000.0, "theta={theta}");
    }
}
