//! Virtual queues (§4, Definition 4.2): an ordered sequence of request
//! groups per LLM serving instance. Virtual queues are lightweight — they
//! hold group ids referencing requests stored once in the global queue, so
//! they can be dropped and rebuilt on instance failure without losing data
//! (§4, Fault Tolerance).

use std::collections::VecDeque;

use crate::backend::{InstanceId, ModelId};
use crate::coordinator::request_group::{GroupId, RequestGroup};

/// Per-instance ordered queue of request groups.
#[derive(Debug, Clone)]
pub struct VirtualQueue {
    pub instance: InstanceId,
    pub groups: VecDeque<GroupId>,
}

impl VirtualQueue {
    pub fn new(instance: InstanceId) -> Self {
        VirtualQueue {
            instance,
            groups: VecDeque::new(),
        }
    }

    pub fn head(&self) -> Option<GroupId> {
        self.groups.front().copied()
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    pub fn push_back(&mut self, g: GroupId) {
        self.groups.push_back(g);
    }

    /// Place a group at the head — the scheduler's eviction trigger (§5):
    /// "the global scheduler replaces an existing request group by placing
    /// a request group at the head of the virtual queue".
    pub fn push_front(&mut self, g: GroupId) {
        self.groups.push_front(g);
    }

    pub fn remove(&mut self, g: GroupId) -> bool {
        let before = self.groups.len();
        self.groups.retain(|&x| x != g);
        before != self.groups.len()
    }

    /// Dequeue the head group (all its requests completed, §4).
    pub fn pop_head(&mut self) -> Option<GroupId> {
        self.groups.pop_front()
    }

    pub fn contains(&self, g: GroupId) -> bool {
        self.groups.contains(&g)
    }

    /// Replace the entire ordering (global scheduler output).
    pub fn set_order(&mut self, order: Vec<GroupId>) {
        self.groups = order.into();
    }

    /// The model sequence this queue implies, given the group table —
    /// consumed by the model-swap LSO and the warm-set logic (§5).
    pub fn model_order<'a>(
        &self,
        lookup: impl Fn(GroupId) -> Option<&'a RequestGroup>,
    ) -> Vec<ModelId> {
        self.groups
            .iter()
            .filter_map(|&g| lookup(g).map(|grp| grp.model))
            .collect()
    }

    /// Number of model switches this ordering implies (Fig. 5 metric).
    pub fn swap_count<'a>(
        &self,
        lookup: impl Fn(GroupId) -> Option<&'a RequestGroup>,
        active: Option<ModelId>,
    ) -> usize {
        let mut swaps = 0;
        let mut cur = active;
        for m in self.model_order(lookup) {
            if cur != Some(m) {
                swaps += 1;
                cur = Some(m);
            }
        }
        swaps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{SloClass, SloTarget};
    use std::collections::BTreeMap;

    fn grp(id: u64, model: u32) -> RequestGroup {
        RequestGroup {
            id: GroupId(id),
            model: ModelId(model),
            class: SloClass::Batch1,
            slo: SloTarget::new(60.0, 1.0),
            earliest_arrival_s: 0.0,
            members: Default::default(),
            mega: false,
        }
    }

    fn table(groups: &[RequestGroup]) -> BTreeMap<GroupId, RequestGroup> {
        groups.iter().map(|g| (g.id, g.clone())).collect()
    }

    #[test]
    fn fifo_order_and_head() {
        let mut vq = VirtualQueue::new(InstanceId(0));
        vq.push_back(GroupId(1));
        vq.push_back(GroupId(2));
        assert_eq!(vq.head(), Some(GroupId(1)));
        vq.push_front(GroupId(3));
        assert_eq!(vq.head(), Some(GroupId(3)));
        assert_eq!(vq.pop_head(), Some(GroupId(3)));
        assert_eq!(vq.len(), 2);
    }

    #[test]
    fn remove_group() {
        let mut vq = VirtualQueue::new(InstanceId(0));
        vq.push_back(GroupId(1));
        vq.push_back(GroupId(2));
        assert!(vq.remove(GroupId(1)));
        assert!(!vq.remove(GroupId(9)));
        assert_eq!(vq.head(), Some(GroupId(2)));
    }

    #[test]
    fn swap_count_counts_transitions() {
        let groups = vec![grp(1, 0), grp(2, 1), grp(3, 1), grp(4, 0)];
        let t = table(&groups);
        let mut vq = VirtualQueue::new(InstanceId(0));
        for g in &groups {
            vq.push_back(g.id);
        }
        // none active: 0→1 (swap to 0), then to 1, then to 0 again = 3.
        assert_eq!(vq.swap_count(|g| t.get(&g), None), 3);
        // model 0 already active: 2 swaps.
        assert_eq!(vq.swap_count(|g| t.get(&g), Some(ModelId(0))), 2);
    }

    #[test]
    fn set_order_replaces() {
        let mut vq = VirtualQueue::new(InstanceId(0));
        vq.push_back(GroupId(1));
        vq.set_order(vec![GroupId(5), GroupId(6)]);
        assert_eq!(vq.head(), Some(GroupId(5)));
        assert_eq!(vq.len(), 2);
    }
}
