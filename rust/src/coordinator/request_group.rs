//! Request groups (§4, Definition 4.1, Algorithm 1).
//!
//! Each group collects requests with homogeneous performance
//! characteristics — model type, SLO value, and token distribution. Groups
//! are created by k-means over numeric features within each model
//! partition, then large groups are split to at most δ × avg_batch_size
//! members. Requests within a group are served FCFS.

use crate::backend::ModelId;
use crate::coordinator::request::Request;
use crate::util::{kmeans::kmeans, Rng};
use crate::workload::{SloClass, SloTarget};

/// Identifier of a request group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GroupId(pub u64);

/// A collection of homogeneous requests, FCFS-ordered.
#[derive(Debug, Clone)]
pub struct RequestGroup {
    pub id: GroupId,
    pub model: ModelId,
    pub class: SloClass,
    /// Tightest SLO among members, per dimension (the group's binding
    /// constraint). The TTFT bound anchors the group deadline.
    pub slo: SloTarget,
    /// Earliest member arrival (deadline anchor for the group).
    pub earliest_arrival_s: f64,
    /// Member request ids in FCFS order. A flat `Vec` (members are
    /// appended, retained, and iterated — never rotated), so the ids sit
    /// contiguously and the per-group VecDeque ring bookkeeping is gone.
    pub members: Vec<u64>,
    /// Whether members are mega prompts (distinct token distribution —
    /// kept separate so the RWT estimator sees the right moments, §8.3).
    pub mega: bool,
}

impl RequestGroup {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Group deadline: earliest member arrival + group TTFT SLO.
    pub fn deadline(&self) -> f64 {
        self.earliest_arrival_s + self.slo.ttft_s
    }
}

/// Groups requests per §4 Algorithm 1. `delta` is the group-size multiple
/// of the average batch size (δ = 4 default per §8.3).
#[derive(Debug)]
pub struct Grouper {
    pub delta: f64,
    pub avg_batch_size: u32,
    next_id: u64,
    rng: Rng,
}

impl Grouper {
    pub fn new(delta: f64, avg_batch_size: u32, seed: u64) -> Self {
        Grouper {
            delta,
            avg_batch_size,
            next_id: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn max_group_size(&self) -> usize {
        ((self.avg_batch_size as f64 * self.delta).ceil() as usize).max(1)
    }

    fn fresh_id(&mut self) -> GroupId {
        let id = GroupId(self.next_id);
        self.next_id += 1;
        id
    }

    /// Algorithm 1: k-means clustering over request features, then split
    /// oversized groups in half until all fit δ × avg_batch_size.
    ///
    /// Features: SLO value (log-scaled — 20 s vs 1 h differ by orders of
    /// magnitude), input length, mega flag. Model identity is a hard
    /// partition (a group maps to exactly one set of weights to swap in).
    pub fn regroup(&mut self, requests: &[&Request]) -> Vec<RequestGroup> {
        let mut groups: Vec<RequestGroup> = Vec::new();
        // Hard partition by model.
        let mut models: Vec<ModelId> = requests.iter().map(|r| r.model).collect();
        models.sort();
        models.dedup();
        for model in models {
            let subset: Vec<&Request> = requests
                .iter()
                .copied()
                .filter(|r| r.model == model)
                .collect();
            groups.extend(self.group_one_model(model, &subset));
        }
        groups
    }

    fn group_one_model(&mut self, model: ModelId, reqs: &[&Request]) -> Vec<RequestGroup> {
        if reqs.is_empty() {
            return Vec::new();
        }
        // Feature vectors: (ln slo, input tokens / 100, mega flag * 10).
        let feats: Vec<Vec<f64>> = reqs
            .iter()
            .map(|r| {
                vec![
                    r.slo.ttft_s.ln() * 3.0,
                    (r.input_tokens as f64 / 100.0).min(20.0),
                    if r.mega { 30.0 } else { 0.0 },
                ]
            })
            .collect();
        // k = number of distinct (class, mega) pairs — the natural cluster
        // count; k-means then recovers the partition from features alone.
        let mut keys: Vec<(SloClass, bool)> = reqs.iter().map(|r| (r.class, r.mega)).collect();
        keys.sort();
        keys.dedup();
        let k = keys.len().max(1);
        let km = kmeans(&feats, k, 30, &mut self.rng);

        let mut clusters: Vec<Vec<&Request>> = vec![Vec::new(); km.centroids.len()];
        for (i, &a) in km.assignment.iter().enumerate() {
            clusters[a].push(reqs[i]);
        }

        let cap = self.max_group_size();
        let mut out = Vec::new();
        for cluster in clusters.into_iter().filter(|c| !c.is_empty()) {
            // FCFS within the group: order members by arrival.
            let mut members: Vec<&Request> = cluster;
            members.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            // Split-half until under the size cap (Algorithm 1 lines 3-6).
            let mut stack = vec![members];
            while let Some(chunk) = stack.pop() {
                if chunk.len() > cap {
                    let mid = chunk.len() / 2;
                    let (a, b) = chunk.split_at(mid);
                    stack.push(b.to_vec());
                    stack.push(a.to_vec());
                } else {
                    out.push(self.build_group(model, &chunk));
                }
            }
        }
        // Deterministic ordering for downstream reproducibility.
        out.sort_by(|a, b| a.deadline().total_cmp(&b.deadline()).then(a.id.0.cmp(&b.id.0)));
        out
    }

    fn build_group(&mut self, model: ModelId, members: &[&Request]) -> RequestGroup {
        let slo = members
            .iter()
            .map(|r| r.slo)
            .fold(SloTarget::new(f64::INFINITY, f64::INFINITY), SloTarget::min);
        let earliest = members
            .iter()
            .map(|r| r.arrival_s)
            .fold(f64::INFINITY, f64::min);
        let class = members[0].class;
        let mega = members.iter().filter(|r| r.mega).count() * 2 > members.len();
        RequestGroup {
            id: self.fresh_id(),
            model,
            class,
            slo,
            earliest_arrival_s: earliest,
            members: members.iter().map(|r| r.id).collect(),
            mega,
        }
    }

    /// Incremental classification (§4, Handling New Incoming Requests):
    /// place a new request into an existing compatible group with space,
    /// else mint a new group for it.
    pub fn classify(&mut self, req: &Request, groups: &mut Vec<RequestGroup>) -> GroupId {
        let cap = self.max_group_size();
        if let Some(g) = groups.iter_mut().find(|g| {
            g.model == req.model
                && g.class == req.class
                && g.mega == req.mega
                && g.len() < cap
        }) {
            g.members.push(req.id);
            g.slo = g.slo.min(req.slo);
            g.earliest_arrival_s = g.earliest_arrival_s.min(req.arrival_s);
            return g.id;
        }
        let g = RequestGroup {
            id: self.fresh_id(),
            model: req.model,
            class: req.class,
            slo: req.slo,
            earliest_arrival_s: req.arrival_s,
            members: vec![req.id],
            mega: req.mega,
        };
        let id = g.id;
        groups.push(g);
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceRequest;

    fn mk(id: u64, model: u32, class: SloClass, arrival: f64, mega: bool) -> Request {
        let mut r = Request::from_trace(
            id,
            &TraceRequest {
                arrival_s: arrival,
                model: ModelId(model),
                class,
                slo: class.target(),
                input_tokens: if mega { 2000 } else { 150 },
                output_tokens: 100,
                mega,
            },
        );
        r.id = id;
        r
    }

    #[test]
    fn groups_partition_by_model() {
        let mut g = Grouper::new(4.0, 16, 1);
        let reqs: Vec<Request> = (0..40)
            .map(|i| mk(i, (i % 2) as u32, SloClass::Batch1, i as f64, false))
            .collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let groups = g.regroup(&refs);
        for grp in &groups {
            for &m in &grp.members {
                assert_eq!(reqs[m as usize].model, grp.model);
            }
        }
        let models: std::collections::BTreeSet<_> = groups.iter().map(|g| g.model).collect();
        assert_eq!(models.len(), 2);
    }

    #[test]
    fn groups_separate_slo_classes() {
        let mut g = Grouper::new(4.0, 16, 2);
        let mut reqs = Vec::new();
        for i in 0..30 {
            reqs.push(mk(i, 0, SloClass::Interactive, i as f64, false));
        }
        for i in 30..60 {
            reqs.push(mk(i, 0, SloClass::Batch2, i as f64, false));
        }
        let refs: Vec<&Request> = reqs.iter().collect();
        let groups = g.regroup(&refs);
        for grp in &groups {
            let classes: std::collections::BTreeSet<_> = grp
                .members
                .iter()
                .map(|&m| reqs[m as usize].class)
                .collect();
            assert_eq!(classes.len(), 1, "group mixes SLO classes");
        }
    }

    #[test]
    fn oversized_groups_split() {
        let mut g = Grouper::new(2.0, 8, 3); // cap = 16
        let reqs: Vec<Request> = (0..100)
            .map(|i| mk(i, 0, SloClass::Batch1, i as f64, false))
            .collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let groups = g.regroup(&refs);
        assert!(groups.iter().all(|g| g.len() <= 16));
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 100, "no request lost in splitting");
    }

    #[test]
    fn members_fcfs_within_group() {
        let mut g = Grouper::new(4.0, 64, 4);
        let reqs: Vec<Request> = (0..20)
            .map(|i| mk(i, 0, SloClass::Batch1, (20 - i) as f64, false))
            .collect();
        let refs: Vec<&Request> = reqs.iter().collect();
        let groups = g.regroup(&refs);
        for grp in &groups {
            let arrivals: Vec<f64> = grp
                .members
                .iter()
                .map(|&m| reqs[m as usize].arrival_s)
                .collect();
            assert!(arrivals.windows(2).all(|w| w[1] >= w[0]));
        }
    }

    #[test]
    fn mega_prompts_isolated() {
        let mut g = Grouper::new(4.0, 16, 5);
        let mut reqs = Vec::new();
        for i in 0..20 {
            reqs.push(mk(i, 0, SloClass::Batch1, i as f64, false));
        }
        for i in 20..30 {
            reqs.push(mk(i, 0, SloClass::Batch1, i as f64, true));
        }
        let refs: Vec<&Request> = reqs.iter().collect();
        let groups = g.regroup(&refs);
        for grp in &groups {
            let megas: std::collections::BTreeSet<_> = grp
                .members
                .iter()
                .map(|&m| reqs[m as usize].mega)
                .collect();
            assert_eq!(megas.len(), 1, "group mixes mega and regular prompts");
        }
    }

    #[test]
    fn classify_joins_compatible_group() {
        let mut g = Grouper::new(4.0, 16, 6);
        let mut groups = Vec::new();
        let a = mk(0, 0, SloClass::Batch1, 0.0, false);
        let id_a = g.classify(&a, &mut groups);
        let b = mk(1, 0, SloClass::Batch1, 1.0, false);
        let id_b = g.classify(&b, &mut groups);
        assert_eq!(id_a, id_b);
        let c = mk(2, 1, SloClass::Batch1, 2.0, false);
        let id_c = g.classify(&c, &mut groups);
        assert_ne!(id_a, id_c, "different model → different group");
        assert_eq!(groups.len(), 2);
    }

    #[test]
    fn classify_respects_size_cap() {
        let mut g = Grouper::new(1.0, 2, 7); // cap = 2
        let mut groups = Vec::new();
        for i in 0..5 {
            let r = mk(i, 0, SloClass::Batch1, i as f64, false);
            g.classify(&r, &mut groups);
        }
        assert_eq!(groups.len(), 3);
        assert!(groups.iter().all(|g| g.len() <= 2));
    }

    #[test]
    fn group_deadline_uses_earliest_member() {
        let mut g = Grouper::new(4.0, 16, 8);
        let mut groups = Vec::new();
        g.classify(&mk(0, 0, SloClass::Batch1, 5.0, false), &mut groups);
        g.classify(&mk(1, 0, SloClass::Batch1, 2.0, false), &mut groups);
        assert_eq!(groups[0].deadline(), 2.0 + 60.0);
    }
}
