//! Cache layer: the scheduler's memory between passes.
//!
//! [`SchedCache`] holds the last plan (per-instance [`CachedQueue`]s)
//! plus the per-group [`GroupPricing`] table; the delta path patches it
//! in place and untouched queues advance their penalties through
//! [`CachedQueue::reanchor`] without a walk. The cache is a *mirror* of
//! the last pass, never an oracle: a view-set mismatch
//! ([`SchedCache::matches_views`]), a cold start, or an exactness
//! demand invalidates it and the next full solve rebuilds it.

use std::collections::BTreeMap;

use crate::backend::{InstanceId, ModelId};
use crate::coordinator::request_group::GroupId;
use crate::coordinator::sched::pricing::{GroupPricing, QTail};
use crate::coordinator::sched::InstanceView;

#[derive(Debug, Clone)]
pub(crate) struct CachedQueue {
    pub(crate) id: InstanceId,
    pub(crate) order: Vec<GroupId>,
    pub(crate) tail: QTail,
    pub(crate) penalty: f64,
    /// The `now` the penalty was last priced at (full walk), advanced
    /// by the constant-time re-anchor on untouched delta passes.
    pub(crate) priced_at: f64,
    /// Groups violating at the last walk — the penalty's d/dt slope
    /// (each violating group's penalty grows one second per second).
    pub(crate) viol_groups: u32,
    /// Future violation-crossing times of the groups still inside their
    /// budgets at the last walk, ascending. Recorded by the repricing
    /// walk; drained by [`Self::reanchor`]'s crossing scan.
    pub(crate) crossings: Vec<f64>,
    /// Crossings already consumed by the scan (a cursor, so draining is
    /// amortized O(1) per pass instead of a front-removal shuffle).
    pub(crate) crossed: usize,
    pub(crate) active_model: Option<ModelId>,
    pub(crate) executing: Option<GroupId>,
}

impl CachedQueue {
    /// A fresh cache entry for `v`'s queue, to be filled by the
    /// repricing walk.
    pub(crate) fn new(v: &InstanceView, order: Vec<GroupId>, now: f64) -> Self {
        CachedQueue {
            id: v.id,
            order,
            tail: QTail::default(),
            penalty: 0.0,
            priced_at: now,
            viol_groups: 0,
            crossings: Vec::new(),
            crossed: 0,
            active_model: v.active_model,
            executing: v.executing,
        }
    }

    /// Advance this queue's penalty from `priced_at` to `now` in O(1)
    /// amortized, without re-walking the order:
    ///
    /// * every group violating at the last anchor accrues one second of
    ///   penalty per second, so the bulk term is `dt × viol_groups`;
    /// * the **crossing scan**: groups whose recorded crossing time
    ///   expired inside `(priced_at, now]` start accruing from their
    ///   own crossing — each contributes `now − t_c` this pass and
    ///   joins the slope for the next one. Before this scan, freshly
    ///   violating groups on clean queues went unpriced until the queue
    ///   was next touched (the PR-4 second-order amortization gap).
    ///
    /// Exactness: with the queue order and prices unchanged (the only
    /// regime in which a queue stays untouched), each group's penalty
    /// is `max(0, now − t_c)` — the slope term plus the crossing scan
    /// reproduce the full walk's value in real arithmetic (floats may
    /// differ in final ulps from a fresh walk, as with the original
    /// slope-only re-anchor).
    ///
    /// Returns how many crossings the scan drained this call — summed
    /// into `SolveStats::crossings_drained` so the telemetry sampler can
    /// report how much work the amortization is actually absorbing.
    pub(crate) fn reanchor(&mut self, now: f64) -> usize {
        let dt = now - self.priced_at;
        if dt <= 0.0 {
            return 0;
        }
        self.penalty += dt * self.viol_groups as f64;
        let before = self.crossed;
        while self.crossed < self.crossings.len() && self.crossings[self.crossed] <= now {
            let t_c = self.crossings[self.crossed];
            self.crossed += 1;
            self.penalty += now - t_c;
            self.viol_groups += 1;
        }
        self.priced_at = now;
        self.crossed - before
    }
}

/// The scheduler's memory between passes: last plan + pricing.
#[derive(Debug, Clone, Default)]
pub(crate) struct SchedCache {
    pub(crate) queues: Vec<CachedQueue>,
    pub(crate) pricing: BTreeMap<GroupId, GroupPricing>,
    /// (group, member count) pairs currently unservable.
    pub(crate) unservable: Vec<(GroupId, u32)>,
}

impl SchedCache {
    /// Is this cache a mirror of `instances`? A mismatch (failure,
    /// autoscaler join/drain) means every cached order may reference a
    /// dead queue — the delta path must bail to a full solve.
    pub(crate) fn matches_views(&self, instances: &[InstanceView]) -> bool {
        self.queues.len() == instances.len()
            && self.queues.iter().zip(instances).all(|(c, v)| c.id == v.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::InstanceId;

    fn queue_with(penalty: f64, viol: u32, crossings: Vec<f64>) -> CachedQueue {
        CachedQueue {
            id: InstanceId(0),
            order: Vec::new(),
            tail: QTail::default(),
            penalty,
            priced_at: 0.0,
            viol_groups: viol,
            crossings,
            crossed: 0,
            active_model: None,
            executing: None,
        }
    }

    #[test]
    fn reanchor_advances_slope_only_without_crossings() {
        let mut q = queue_with(7.0, 3, vec![]);
        q.reanchor(10.0);
        assert!((q.penalty - 37.0).abs() < 1e-12, "7 + 3×10 = {}", q.penalty);
        assert_eq!(q.viol_groups, 3);
        assert_eq!(q.priced_at, 10.0);
    }

    #[test]
    fn crossing_inside_the_window_accrues_from_its_own_time() {
        // Two clean groups cross at t=4 and t=25; a re-anchor to t=10
        // picks up only the first: penalty grows by dt×slope (2×10)
        // plus the crossed group's own accrual (10 − 4 = 6), and the
        // slope gains the crossed group for the *next* pass.
        let mut q = queue_with(5.0, 2, vec![4.0, 25.0]);
        q.reanchor(10.0);
        assert!(
            (q.penalty - (5.0 + 20.0 + 6.0)).abs() < 1e-12,
            "got {}",
            q.penalty
        );
        assert_eq!(q.viol_groups, 3, "crossed group joins the slope");
        assert_eq!(q.crossed, 1, "future crossing stays queued");
        // Second re-anchor: the new slope (3) applies over +5 s and the
        // remaining crossing is still in the future — exactly the +dt
        // arithmetic a chain of delta passes performs.
        q.reanchor(15.0);
        assert!((q.penalty - (31.0 + 15.0)).abs() < 1e-12, "got {}", q.penalty);
        assert_eq!(q.viol_groups, 3);
        // Third pass crosses the last group at t=25 on the way to t=30.
        q.reanchor(30.0);
        assert!(
            (q.penalty - (46.0 + 45.0 + 5.0)).abs() < 1e-12,
            "got {}",
            q.penalty
        );
        assert_eq!(q.viol_groups, 4);
        assert_eq!(q.crossed, 2);
    }

    #[test]
    fn reanchor_matches_exact_per_group_accrual() {
        // Exactness against first principles: penalty(t) =
        // Σ_g max(0, t − t_c(g)). Start with every group clean.
        let crossings = vec![3.0, 8.0, 8.0, 21.0];
        let exact = |t: f64| -> f64 {
            crossings.iter().map(|c| (t - c).max(0.0)).sum()
        };
        let mut q = queue_with(0.0, 0, crossings.clone());
        for t in [1.0, 5.0, 8.0, 9.0, 20.0, 21.5, 40.0] {
            q.reanchor(t);
            assert!(
                (q.penalty - exact(t)).abs() < 1e-9,
                "t={t}: got {} want {}",
                q.penalty,
                exact(t)
            );
        }
        assert_eq!(q.viol_groups, 4);
    }

    #[test]
    fn reanchor_is_a_noop_for_non_positive_dt() {
        let mut q = queue_with(5.0, 2, vec![1.0]);
        q.priced_at = 10.0;
        q.reanchor(10.0);
        assert_eq!(q.penalty, 5.0);
        q.reanchor(9.0);
        assert_eq!(q.penalty, 5.0, "time never runs backwards mid-run");
    }

    #[test]
    fn matches_views_detects_set_changes() {
        use crate::coordinator::sched::testutil::view;
        let cache = SchedCache {
            queues: vec![
                CachedQueue::new(&view(0, &[0], None), Vec::new(), 0.0),
                CachedQueue::new(&view(1, &[0], None), Vec::new(), 0.0),
            ],
            ..Default::default()
        };
        let same = vec![view(0, &[0], None), view(1, &[0], None)];
        assert!(cache.matches_views(&same));
        let shrunk = vec![view(0, &[0], None)];
        assert!(!cache.matches_views(&shrunk));
        let renamed = vec![view(0, &[0], None), view(2, &[0], None)];
        assert!(!cache.matches_views(&renamed));
    }
}
