//! Solve layer: orchestration of the full solve, the incremental delta
//! patch, and the exact-MILP refinement — plus every fallback trigger
//! between them (cold cache, view-set change, `ExactMilp`, dirtiness
//! above `SchedulerConfig::incremental_dirty_frac`).
//!
//! This file owns *when* things happen; *how* a group is priced lives
//! in [`super::pricing`], *how* a queue is ordered in [`super::plan`],
//! and *what* survives between passes in [`super::cache`].

use std::collections::{BTreeMap, BTreeSet};

use crate::backend::InstanceId;
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::sched::cache::{CachedQueue, SchedCache};
use crate::coordinator::sched::plan::Assignment;
use crate::coordinator::sched::plan::{
    affinity_order, candidate_improves, finish_unservable, reorder_cached, split_pinned,
};
use crate::coordinator::sched::pricing::{self, QTail};
use crate::coordinator::sched::{InstanceView, MILP_HARD_CAP, SchedDelta, SolveStats, SolverKind};
use crate::coordinator::scheduler::GlobalScheduler;
use crate::solver::{Cmp, Lp, Milp, MilpResult};

impl GlobalScheduler {
    /// Penalty of an ordering on one instance: Σ max(0, completion − budget).
    pub fn queue_penalty(&self, order: &[&RequestGroup], view: &InstanceView, now: f64) -> f64 {
        if order.is_empty() {
            return 0.0;
        }
        // Perf is per-model; use the head group's model for Θ (groups on
        // one queue in one walk segment share the instance's device).
        let Some(perf) = view.perf_for.get(&order[0].model) else {
            return f64::INFINITY;
        };
        let est = self.estimator.estimate_queue(
            order,
            perf,
            view.active_model,
            |m| view.swap_s(m),
        );
        order
            .iter()
            .zip(&est)
            .map(|(g, e)| (e.completion_mean_s - (g.deadline() - now)).max(0.0))
            .sum()
    }

    /// Main entry: assign + order all schedulable groups.
    ///
    /// Takes group *references* so callers holding groups in a table
    /// (the simulator's live group map) schedule without deep-cloning
    /// every member list per invocation (§Perf).
    pub fn schedule(
        &self,
        groups: &[&RequestGroup],
        instances: &[InstanceView],
        now: f64,
    ) -> Assignment {
        // One scheduler invocation = one memo epoch for service pricing.
        self.estimator.begin_epoch();
        let by_id: BTreeMap<GroupId, &RequestGroup> =
            groups.iter().map(|g| (g.id, *g)).collect();
        let mut orders: BTreeMap<InstanceId, Vec<GroupId>> = BTreeMap::new();
        let mut unservable: Vec<(GroupId, u32)> = Vec::new();
        let mut stats = SolveStats {
            groups: groups.len(),
            ..Default::default()
        };

        // 1. Pin executing groups to their instances' heads.
        let mut pinned: BTreeMap<GroupId, InstanceId> = BTreeMap::new();
        for v in instances {
            let order = orders.entry(v.id).or_default();
            if let Some(g) = v.executing {
                if by_id.contains_key(&g) {
                    order.push(g);
                    pinned.insert(g, v.id);
                }
            }
        }

        // 2. Deadline-ordered greedy assignment of the rest.
        let mut todo: Vec<&RequestGroup> = groups
            .iter()
            .copied()
            .filter(|g| !pinned.contains_key(&g.id))
            .collect();
        todo.sort_by(|a, b| a.deadline().total_cmp(&b.deadline()).then(a.id.cmp(&b.id)));

        // §Perf: incremental O(G·V) assignment — each candidate append is
        // priced from cached per-queue state (accumulated wait, tail
        // model) instead of re-walking the whole queue (which made the
        // assignment quadratic in groups; see EXPERIMENTS.md §Perf).
        let mut qstate: BTreeMap<InstanceId, QTail> = instances
            .iter()
            .map(|v| {
                let mut st = QTail {
                    wait: 0.0,
                    tail_model: v.active_model,
                    load: 0.0,
                };
                // Seed with the pinned executing group, if any.
                if let Some(gid) = v.executing {
                    if let Some(g) = by_id.get(&gid) {
                        if let Some(perf) = v.perf_for.get(&g.model) {
                            let (svc, _) = self.estimator.group_service(g, perf);
                            st.wait += svc + perf.prefill_s;
                            st.tail_model = Some(g.model);
                            st.load += g.len() as f64;
                        }
                    }
                }
                (v.id, st)
            })
            .collect();

        for g in todo {
            let mut best: Option<(InstanceId, f64, f64, f64)> = None; // (id, pen, completion, load)
            for v in instances {
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                let st = qstate[&v.id];
                let (pen, completion) =
                    pricing::append_score(&self.estimator, &st, g, v, perf, now);
                if candidate_improves(
                    best.map(|(_, p, c, l)| (p, c, l)),
                    pen,
                    completion,
                    st.load,
                ) {
                    best = Some((v.id, pen, completion, st.load));
                }
            }
            match best {
                Some((id, _, completion, _)) => {
                    orders.entry(id).or_default().push(g.id);
                    // audit:allow(hot-path-panic): `id` comes from the instance loop
                    // above, and `qstate` was seeded with every instance.
                    let st = qstate.get_mut(&id).unwrap();
                    st.wait = completion;
                    st.tail_model = Some(g.model);
                    st.load += g.len() as f64;
                }
                None => {
                    // No instance can serve this model (misconfigured
                    // fleet): report separately with a large finite
                    // penalty. Parking it on an arbitrary queue made
                    // `queue_penalty` go infinite at the queue head,
                    // rendering the penalty signal useless.
                    unservable.push((g.id, g.len() as u32));
                }
            }
        }

        // 3. Per-queue ordering: affinity-EDF, optionally MILP-refined.
        for v in instances {
            let ids = orders.entry(v.id).or_default();
            let all: Vec<&RequestGroup> =
                ids.iter().filter_map(|id| by_id.get(id).copied()).collect();
            let (head, mut rest) = split_pinned(&all, v.executing);
            affinity_order(&mut rest, v.active_model);

            // `ExactMilp` is honored past `milp_max_groups` (the old
            // code silently fell back to the heuristic there), bounded
            // only by [`MILP_HARD_CAP`] — the node limit bounds the
            // search but not tableau construction, and the heuristic-
            // regression guard below keeps truncated searches harmless.
            let use_milp = rest.len() >= 2
                && match self.cfg.solver {
                    SolverKind::Greedy => false,
                    SolverKind::ExactMilp => rest.len() <= MILP_HARD_CAP,
                    SolverKind::Auto => {
                        rest.len() <= self.cfg.milp_max_groups.min(MILP_HARD_CAP)
                    }
                };

            if use_milp {
                if let Some((order, nodes)) = self.milp_order(&rest, v, now) {
                    stats.milp_nodes += nodes;
                    stats.used_milp = true;
                    // Accept MILP order only if it doesn't regress the
                    // heuristic (node-limit exhaustion can truncate search).
                    let full_h: Vec<&RequestGroup> =
                        head.iter().copied().chain(rest.iter().copied()).collect();
                    let full_m: Vec<&RequestGroup> = head
                        .iter()
                        .copied()
                        .chain(order.iter().map(|&i| rest[i]))
                        .collect();
                    if self.queue_penalty(&full_m, v, now)
                        <= self.queue_penalty(&full_h, v, now) + 1e-9
                    {
                        rest = full_m[head.len()..].to_vec();
                    }
                }
            }

            let full: Vec<&RequestGroup> =
                head.into_iter().chain(rest.into_iter()).collect();
            *ids = full.iter().map(|g| g.id).collect();
        }

        // Penalty: per-group pricing via the same `reprice_queue` walk
        // the delta path uses, so full and delta passes report one
        // consistent signal (head-perf `queue_penalty` stays as the
        // MILP acceptance metric above). The walk doubles as the cache
        // rebuild; ExactMilp never feeds the delta path (it always
        // bails to preserve exactness), so it skips the cache and
        // prices with `queue_penalty` instead.
        let mut total_penalty = if self.cfg.solver != SolverKind::ExactMilp {
            self.store_cache(&orders, &by_id, instances, now, unservable.clone())
        } else {
            instances
                .iter()
                .map(|v| {
                    let refs: Vec<&RequestGroup> = orders[&v.id]
                        .iter()
                        .filter_map(|id| by_id.get(id).copied())
                        .collect();
                    self.queue_penalty(&refs, v, now)
                })
                .sum()
        };
        let (unservable, unservable_pen) = finish_unservable(&unservable);
        total_penalty += unservable_pen;

        Assignment {
            feasible: total_penalty <= 1e-9,
            total_penalty_s: total_penalty,
            orders,
            unservable,
            stats,
        }
    }

    /// Rebuild the incremental cache from a just-computed full plan:
    /// price every queued group (cheap — the services were just
    /// memoized), then run the shared repricing walk per queue for tail
    /// state, penalty, and violation-slope data. Returns the summed
    /// queue penalty so full solves report the exact signal delta
    /// passes will maintain.
    fn store_cache(
        &self,
        orders: &BTreeMap<InstanceId, Vec<GroupId>>,
        by_id: &BTreeMap<GroupId, &RequestGroup>,
        instances: &[InstanceView],
        now: f64,
        unservable: Vec<(GroupId, u32)>,
    ) -> f64 {
        let mut group_pricing = BTreeMap::new();
        let mut queues = Vec::with_capacity(instances.len());
        for v in instances {
            let order = orders.get(&v.id).cloned().unwrap_or_default();
            for gid in &order {
                let Some(g) = by_id.get(gid) else { continue };
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                group_pricing.insert(g.id, pricing::price_group(&self.estimator, g, perf, v.id));
            }
            queues.push(CachedQueue::new(v, order, now));
        }
        // §Perf: each queue's repricing walk is independent of every
        // other's (it reads only the shared pricing table), so the
        // walks fan out over the persistent worker pool — spawned once
        // and shared with the engine's view refresh, so a pass costs
        // one dispatch instead of a scoped spawn per thread. Queues
        // stay in instance order and the penalty is summed sequentially
        // afterwards, so the result is bit-identical to the serial pass
        // whatever the lane count.
        let view_of: BTreeMap<InstanceId, &InstanceView> =
            instances.iter().map(|v| (v.id, v)).collect();
        let pricing_ref = &group_pricing;
        self.pool.run_chunks_mut(&mut queues, |cq| {
            pricing::reprice_queue(cq, pricing_ref, view_of[&cq.id], now);
        });
        let total: f64 = queues.iter().map(|q| q.penalty).sum();
        // With the delta path disabled there is no consumer for the
        // plan cache — the walk above still ran (it *is* the penalty
        // computation), but keep no state a disabled path could read.
        if self.cfg.incremental {
            *self.cache.borrow_mut() = Some(SchedCache {
                queues,
                pricing: group_pricing,
                unservable,
            });
        }
        total
    }

    /// Incremental pass: patch the cached plan with one pass's dirty
    /// set instead of re-solving the whole group table.
    ///
    /// Returns `None` when a full solve is required — no cache yet, the
    /// instance set changed (failures), the solver demands exactness, or
    /// dirtiness exceeds `incremental_dirty_frac` — and the caller then
    /// runs [`Self::schedule`], which refreshes the cache.
    ///
    /// Cost is O(dirty × instances + touched queue lengths); clean
    /// queues keep their order and tail state, and their last-priced
    /// penalty is *re-anchored* to `now` in amortized constant time:
    /// each violating group's penalty grows exactly one second per
    /// second (the slope term), and groups whose budget ran out since
    /// the last walk are picked up by the crossing scan over the
    /// violation-slope data recorded per queue — see
    /// [`CachedQueue::reanchor`]. Per-queue ordering on touched queues
    /// is greedy affinity-EDF, then — under [`SolverKind::Auto`], when
    /// the delta carries the group table — MILP refinement re-applies
    /// *in this pass* to any touched queue whose MILP-eligible head
    /// window changed membership, behind the same heuristic-regression
    /// guard as the full solve. Queues whose window membership is
    /// unchanged keep their standing order (the previous refinement
    /// still covers them), so steady-state deltas stay walk-free.
    pub fn try_schedule_delta(
        &self,
        delta: &SchedDelta,
        instances: &[InstanceView],
        now: f64,
    ) -> Option<Assignment> {
        if !self.cfg.incremental || self.cfg.solver == SolverKind::ExactMilp {
            return None;
        }
        let mut guard = self.cache.borrow_mut();
        let cache = guard.as_mut()?;
        if !cache.matches_views(instances) {
            return None;
        }
        let changed = delta.dirty.len() + delta.removed.len();
        if changed as f64 > self.cfg.incremental_dirty_frac * delta.total_groups.max(1) as f64 {
            return None;
        }
        let SchedCache {
            queues,
            pricing: group_pricing,
            unservable,
        } = cache;

        // The sorted membership of one queue's MILP-eligible head window
        // (reorderable groups past the pinned executing head), or empty
        // when the window is too small / too large to refine. Captured
        // per queue *before* the patch below so step 4.5 can detect
        // membership changes.
        let window = self.cfg.milp_max_groups.min(MILP_HARD_CAP);
        let milp_window = |cq: &CachedQueue| -> Vec<GroupId> {
            let start =
                usize::from(cq.executing.is_some() && cq.order.first() == cq.executing.as_ref());
            let rest = &cq.order[start..];
            if rest.len() < 2 || rest.len() > window {
                return Vec::new();
            }
            let mut ids = rest.to_vec();
            ids.sort_unstable();
            ids
        };
        let refine = delta.groups.filter(|_| self.cfg.solver == SolverKind::Auto);
        let pre_window: Vec<Vec<GroupId>> = match refine {
            Some(_) => queues.iter().map(&milp_window).collect(),
            None => Vec::new(),
        };

        // Executing groups stay pinned at their heads even when dirty.
        let pinned: BTreeMap<GroupId, usize> = instances
            .iter()
            .enumerate()
            .filter_map(|(k, v)| v.executing.map(|g| (g, k)))
            .collect();

        // Everything leaving its current queue position.
        let mut gone: BTreeSet<GroupId> = delta.removed.iter().copied().collect();
        for g in &delta.dirty {
            if !pinned.contains_key(&g.id) {
                gone.insert(g.id);
            }
        }
        unservable.retain(|(g, _)| !gone.contains(g));

        let mut touched = vec![false; instances.len()];
        let idx_of: BTreeMap<InstanceId, usize> = instances
            .iter()
            .enumerate()
            .map(|(k, v)| (v.id, k))
            .collect();

        // Only queues that actually hold a departing group need their
        // order rewritten — the owner index keeps this O(dirty) instead
        // of O(total groups) (see `GroupPricing::owner`).
        for gid in &gone {
            if let Some(p) = group_pricing.get(gid) {
                if let Some(&k) = idx_of.get(&p.owner) {
                    touched[k] = true;
                }
            }
        }
        for gid in &delta.removed {
            group_pricing.remove(gid);
        }

        // 1. Drop departing groups; sync pinning and active-model state.
        for (k, v) in instances.iter().enumerate() {
            let cq = &mut queues[k];
            if touched[k] {
                cq.order.retain(|g| !gone.contains(g));
            }
            if cq.executing != v.executing {
                cq.executing = v.executing;
                touched[k] = true;
            }
            if let Some(e) = v.executing {
                if cq.order.first() != Some(&e) && cq.order.contains(&e) {
                    cq.order.retain(|&g| g != e);
                    cq.order.insert(0, e);
                    touched[k] = true;
                }
            }
            if cq.active_model != v.active_model {
                cq.active_model = v.active_model;
                touched[k] = true; // head-swap pricing changed
            }
        }

        // 2. Re-price pinned dirty groups in place.
        for g in &delta.dirty {
            let Some(&k) = pinned.get(&g.id) else { continue };
            touched[k] = true;
            if let Some(perf) = instances[k].perf_for.get(&g.model) {
                group_pricing.insert(
                    g.id,
                    pricing::price_group(&self.estimator, g, perf, instances[k].id),
                );
            }
            if !queues[k].order.contains(&g.id) {
                queues[k].order.insert(0, g.id);
            }
        }

        // 2.5 Refresh tail state of every queue touched so far, *before*
        //     scoring insertions: without this, step 3 would price
        //     candidates against tails that still include the groups
        //     just removed above, steering arrivals away from queues
        //     that freed capacity this very pass.
        //
        // §Perf: the touched queues are disjoint per-instance state and
        // the walk reads only the shared pricing table, so it fans out
        // over the same persistent pool as the full solve's walk
        // (store_cache). Index-ordered disjoint chunks ⇒ bit-identical
        // to the serial loop at any lane count; with few touched queues
        // the pool's engagement gate keeps it serial and allocation-free.
        let view_of: BTreeMap<InstanceId, &InstanceView> =
            instances.iter().map(|v| (v.id, v)).collect();
        {
            let pricing_ref = &*group_pricing;
            let view_ref = &view_of;
            let mut walk: Vec<&mut CachedQueue> = queues
                .iter_mut()
                .enumerate()
                .filter(|(k, _)| touched[*k])
                .map(|(_, q)| q)
                .collect();
            self.pool.run_chunks_mut(&mut walk, |cq| {
                pricing::reprice_queue(cq, pricing_ref, view_ref[&cq.id], now);
            });
        }

        // 3. Greedy re-insertion of dirty groups in deadline order —
        //    identical candidate scoring to the full solve, priced
        //    against cached queue tails.
        let mut todo: Vec<&RequestGroup> = delta
            .dirty
            .iter()
            .copied()
            .filter(|g| !pinned.contains_key(&g.id))
            .collect();
        todo.sort_by(|a, b| a.deadline().total_cmp(&b.deadline()).then(a.id.cmp(&b.id)));
        for g in todo {
            let mut best: Option<(usize, f64, f64, f64)> = None;
            for (k, v) in instances.iter().enumerate() {
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                let t = queues[k].tail;
                let (pen, completion) =
                    pricing::append_score(&self.estimator, &t, g, v, perf, now);
                if candidate_improves(
                    best.map(|(_, p, c, l)| (p, c, l)),
                    pen,
                    completion,
                    t.load,
                ) {
                    best = Some((k, pen, completion, t.load));
                }
            }
            match best {
                Some((k, _, completion, _)) => {
                    let v = &instances[k];
                    let perf = v.perf_for[&g.model];
                    group_pricing
                        .insert(g.id, pricing::price_group(&self.estimator, g, &perf, v.id));
                    let cq = &mut queues[k];
                    cq.order.push(g.id);
                    cq.tail.wait = completion;
                    cq.tail.tail_model = Some(g.model);
                    cq.tail.load += g.len() as f64;
                    touched[k] = true;
                }
                None => unservable.push((g.id, g.len() as u32)),
            }
        }

        // 4. Reorder + re-price touched queues from cached pricing;
        //    re-anchor untouched queues' penalties to `now` via the
        //    amortized-constant-time epoch offset (slope term plus the
        //    crossing scan — no walk needed).
        //
        // §Perf: same fan-out as step 2.5 — reorder + walk are pure
        // per-queue functions of the (now frozen) pricing table, so the
        // touched set goes wide while the untouched re-anchor (a
        // counter fold) stays serial.
        {
            let pricing_ref = &*group_pricing;
            let view_ref = &view_of;
            let mut walk: Vec<&mut CachedQueue> = queues
                .iter_mut()
                .enumerate()
                .filter(|(k, _)| touched[*k])
                .map(|(_, q)| q)
                .collect();
            self.pool.run_chunks_mut(&mut walk, |cq| {
                reorder_cached(cq, pricing_ref);
                pricing::reprice_queue(cq, pricing_ref, view_ref[&cq.id], now);
            });
        }
        let mut crossings_drained = 0usize;
        for (k, q) in queues.iter_mut().enumerate() {
            if !touched[k] {
                crossings_drained += q.reanchor(now);
            }
        }

        // 4.5 `Auto`-mode MILP refinement, in-pass: any touched queue
        //     whose MILP-eligible head window changed membership gets
        //     the same exact refinement a full solve would apply,
        //     accepted only if it doesn't regress the heuristic order
        //     (node-limit exhaustion can truncate the search). This
        //     closes the carry-over gap where delta passes left touched
        //     queues greedy-only until the next full solve.
        let mut milp_nodes = 0usize;
        let mut used_milp = false;
        if let Some(by_id) = refine {
            for (k, v) in instances.iter().enumerate() {
                if !touched[k] {
                    continue;
                }
                let cq = &mut queues[k];
                let post = milp_window(cq);
                if post.len() < 2 || post == pre_window[k] {
                    continue;
                }
                let start = usize::from(
                    cq.executing.is_some() && cq.order.first() == cq.executing.as_ref(),
                );
                let head: Vec<&RequestGroup> = cq.order[..start]
                    .iter()
                    .filter_map(|g| by_id.get(g))
                    .collect();
                let rest: Vec<&RequestGroup> = cq.order[start..]
                    .iter()
                    .filter_map(|g| by_id.get(g))
                    .collect();
                // A stale lookup (id missing from the live table) means
                // the window can't be priced faithfully: keep greedy.
                if head.len() != start || rest.len() != cq.order.len() - start {
                    continue;
                }
                let Some((perm, nodes)) = self.milp_order(&rest, v, now) else {
                    continue;
                };
                milp_nodes += nodes;
                used_milp = true;
                let full_h: Vec<&RequestGroup> =
                    head.iter().copied().chain(rest.iter().copied()).collect();
                let full_m: Vec<&RequestGroup> = head
                    .iter()
                    .copied()
                    .chain(perm.iter().map(|&i| rest[i]))
                    .collect();
                if self.queue_penalty(&full_m, v, now)
                    <= self.queue_penalty(&full_h, v, now) + 1e-9
                {
                    for (slot, g) in cq.order[start..]
                        .iter_mut()
                        .zip(perm.iter().map(|&i| rest[i]))
                    {
                        *slot = g.id;
                    }
                    pricing::reprice_queue(cq, group_pricing, v, now);
                }
            }
        }

        // 5. Assemble the patch: orders only for queues that changed.
        let mut orders = BTreeMap::new();
        for (k, cq) in queues.iter().enumerate() {
            if touched[k] {
                orders.insert(cq.id, cq.order.clone());
            }
        }
        let mut total_penalty: f64 = queues.iter().map(|q| q.penalty).sum();
        let (unservable_ids, unservable_pen) = finish_unservable(unservable);
        total_penalty += unservable_pen;
        let touched_instances = touched.iter().filter(|&&t| t).count();
        Some(Assignment {
            feasible: total_penalty <= 1e-9,
            total_penalty_s: total_penalty,
            orders,
            unservable: unservable_ids,
            stats: SolveStats {
                groups: delta.total_groups,
                milp_nodes,
                used_milp,
                incremental: true,
                dirty: delta.dirty.len(),
                touched_instances,
                crossings_drained,
            },
        })
    }

    /// Exact ordering of `groups` on instance `v` via the §7 MILP.
    /// Returns the permutation (indices into `groups`) and node count.
    pub fn milp_order(
        &self,
        groups: &[&RequestGroup],
        v: &InstanceView,
        now: f64,
    ) -> Option<(Vec<usize>, usize)> {
        let n = groups.len();
        if n == 0 {
            return Some((Vec::new(), 0));
        }
        let perf = v.perf_for.get(&groups[0].model)?;
        // Per-group constants.
        let svc: Vec<f64> = groups
            .iter()
            .map(|g| {
                let (m, _) = self.estimator.group_service(g, perf);
                m + perf.prefill_s
            })
            .collect();
        let budget: Vec<f64> = groups.iter().map(|g| g.deadline() - now).collect();
        let model_val: Vec<f64> = groups.iter().map(|g| g.model.0 as f64 + 1.0).collect();
        let active_val = v.active_model.map(|m| m.0 as f64 + 1.0).unwrap_or(0.0);
        let swap_s = groups
            .iter()
            .map(|g| v.swap_s(g.model))
            .fold(0.0_f64, f64::max); // uniformized S (see module docs)
        let big_m = model_val.iter().fold(active_val, |a, &b| a.max(b)) + 2.0;

        // Variable layout.
        let x = |i: usize, j: usize| i * n + j;
        let m_of = |j: usize| n * n + j;
        let t_of = |j: usize| n * n + n + j;
        let w_of = |j: usize| n * n + 2 * n + j;
        let v_of = |j: usize| n * n + 3 * n + j;
        let nv = n * n + 4 * n;

        let mut lp = Lp::new(nv);
        // Objective (Eq. 13): minimize Σ v_j + tiny swap regularizer.
        let mut obj = vec![0.0; nv];
        for j in 0..n {
            obj[v_of(j)] = -1.0;
            obj[t_of(j)] = -0.001 * swap_s.max(1e-3);
        }
        // Tie-break: when several orderings are penalty-free, prefer
        // placing larger-budget groups later (EDF within feasibility).
        let max_budget = budget.iter().cloned().fold(1.0_f64, f64::max).max(1.0);
        for i in 0..n {
            for j in 0..n {
                obj[x(i, j)] = 1e-5 * (budget[i] / max_budget) * j as f64 / n as f64;
            }
        }
        lp.set_objective(obj);

        // Eq. 6: assignment bijection.
        for i in 0..n {
            let mut row = vec![0.0; nv];
            for j in 0..n {
                row[x(i, j)] = 1.0;
            }
            lp.add(row, Cmp::Eq, 1.0);
        }
        for j in 0..n {
            let mut row = vec![0.0; nv];
            for i in 0..n {
                row[x(i, j)] = 1.0;
            }
            lp.add(row, Cmp::Eq, 1.0);
        }
        // Eq. 7: m_j = Σ_i model_i x_{i,j}.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            for i in 0..n {
                row[x(i, j)] = model_val[i];
            }
            row[m_of(j)] = -1.0;
            lp.add(row, Cmp::Eq, 0.0);
        }
        // Eq. 9 via big-M: |m_j − m_{j−1}| ≤ M t_j (m_{-1} = active).
        for j in 0..n {
            let mut r1 = vec![0.0; nv];
            let mut r2 = vec![0.0; nv];
            r1[m_of(j)] = 1.0;
            r2[m_of(j)] = -1.0;
            let rhs = if j == 0 { active_val } else { 0.0 };
            if j > 0 {
                r1[m_of(j - 1)] = -1.0;
                r2[m_of(j - 1)] = 1.0;
            }
            r1[t_of(j)] = -big_m;
            r2[t_of(j)] = -big_m;
            lp.add(r1, Cmp::Le, rhs);
            lp.add(r2, Cmp::Le, -rhs);
        }
        // Eq. 10: w_0 = S·t_0; w_j = w_{j−1} + Σ_i svc_i x_{i,j−1} + S·t_j.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            row[w_of(j)] = 1.0;
            row[t_of(j)] = -swap_s;
            if j > 0 {
                row[w_of(j - 1)] = -1.0;
                for i in 0..n {
                    row[x(i, j - 1)] = -svc[i];
                }
            }
            lp.add(row, Cmp::Eq, 0.0);
        }
        // Eq. 11/12 softened: w_j + Σ_i (svc_i − budget_i) x_{i,j} − v_j ≤ 0.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            row[w_of(j)] = 1.0;
            for i in 0..n {
                row[x(i, j)] = svc[i] - budget[i];
            }
            row[v_of(j)] = -1.0;
            lp.add(row, Cmp::Le, 0.0);
        }

        let mut binaries: Vec<usize> = (0..n * n).collect();
        binaries.extend((0..n).map(t_of));
        let mut milp = Milp::new(lp, binaries);
        milp.node_limit = self.cfg.node_limit;
        match milp.solve() {
            MilpResult::Optimal { x: sol, nodes, .. } => {
                let mut perm = vec![0usize; n];
                for j in 0..n {
                    for i in 0..n {
                        if sol[x(i, j)] > 0.5 {
                            perm[j] = i;
                        }
                    }
                }
                Some((perm, nodes))
            }
            MilpResult::Infeasible => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::InstanceId;
    use crate::coordinator::sched::testutil::{estimator, grp, view};
    use crate::coordinator::scheduler::{GlobalScheduler, SchedulerConfig, UNSERVABLE_PENALTY_S};

    #[test]
    fn tight_slo_scheduled_ahead() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let big = grp(1, 0, 200, 0.0, 3600.0);
        let tight = grp(2, 0, 4, 0.0, 20.0);
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&[&big, &tight], &views, 0.0);
        let order = &a.orders[&InstanceId(0)];
        assert_eq!(order[0], crate::coordinator::request_group::GroupId(2));
    }

    #[test]
    fn multi_instance_load_balances() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let groups: Vec<_> = (0..8).map(|i| grp(i, 0, 64, 0.0, 60.0)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        let l0 = a.orders[&InstanceId(0)].len();
        let l1 = a.orders[&InstanceId(1)].len();
        assert_eq!(l0 + l1, 8);
        assert!(l0 >= 2 && l1 >= 2, "unbalanced {l0}/{l1}");
    }

    #[test]
    fn respects_model_servability() {
        // Llama-70B (model 2) can only run on instance 1.
        use crate::coordinator::request_group::GroupId;
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let groups = vec![grp(1, 2, 8, 0.0, 3600.0), grp(2, 0, 8, 0.0, 3600.0)];
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0, 2], None)];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(a.orders[&InstanceId(1)].contains(&GroupId(1)));
        assert!(!a.orders[&InstanceId(0)].contains(&GroupId(1)));
    }

    #[test]
    fn pinned_group_stays_at_head() {
        use crate::coordinator::request_group::GroupId;
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let executing = grp(7, 0, 32, 0.0, 3600.0);
        let urgent = grp(8, 0, 4, 0.0, 10.0);
        let mut v = view(0, &[0], Some(0));
        v.executing = Some(GroupId(7));
        let a = sched.schedule(&[&executing, &urgent], &[v], 0.0);
        let order = &a.orders[&InstanceId(0)];
        assert_eq!(order[0], GroupId(7), "executing group pinned");
        assert_eq!(order[1], GroupId(8));
    }

    #[test]
    fn repeated_schedules_reuse_service_memo() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // 8 groups: enough to stay on the greedy path (no MILP) while
        // still exercising the assignment + penalty pricing.
        let groups: Vec<_> = (0..8).map(|i| grp(i, 0, 32, 0.0, 600.0)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        let b = sched.schedule(&refs, &views, 0.0);
        assert_eq!(a.orders, b.orders, "identical inputs, identical plan");
        let (hits, misses) = sched.estimator.memo_stats();
        assert!(hits > 0, "second invocation must hit the memo");
        assert!(
            hits >= misses,
            "unchanged groups should mostly hit: {hits} hits / {misses} misses"
        );
    }

    #[test]
    fn milp_orders_by_deadline_single_model() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 4,
                node_limit: 50_000,
                ..Default::default()
            },
            estimator(),
        );
        let g1 = grp(1, 0, 16, 0.0, 3600.0);
        let g2 = grp(2, 0, 16, 0.0, 30.0);
        let g3 = grp(3, 0, 16, 0.0, 600.0);
        let v = view(0, &[0], Some(0));
        let refs = vec![&g1, &g2, &g3];
        let (perm, _) = sched.milp_order(&refs, &v, 0.0).unwrap();
        // Tightest (g2) first.
        assert_eq!(perm[0], 1, "perm {perm:?}");
    }

    #[test]
    fn milp_avoids_needless_swaps() {
        // Two models, relaxed SLOs: optimal order clusters by model
        // (1 swap), not interleaved (3 swaps).
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 4,
                node_limit: 50_000,
                ..Default::default()
            },
            estimator(),
        );
        let g1 = grp(1, 0, 16, 0.0, 7200.0);
        let g2 = grp(2, 3, 16, 0.0, 7200.0);
        let g3 = grp(3, 0, 16, 0.0, 7200.0);
        let g4 = grp(4, 3, 16, 0.0, 7200.0);
        let v = view(0, &[0, 3], Some(0));
        let refs = vec![&g1, &g2, &g3, &g4];
        let (perm, _) = sched.milp_order(&refs, &v, 0.0).unwrap();
        let models: Vec<u32> = perm.iter().map(|&i| refs[i].model.0).collect();
        let transitions = models.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 1, "models {models:?}");
    }

    #[test]
    fn infeasible_flagged_when_capacity_exceeded() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // Enormous backlog with tiny SLOs.
        let groups: Vec<_> = (0..20).map(|i| grp(i, 0, 256, 0.0, 5.0)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(!a.feasible);
        assert!(a.total_penalty_s > 0.0);
    }

    #[test]
    fn unservable_group_reported_with_finite_penalty() {
        use crate::coordinator::request_group::GroupId;
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // Model 2 (Llama-70B) is not servable by the only instance.
        let lost = grp(1, 2, 8, 0.0, 60.0);
        let ok = grp(2, 0, 8, 0.0, 3600.0);
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&[&lost, &ok], &views, 0.0);
        assert!(
            a.total_penalty_s.is_finite(),
            "unservable group must not poison the penalty signal"
        );
        assert!(a.total_penalty_s >= UNSERVABLE_PENALTY_S);
        assert!(!a.feasible);
        assert_eq!(a.unservable, vec![GroupId(1)]);
        assert!(
            !a.orders[&InstanceId(0)].contains(&GroupId(1)),
            "unservable group must not be parked on a queue"
        );
        assert!(a.orders[&InstanceId(0)].contains(&GroupId(2)));
    }

    #[test]
    fn exact_milp_honored_beyond_milp_max_groups() {
        // Regression: ExactMilp used to silently fall back to the
        // heuristic when a queue exceeded `milp_max_groups`.
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 2,
                node_limit: 50_000,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<_> =
            (0..4).map(|i| grp(i, 0, 16, 0.0, 600.0 + i as f64)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(
            a.stats.used_milp,
            "ExactMilp must refine queues larger than milp_max_groups"
        );
    }

    /// Deterministic Fisher–Yates driven by a splitmix-style LCG.
    fn lcg_shuffle<T>(v: &mut [T], seed: &mut u64) {
        for i in (1..v.len()).rev() {
            *seed = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = ((*seed >> 33) as usize) % (i + 1);
            v.swap(i, j);
        }
    }

    #[test]
    fn schedule_invariant_to_group_slice_order() {
        // Property: the plan is a function of the group *set*, not the
        // iteration order of the slice handed in (which comes from a
        // BTreeMap in the engine).
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<_> = (0..24)
            .map(|i| {
                let slo = 30.0 + (i % 7) as f64 * 200.0;
                grp(i, (i % 2) as u32 * 3, 16 + (i % 5) as usize, i as f64, slo)
            })
            .collect();
        let views = vec![
            view(0, &[0, 3], Some(0)),
            view(1, &[0, 3], Some(3)),
            view(2, &[0], None),
        ];
        let base_refs: Vec<_> = groups.iter().collect();
        let base = sched.schedule(&base_refs, &views, 0.0);
        let mut seed = 0xC0FFEE_u64;
        for _ in 0..5 {
            let mut refs = base_refs.clone();
            lcg_shuffle(&mut refs, &mut seed);
            let a = sched.schedule(&refs, &views, 0.0);
            assert_eq!(a.orders, base.orders, "plan depends on slice order");
            assert!((a.total_penalty_s - base.total_penalty_s).abs() < 1e-9);
        }
    }

    #[test]
    fn delta_without_cache_falls_back_to_full() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let views = vec![view(0, &[0], Some(0))];
        let d = SchedDelta::default();
        assert!(sched.try_schedule_delta(&d, &views, 0.0).is_none());
    }

    #[test]
    fn delta_with_empty_dirty_set_changes_nothing() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<_> =
            (0..8).map(|i| grp(i, 0, 32, 0.0, 60.0 + i as f64)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let full = sched.schedule(&refs, &views, 0.0);
        let d = SchedDelta {
            total_groups: groups.len(),
            ..Default::default()
        };
        let a = sched
            .try_schedule_delta(&d, &views, 0.0)
            .expect("cache is warm");
        assert!(a.stats.incremental);
        assert!(
            a.orders.is_empty(),
            "identical inputs must produce an empty patch"
        );
        assert_eq!(
            sched.cached_orders().unwrap(),
            full.orders,
            "cached plan must still equal the full solve"
        );
    }

    #[test]
    fn delta_inserts_new_group_like_a_full_solve() {
        let mk_sched = || {
            GlobalScheduler::new(
                SchedulerConfig {
                    solver: SolverKind::Greedy,
                    ..Default::default()
                },
                estimator(),
            )
        };
        let mut groups: Vec<_> =
            (0..6).map(|i| grp(i, 0, 32, 0.0, 100.0 + 50.0 * i as f64)).collect();
        let views = vec![view(0, &[0], Some(0))];
        // Warm the incremental scheduler on the first 6 groups, then
        // deliver group 6 via the delta path.
        let inc = mk_sched();
        let refs: Vec<_> = groups.iter().collect();
        inc.schedule(&refs, &views, 0.0);
        groups.push(grp(6, 0, 32, 0.0, 900.0));
        let d = SchedDelta {
            dirty: vec![groups.last().unwrap()],
            removed: vec![],
            total_groups: groups.len(),
            ..Default::default()
        };
        let a = inc.try_schedule_delta(&d, &views, 0.0).expect("warm cache");
        assert!(a.stats.incremental);
        assert_eq!(a.stats.dirty, 1);
        // A fresh full solve over all 7 groups lands on the same plan.
        let full = mk_sched();
        let refs: Vec<_> = groups.iter().collect();
        let b = full.schedule(&refs, &views, 0.0);
        assert_eq!(inc.cached_orders().unwrap(), b.orders);
    }

    #[test]
    fn delta_invariant_to_dirty_iteration_order() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                incremental_dirty_frac: 1.0,
                ..Default::default()
            },
            estimator(),
        );
        let base: Vec<_> =
            (0..10).map(|i| grp(i, 0, 32, 0.0, 60.0 + 10.0 * i as f64)).collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let fresh: Vec<_> = (10..14)
            .map(|i| grp(i, 0, 32, 0.0, 45.0 + 5.0 * i as f64))
            .collect();
        let run = |dirty: Vec<&RequestGroup>| {
            let refs: Vec<_> = base.iter().collect();
            sched.schedule(&refs, &views, 0.0);
            let d = SchedDelta {
                dirty,
                removed: vec![],
                total_groups: base.len() + fresh.len(),
                ..Default::default()
            };
            sched.try_schedule_delta(&d, &views, 0.0).expect("warm");
            sched.cached_orders().unwrap()
        };
        let fwd = run(fresh.iter().collect());
        let rev = run(fresh.iter().rev().collect());
        assert_eq!(fwd, rev, "delta plan depends on dirty iteration order");
    }

    #[test]
    fn delta_removed_group_leaves_its_queue() {
        use crate::coordinator::request_group::GroupId;
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<_> =
            (0..6).map(|i| grp(i, 0, 32, 0.0, 60.0 + i as f64)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        sched.schedule(&refs, &views, 0.0);
        let d = SchedDelta {
            dirty: vec![],
            removed: vec![GroupId(3)],
            total_groups: 5,
            ..Default::default()
        };
        let a = sched.try_schedule_delta(&d, &views, 0.0).expect("warm");
        let order = &a.orders[&InstanceId(0)];
        assert!(!order.contains(&GroupId(3)));
        assert_eq!(order.len(), 5);
    }

    #[test]
    fn delta_reapplies_milp_on_head_window_membership_change() {
        // Carry-over gap closed: an `Auto`-mode delta pass that changes
        // a queue's MILP-eligible head window must refine it *now*, not
        // at the next full solve — and land on the same plan a cold
        // full solve of the identical state produces.
        use crate::coordinator::request_group::GroupId;
        let mk = || {
            GlobalScheduler::new(
                SchedulerConfig {
                    solver: SolverKind::Auto,
                    milp_max_groups: 4,
                    node_limit: 50_000,
                    ..Default::default()
                },
                estimator(),
            )
        };
        // Two models with relaxed SLOs — the swap-clustering structure
        // MILP refines — delivered incrementally.
        let mut groups = vec![
            grp(1, 0, 16, 0.0, 7200.0),
            grp(2, 3, 16, 0.0, 7200.0),
            grp(3, 0, 16, 0.0, 7200.0),
        ];
        let views = vec![view(0, &[0, 3], Some(0))];
        let inc = mk();
        let refs: Vec<_> = groups.iter().collect();
        inc.schedule(&refs, &views, 0.0);
        groups.push(grp(4, 3, 16, 0.0, 7200.0));
        let by_id: BTreeMap<GroupId, RequestGroup> =
            groups.iter().map(|g| (g.id, g.clone())).collect();
        let d = SchedDelta {
            dirty: vec![&by_id[&GroupId(4)]],
            removed: vec![],
            total_groups: groups.len(),
            groups: Some(&by_id),
        };
        let a = inc.try_schedule_delta(&d, &views, 0.0).expect("warm cache");
        assert!(a.stats.incremental);
        assert!(
            a.stats.used_milp,
            "head-window membership change must trigger in-pass MILP"
        );
        let full = mk();
        let refs: Vec<_> = groups.iter().collect();
        let b = full.schedule(&refs, &views, 0.0);
        assert!(b.stats.used_milp);
        assert_eq!(
            inc.cached_orders().unwrap(),
            b.orders,
            "refined delta plan must match the cold full solve"
        );
    }

    #[test]
    fn delta_without_group_table_keeps_greedy_order() {
        // `groups: None` disables the in-pass refinement (the patch
        // itself never needs the table) — the pass still succeeds and
        // stays greedy-only.
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Auto,
                milp_max_groups: 4,
                node_limit: 50_000,
                ..Default::default()
            },
            estimator(),
        );
        let mut groups = vec![
            grp(1, 0, 16, 0.0, 7200.0),
            grp(2, 3, 16, 0.0, 7200.0),
            grp(3, 0, 16, 0.0, 7200.0),
        ];
        let views = vec![view(0, &[0, 3], Some(0))];
        let refs: Vec<_> = groups.iter().collect();
        sched.schedule(&refs, &views, 0.0);
        groups.push(grp(4, 3, 16, 0.0, 7200.0));
        let d = SchedDelta {
            dirty: vec![groups.last().unwrap()],
            removed: vec![],
            total_groups: groups.len(),
            groups: None,
        };
        let a = sched.try_schedule_delta(&d, &views, 0.0).expect("warm");
        assert!(a.stats.incremental);
        assert!(!a.stats.used_milp, "no group table, no refinement");
    }

    #[test]
    fn delta_dirtiness_beyond_threshold_forces_full_solve() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                incremental_dirty_frac: 0.25,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<_> =
            (0..8).map(|i| grp(i, 0, 32, 0.0, 60.0 + i as f64)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        sched.schedule(&refs, &views, 0.0);
        let d = SchedDelta {
            dirty: groups.iter().take(4).collect(),
            removed: vec![],
            total_groups: groups.len(),
            ..Default::default()
        };
        assert!(
            sched.try_schedule_delta(&d, &views, 0.0).is_none(),
            "4/8 dirty exceeds the 25% threshold"
        );
    }

    #[test]
    fn delta_reanchors_untouched_queue_penalties() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        // Every group violating at t=0: 256-member groups, 5 s SLOs —
        // each violating group's penalty grows one second per second.
        let groups: Vec<_> = (0..8).map(|i| grp(i, 0, 256, 0.0, 5.0)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let full = sched.schedule(&refs, &views, 0.0);
        assert!(full.total_penalty_s > 0.0);
        let d = SchedDelta {
            total_groups: groups.len(),
            ..Default::default()
        };
        // An empty delta 10 s later must re-anchor the untouched queues:
        // 8 violating groups × 10 s of extra lateness.
        let a = sched.try_schedule_delta(&d, &views, 10.0).expect("warm");
        assert!(
            (a.total_penalty_s - (full.total_penalty_s + 80.0)).abs() < 1e-6,
            "expected {} + 80, got {}",
            full.total_penalty_s,
            a.total_penalty_s
        );
        // A second pass advances from the new anchor, not from t=0.
        let b = sched.try_schedule_delta(&d, &views, 15.0).expect("warm");
        assert!(
            (b.total_penalty_s - (a.total_penalty_s + 40.0)).abs() < 1e-6,
            "expected {} + 40, got {}",
            a.total_penalty_s,
            b.total_penalty_s
        );
    }

    #[test]
    fn delta_crossing_scan_prices_freshly_violating_groups() {
        // The second-order amortization gap the crossing scan closes:
        // a group whose budget is healthy at the full solve but runs
        // out *between* passes must start accruing penalty on an
        // untouched queue — and the re-anchored signal must match a
        // fresh full solve of the identical state.
        let mk = || {
            GlobalScheduler::new(
                SchedulerConfig {
                    solver: SolverKind::Greedy,
                    ..Default::default()
                },
                estimator(),
            )
        };
        // One modest group per queue, with an SLO calibrated from the
        // estimator itself so the groups start comfortably inside their
        // budgets (feasible at t=0) whatever the profiled throughput.
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let probe = grp(0, 0, 16, 0.0, 1e9);
        let perf = views[0].perf_for[&probe.model];
        let est = estimator();
        let (svc, _) = est.group_service(&probe, &perf);
        // Floor of 25 s keeps the groups in the probe's SLO class
        // (Batch1, > 20 s) so they price with the probed profile.
        let budget = ((svc + perf.prefill_s) * 1.5 + 5.0).max(25.0);
        let groups: Vec<_> = (0..2).map(|i| grp(i, 0, 16, 0.0, budget)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let inc = mk();
        let full0 = inc.schedule(&refs, &views, 0.0);
        assert!(
            full0.feasible,
            "groups must start inside their budgets: {}",
            full0.total_penalty_s
        );
        let d = SchedDelta {
            total_groups: groups.len(),
            ..Default::default()
        };
        // Long after every budget has run out, an *empty* delta pass
        // must price the crossings; compare against a cold full solve
        // of the same state at the same time.
        let late = budget + 100.0;
        let a = inc.try_schedule_delta(&d, &views, late).expect("warm");
        assert!(a.orders.is_empty(), "no queue was touched");
        assert!(
            a.total_penalty_s > 0.0,
            "crossing scan must surface the new violations"
        );
        let fresh = mk().schedule(&refs, &views, late);
        assert!(
            (a.total_penalty_s - fresh.total_penalty_s).abs() < 1e-6,
            "re-anchored {} vs fresh {}",
            a.total_penalty_s,
            fresh.total_penalty_s
        );
        assert!(!a.feasible);
    }

    #[test]
    fn parallel_repricing_is_bit_identical_to_serial() {
        let mk = |threads: usize| {
            GlobalScheduler::new(
                SchedulerConfig {
                    solver: SolverKind::Greedy,
                    threads,
                    ..Default::default()
                },
                estimator(),
            )
        };
        let groups: Vec<_> = (0..48)
            .map(|i| {
                let slo = 30.0 + (i % 7) as f64 * 150.0;
                grp(i, (i % 2) as u32 * 3, 16 + (i % 5) as usize, i as f64 * 0.1, slo)
            })
            .collect();
        let refs: Vec<_> = groups.iter().collect();
        let views: Vec<InstanceView> = (0..8).map(|i| view(i, &[0, 3], Some(0))).collect();
        let serial = mk(1).schedule(&refs, &views, 3.0);
        let par = mk(4).schedule(&refs, &views, 3.0);
        assert_eq!(serial.orders, par.orders, "plan must not depend on threads");
        assert_eq!(
            serial.total_penalty_s.to_bits(),
            par.total_penalty_s.to_bits(),
            "penalty must be bit-identical across thread counts"
        );
    }

    #[test]
    fn delta_instance_set_change_forces_full_solve() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<_> = (0..4).map(|i| grp(i, 0, 32, 0.0, 60.0)).collect();
        let refs: Vec<_> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        sched.schedule(&refs, &views, 0.0);
        // Instance 1 failed: the survivor-only view set must not patch.
        let survivors = vec![view(0, &[0], Some(0))];
        let d = SchedDelta {
            total_groups: groups.len(),
            ..Default::default()
        };
        assert!(sched.try_schedule_delta(&d, &survivors, 0.0).is_none());
    }
}
