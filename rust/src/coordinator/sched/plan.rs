//! Plan layer: queue orderings and the assignment the scheduler emits.
//!
//! [`Assignment`] is the scheduler's output contract (order *patches* —
//! instances absent from `orders` keep their current queue). The
//! affinity-EDF comparator lives here once ([`affinity_cmp`] over
//! [`AffinityKey`]) and drives both ordering paths: [`affinity_order`]
//! over live groups (full solve) and [`reorder_cached`] over the
//! pricing table (delta path) — one comparator is what guarantees the
//! two paths produce the same plan for the same state. Unservable
//! groups retire through [`finish_unservable`] instead of being parked
//! on an arbitrary queue.

use std::collections::BTreeMap;

use crate::backend::{InstanceId, ModelId};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::sched::cache::CachedQueue;
use crate::coordinator::sched::pricing::GroupPricing;
use crate::coordinator::sched::{SolveStats, UNSERVABLE_PENALTY_S};

/// Scheduler output: per-instance virtual-queue orderings.
///
/// A full solve emits an order for every instance; an incremental pass
/// emits orders only for instances whose queue actually changed, so
/// callers apply `orders` as a patch (clean queues keep their position).
#[derive(Debug, Clone)]
pub struct Assignment {
    pub orders: BTreeMap<InstanceId, Vec<GroupId>>,
    /// True iff every group's estimated completion meets its SLO.
    pub feasible: bool,
    /// Σ max(0, estimated completion − budget) across groups, seconds,
    /// plus [`UNSERVABLE_PENALTY_S`] per member of each unservable group.
    pub total_penalty_s: f64,
    /// Groups no instance can serve, reported separately instead of
    /// being parked on an arbitrary queue.
    pub unservable: Vec<GroupId>,
    pub stats: SolveStats,
}

/// The affinity-EDF sort key: (cluster deadline, non-active-model flag,
/// model id, deadline, group id).
pub(crate) type AffinityKey = (f64, bool, ModelId, f64, GroupId);

/// The one comparator behind both ordering paths — [`affinity_order`]
/// (full solve, over groups) and [`reorder_cached`] (delta path, over
/// the pricing table).
pub(crate) fn affinity_cmp(a: &AffinityKey, b: &AffinityKey) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0)
        .then(a.1.cmp(&b.1))
        .then(a.2.cmp(&b.2))
        .then(a.3.total_cmp(&b.3))
        .then(a.4.cmp(&b.4))
}

/// Model-affinity EDF ordering of one queue's groups: cluster by
/// model, order clusters by earliest deadline, EDF within cluster —
/// the Fig. 5 "Oracle" structure that avoids swap thrashing.
pub fn affinity_order(groups: &mut [&RequestGroup], active: Option<ModelId>) {
    // Cluster key: model; cluster deadline: min member deadline.
    let mut cluster_deadline: BTreeMap<ModelId, f64> = BTreeMap::new();
    for g in groups.iter() {
        let e = cluster_deadline.entry(g.model).or_insert(f64::INFINITY);
        *e = e.min(g.deadline());
    }
    // Active-model cluster first on deadline ties (swap-free). The
    // active-model flag must compare *before* the raw model-id
    // tie-break: with the old order, equal models made the flags
    // trivially equal and the preference was unreachable.
    let key = |g: &RequestGroup| -> AffinityKey {
        (
            cluster_deadline[&g.model],
            Some(g.model) != active,
            g.model,
            g.deadline(),
            g.id,
        )
    };
    groups.sort_by(|a, b| affinity_cmp(&key(a), &key(b)));
}

/// Affinity-EDF over cached pricing — driven by the pricing table so
/// the delta path never touches the group table. The pinned executing
/// head, if present, is left in place.
pub(crate) fn reorder_cached(cq: &mut CachedQueue, pricing: &BTreeMap<GroupId, GroupPricing>) {
    let start =
        usize::from(cq.executing.is_some() && cq.order.first() == cq.executing.as_ref());
    let active = cq.active_model;
    let rest = &mut cq.order[start..];
    let mut cluster_deadline: BTreeMap<ModelId, f64> = BTreeMap::new();
    for gid in rest.iter() {
        if let Some(p) = pricing.get(gid) {
            let e = cluster_deadline.entry(p.model).or_insert(f64::INFINITY);
            *e = e.min(p.deadline);
        }
    }
    let key = |gid: &GroupId| -> AffinityKey {
        match pricing.get(gid) {
            Some(p) => (
                cluster_deadline
                    .get(&p.model)
                    .copied()
                    .unwrap_or(f64::INFINITY),
                Some(p.model) != active,
                p.model,
                p.deadline,
                *gid,
            ),
            // Unpriced ids (shouldn't happen) sink to the back, stably.
            None => (f64::INFINITY, true, ModelId(u32::MAX), f64::INFINITY, *gid),
        }
    };
    rest.sort_by(|a, b| affinity_cmp(&key(a), &key(b)));
}

/// The better-candidate predicate shared by both greedy assignment
/// loops: lower penalty, then earlier completion, then lighter load
/// (1e-9 epsilons throughout). `best` carries (pen, completion, load).
pub(crate) fn candidate_improves(
    best: Option<(f64, f64, f64)>,
    pen: f64,
    completion: f64,
    load: f64,
) -> bool {
    match best {
        None => true,
        Some((bp, bc, bl)) => {
            pen < bp - 1e-9
                || ((pen - bp).abs() < 1e-9
                    && (completion < bc - 1e-9
                        || ((completion - bc).abs() < 1e-9 && load < bl)))
        }
    }
}

/// Split a queue into (pinned executing head, reorderable rest).
pub(crate) fn split_pinned<'a>(
    all: &[&'a RequestGroup],
    executing: Option<GroupId>,
) -> (Vec<&'a RequestGroup>, Vec<&'a RequestGroup>) {
    let mut head = Vec::new();
    let mut rest = Vec::new();
    for &g in all {
        if Some(g.id) == executing {
            head.push(g);
        } else {
            rest.push(g);
        }
    }
    (head, rest)
}

/// Retire the pass's unservable set into the assignment contract: a
/// sorted id list for the engine's shed path plus the finite penalty
/// surcharge ([`UNSERVABLE_PENALTY_S`] per member) that keeps the
/// signal comparable instead of infinite.
pub(crate) fn finish_unservable(unservable: &[(GroupId, u32)]) -> (Vec<GroupId>, f64) {
    let penalty = unservable
        .iter()
        .map(|&(_, n)| UNSERVABLE_PENALTY_S * n as f64)
        .sum::<f64>();
    let mut ids: Vec<GroupId> = unservable.iter().map(|&(g, _)| g).collect();
    ids.sort_unstable();
    (ids, penalty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::testutil::grp;

    #[test]
    fn affinity_order_groups_same_model_together() {
        let g1 = grp(1, 0, 8, 0.0, 60.0);
        let g2 = grp(2, 1, 8, 1.0, 61.0);
        let g3 = grp(3, 0, 8, 2.0, 62.0);
        let g4 = grp(4, 1, 8, 3.0, 63.0);
        let mut v = vec![&g4, &g3, &g2, &g1];
        affinity_order(&mut v, None);
        let models: Vec<u32> = v.iter().map(|g| g.model.0).collect();
        // Same-model groups contiguous ⇒ exactly one transition.
        let transitions = models.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 1, "order {models:?}");
    }

    #[test]
    fn affinity_order_active_model_cluster_leads_on_deadline_tie() {
        // Regression: the active-model preference used to sit *after*
        // the raw model-id tie-break, making it unreachable — deadline-
        // tied clusters ordered by model id and swapped needlessly.
        let g1 = grp(1, 0, 8, 0.0, 60.0);
        let g2 = grp(2, 1, 8, 0.0, 60.0); // same cluster deadline as model 0
        let g3 = grp(3, 0, 8, 0.0, 60.0);
        let g4 = grp(4, 1, 8, 0.0, 60.0);
        let mut v = vec![&g1, &g2, &g3, &g4];
        affinity_order(&mut v, Some(ModelId(1)));
        let models: Vec<u32> = v.iter().map(|g| g.model.0).collect();
        assert_eq!(
            models,
            vec![1, 1, 0, 0],
            "active model-1 cluster must lead on a deadline tie"
        );
    }

    #[test]
    fn finish_unservable_sorts_and_prices() {
        let (ids, pen) = finish_unservable(&[(GroupId(9), 2), (GroupId(3), 1)]);
        assert_eq!(ids, vec![GroupId(3), GroupId(9)]);
        assert!((pen - 3.0 * UNSERVABLE_PENALTY_S).abs() < 1e-6);
        let (ids, pen) = finish_unservable(&[]);
        assert!(ids.is_empty());
        assert_eq!(pen, 0.0);
    }
}
