//! Pricing layer: everything that turns a request group into seconds.
//!
//! [`GroupPricing`] is the cached per-group price; [`price_group`] is
//! its single constructor (full-solve cache rebuild and both delta-path
//! insertion sites must price identically or the paths drift);
//! [`append_score`] scores a candidate append behind a queue tail (the
//! one implementation shared by the full-solve assignment loop and the
//! delta insertion loop); and [`reprice_queue`] is the front-to-back
//! walk that recomputes a cached queue's tail state, penalty, and the
//! violation-slope data ([`crate::coordinator::sched::cache`] re-anchors
//! from it in constant time).

use std::collections::BTreeMap;

use crate::backend::{InstanceId, ModelId, PerfModel};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::rwt::RwtEstimator;
use crate::coordinator::sched::cache::CachedQueue;
use crate::coordinator::sched::InstanceView;

/// Cached per-group pricing from the pass that last (re)assigned it —
/// everything the delta path needs to reorder and re-price a queue
/// without touching the group table.
#[derive(Debug, Clone, Copy)]
pub(crate) struct GroupPricing {
    pub(crate) model: ModelId,
    pub(crate) deadline: f64,
    /// Mean service time including prefill, on the assigned instance.
    pub(crate) svc_s: f64,
    pub(crate) len: u32,
    /// Instance whose cached order holds this group — lets a removal
    /// touch only the owning queue instead of scanning every order, so
    /// a delta pass stays O(dirty), independent of total queue size.
    pub(crate) owner: InstanceId,
}

/// Aggregate tail state of one cached queue (what a greedy append sees).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct QTail {
    pub(crate) wait: f64,
    pub(crate) tail_model: Option<ModelId>,
    pub(crate) load: f64,
}

/// Predicted device time to drain `g` on `perf`: mean service including
/// prefill. The scalar behind [`GroupPricing::svc_s`], also consumed
/// directly by the device-time-aware baselines (WFQ's weighted deficit,
/// the EDF+swap-penalty oracle).
pub(crate) fn device_time(est: &RwtEstimator, g: &RequestGroup, perf: &PerfModel) -> f64 {
    let (svc, _) = est.group_service(g, perf);
    svc + perf.prefill_s
}

/// Price one group on `perf` for the cache: mean service including
/// prefill, deadline, size, and the queue that will hold it. The single
/// constructor for [`GroupPricing`].
pub(crate) fn price_group(
    est: &RwtEstimator,
    g: &RequestGroup,
    perf: &PerfModel,
    owner: InstanceId,
) -> GroupPricing {
    GroupPricing {
        model: g.model,
        deadline: g.deadline(),
        svc_s: device_time(est, g, perf),
        len: g.len() as u32,
        owner,
    }
}

/// Score appending `g` behind tail `t` of `v`'s queue: returns
/// (penalty, completion). Shared by the full-solve assignment loop and
/// the delta insertion loop — the two must score identically or their
/// plans drift.
pub(crate) fn append_score(
    est: &RwtEstimator,
    t: &QTail,
    g: &RequestGroup,
    v: &InstanceView,
    perf: &PerfModel,
    now: f64,
) -> (f64, f64) {
    let swap = if t.tail_model != Some(g.model) {
        v.swap_s(g.model)
    } else {
        0.0
    };
    let (svc, _) = est.group_service(g, perf);
    let completion = t.wait + swap + perf.prefill_s + svc;
    let pen = (completion - (g.deadline() - now)).max(0.0);
    (pen, completion)
}

/// Walk a cached order front-to-back, recomputing the queue's tail
/// state (what a greedy append sees) and its penalty from the pricing
/// table alone. Also records the violation-slope data the constant-time
/// re-anchor runs on:
///
/// * `viol_groups` — groups violating *now* (each accrues one second of
///   penalty per second, so the count is the penalty's d/dt slope);
/// * `crossings` — for every group still inside its budget, the future
///   time its slack runs out and it starts accruing too. A delta pass
///   that leaves this queue untouched drains expired crossings instead
///   of re-walking ([`CachedQueue::reanchor`]) — the "crossing scan"
///   that closes the second-order amortization gap where freshly
///   violating groups on clean queues went unpriced until the queue was
///   next touched.
pub(crate) fn reprice_queue(
    cq: &mut CachedQueue,
    pricing: &BTreeMap<GroupId, GroupPricing>,
    v: &InstanceView,
    now: f64,
) {
    let mut tail = QTail {
        wait: 0.0,
        tail_model: v.active_model,
        load: 0.0,
    };
    let mut penalty = 0.0;
    let mut viol = 0u32;
    cq.crossings.clear();
    cq.crossed = 0;
    // audit:hot-loop — the per-pass repricing walk; `crossings` is
    // cleared and refilled in place, so the walk allocates nothing.
    for gid in &cq.order {
        let Some(p) = pricing.get(gid) else { continue };
        if tail.tail_model != Some(p.model) {
            tail.wait += v.swap_s(p.model);
        }
        tail.tail_model = Some(p.model);
        // Signed lateness: positive ⇒ violating now; non-positive ⇒
        // the group crosses into violation at `now - raw` (assuming
        // its queue position and price hold, which is exactly the
        // regime the re-anchor covers — anything else re-walks).
        let raw = tail.wait + p.svc_s - (p.deadline - now);
        if raw > 0.0 {
            viol += 1;
            penalty += raw;
        } else {
            cq.crossings.push(now - raw);
        }
        tail.wait += p.svc_s;
        tail.load += p.len as f64;
    }
    // Walk order is queue order; the re-anchor drains crossings in
    // *time* order, so sort ascending (ties are equivalent: each
    // crossing contributes `now - t_c` independent of drain order).
    cq.crossings.sort_by(|a, b| a.total_cmp(b));
    cq.tail = tail;
    cq.penalty = penalty;
    cq.priced_at = now;
    cq.viol_groups = viol;
}
