//! The layered scheduling core behind [`crate::coordinator::scheduler`]
//! (the thin façade every call site imports through).
//!
//! The paper's global scheduler is itself layered — RWT pricing feeds an
//! affinity ordering which feeds the LSO plan — and the module split
//! mirrors that, so a new policy or amortization edits one layer instead
//! of a fused hot core:
//!
//! ```text
//!             ┌──────────────────────────────────────────────┐
//!             │ solve.rs — orchestration                     │
//!             │  full solve · delta patch · MILP refinement  │
//!             │  fallback triggers (cold cache, view-set     │
//!             │  change, ExactMilp, dirtiness threshold)     │
//!             └───────┬───────────────┬──────────────┬───────┘
//!                     │ prices via    │ orders via   │ remembers via
//!             ┌───────▼──────┐ ┌──────▼───────┐ ┌────▼─────────────┐
//!             │ pricing.rs   │ │ plan.rs      │ │ cache.rs         │
//!             │ GroupPricing │ │ Assignment   │ │ SchedCache       │
//!             │ price_group  │ │ AffinityKey  │ │ CachedQueue      │
//!             │ append_score │ │ affinity     │ │ epoch re-anchor  │
//!             │ reprice walk │ │ ordering,    │ │ + crossing scan  │
//!             │ + violation  │ │ order        │ │ view-set         │
//!             │ slopes       │ │ patches      │ │ invalidation     │
//!             └──────────────┘ └──────────────┘ └──────────────────┘
//! ```
//!
//! Invariants the layers hold jointly (the golden suite enforces them
//! end to end):
//!
//! * **One price, one comparator.** `pricing::price_group` /
//!   `pricing::append_score` are the only scoring paths and
//!   `plan::affinity_cmp` the only ordering comparator, shared by the
//!   full solve and the delta patch — the two paths must not drift.
//! * **The cache is a mirror, never an oracle.** `cache::SchedCache`
//!   holds exactly what the last pass computed; any doubt (view-set
//!   change, cold start, exactness) invalidates it and the full solve
//!   rebuilds it from scratch.
//! * **Threading is invisible.** The repricing walk fans out over the
//!   shared [`crate::util::WorkerPool`] in index-ordered chunks with a
//!   sequential penalty fold, so any lane count is bit-identical to
//!   serial.

pub mod cache;
pub mod plan;
pub mod pricing;
pub mod solve;

use std::collections::BTreeMap;

use crate::backend::{InstanceId, ModelId, PerfModel};
use crate::coordinator::request_group::{GroupId, RequestGroup};

/// Scheduler's view of one serving instance.
#[derive(Debug, Clone)]
pub struct InstanceView {
    pub id: InstanceId,
    pub active_model: Option<ModelId>,
    /// Profiled perf per servable model (absent ⇒ model can't run here,
    /// e.g. Llama-70B on an A10 — hardware heterogeneity, §8.3).
    pub perf_for: BTreeMap<ModelId, PerfModel>,
    /// Swap-in latency per model from its current tier.
    pub swap_time: BTreeMap<ModelId, f64>,
    /// Group currently executing — pinned (no preemptive migration, §5).
    pub executing: Option<GroupId>,
}

impl InstanceView {
    pub fn can_serve(&self, m: ModelId) -> bool {
        self.perf_for.contains_key(&m)
    }

    /// Swap-in cost charged when the queue transitions onto model `m`.
    pub fn swap_s(&self, m: ModelId) -> f64 {
        self.swap_time.get(&m).copied().unwrap_or(0.0)
    }
}

/// Which solver the global scheduler uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Greedy,
    /// Exact per-queue MILP refinement after greedy assignment.
    ExactMilp,
    /// Greedy, with MILP refinement only for queues small enough.
    Auto,
}

/// Hard safety cap on the exact-MILP queue size. The dense tableau is
/// O(n²) variables with O(n) rows of that width, so honoring
/// `ExactMilp` *unbounded* would allocate gigabytes at Fig. 20 queue
/// sizes; beyond this cap the heuristic ordering stands in even under
/// `ExactMilp`. 64 groups ⇒ ~4k binaries, ~10 MB of tableau — the
/// practical ceiling of the branch-and-bound anyway.
pub const MILP_HARD_CAP: usize = 64;

/// Penalty charged per member of a group no instance can serve
/// (misconfigured fleet). Large but *finite*: the old behavior parked
/// such groups at a queue head, where `queue_penalty` returned
/// `f64::INFINITY` and poisoned `total_penalty_s` for every comparison.
pub const UNSERVABLE_PENALTY_S: f64 = 1e6;

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    pub solver: SolverKind,
    /// Max groups per queue for the `Auto` MILP refinement path
    /// (`ExactMilp` refines regardless, up to [`MILP_HARD_CAP`]).
    pub milp_max_groups: usize,
    pub node_limit: usize,
    /// Incremental passes fall back to a full solve when
    /// (dirty + removed) exceeds this fraction of the live group table —
    /// past that point re-walking everything is cheaper than patching.
    ///
    /// Default tuned with `cargo bench -- dirty_frac` against the
    /// `scale`-scenario shape (1562 groups, 10 instances): the delta
    /// pass skips the global deadline sort and the re-insertion of
    /// every *clean* group even when most queues end up touched, so it
    /// stays ahead of the full solve well past the old 0.25 threshold;
    /// the crossover sits near half the table dirty.
    pub incremental_dirty_frac: f64,
    /// Master switch for the delta path. Off ⇒ `try_schedule_delta`
    /// always bails and full solves never store a plan cache (they
    /// still price plans with the same shared walk).
    pub incremental: bool,
    /// Worker lanes for the per-queue repricing walk of a full solve
    /// (each queue's walk is independent; results are merged in index
    /// order, so the plan and the summed penalty are bit-identical to
    /// the serial pass). 1 = serial; wired from `SimConfig::threads`.
    /// The lanes come from a persistent [`crate::util::WorkerPool`] —
    /// shared with the engine's view refresh when the scheduler is
    /// built through the simulator.
    pub threads: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            solver: SolverKind::Auto,
            milp_max_groups: 6,
            node_limit: 20_000,
            incremental_dirty_frac: 0.5,
            incremental: true,
            threads: 1,
        }
    }
}

/// Solve statistics for overhead studies (Fig. 20).
#[derive(Debug, Clone, Copy, Default)]
pub struct SolveStats {
    pub groups: usize,
    pub milp_nodes: usize,
    pub used_milp: bool,
    /// This pass went down the cached delta path.
    pub incremental: bool,
    /// Dirty groups re-inserted by the delta path.
    pub dirty: usize,
    /// Instances whose queue changed this pass.
    pub touched_instances: usize,
    /// Violation crossings drained by the delta pass's re-anchor scans
    /// (untouched queues advancing their penalties without a walk).
    pub crossings_drained: usize,
}

/// One scheduler pass's worth of group-table changes, produced by the
/// engine's dirty tracking and consumed by the incremental path.
#[derive(Debug, Clone, Default)]
pub struct SchedDelta<'a> {
    /// Groups whose membership, deadline anchor, or member states
    /// changed since the last pass — re-priced and re-inserted.
    pub dirty: Vec<&'a RequestGroup>,
    /// Groups that drained or were dissolved since the last pass.
    pub removed: Vec<GroupId>,
    /// Live group count (for the full-solve dirtiness threshold).
    pub total_groups: usize,
    /// Full live group table, for the delta path's in-pass `Auto`-mode
    /// MILP refinement — re-ordering a touched queue's head window
    /// needs the *clean* groups on it too, which `dirty` alone can't
    /// supply. `None` disables the refinement (the patch itself never
    /// needs it).
    pub groups: Option<&'a BTreeMap<GroupId, RequestGroup>>,
}

/// Shared fixtures for the layer tests (estimator / views / groups built
/// the same way across `plan`, `cache`, and `solve` suites).
#[cfg(test)]
pub(crate) mod testutil {
    use std::collections::BTreeMap;

    use crate::backend::{GpuKind, InstanceId, ModelCatalog, ModelId, PerfModel};
    use crate::coordinator::request_group::{GroupId, RequestGroup};
    use crate::coordinator::rwt::{ProfileTable, RwtEstimator};
    use crate::workload::{SloClass, Trace, WorkloadSpec};

    use super::InstanceView;

    pub fn estimator() -> RwtEstimator {
        let spec = WorkloadSpec::w_a(ModelId(0), 100.0, 2000);
        let trace = Trace::generate(&spec, 11);
        RwtEstimator::new(ProfileTable::from_trace(&trace))
    }

    pub fn view(id: u32, models: &[u32], active: Option<u32>) -> InstanceView {
        let catalog = ModelCatalog::paper_multi_model();
        let mut perf_for = BTreeMap::new();
        let mut swap_time = BTreeMap::new();
        for &m in models {
            let p = PerfModel::profile(catalog.get(ModelId(m)), GpuKind::A100, 161.0);
            perf_for.insert(ModelId(m), p);
            swap_time.insert(ModelId(m), p.swap_cpu_gpu_s);
        }
        InstanceView {
            id: InstanceId(id),
            active_model: active.map(ModelId),
            perf_for,
            swap_time,
            executing: None,
        }
    }

    pub fn grp(id: u64, model: u32, n: usize, arrival: f64, slo: f64) -> RequestGroup {
        RequestGroup {
            id: GroupId(id),
            model: ModelId(model),
            class: if slo <= 20.0 {
                SloClass::Interactive
            } else {
                SloClass::Batch1
            },
            slo: crate::workload::SloTarget::new(slo, 1.0),
            earliest_arrival_s: arrival,
            members: (0..n as u64).collect(),
            mega: false,
        }
    }
}
