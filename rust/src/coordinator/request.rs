//! Request model (Def. 2.1/2.2): prompt token count plus metadata — model
//! type and SLO target (p99 TTFT bound + per-token TPOT bound). The
//! ground-truth output length is carried for the execution backend only;
//! the coordinator's estimator never reads it (the paper's premise:
//! output lengths are unknown a priori and must be modeled as a
//! distribution).

use crate::backend::ModelId;
use crate::workload::{SloClass, SloTarget, TraceRequest};

/// Lifecycle state of a request in QLM (§3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// In the global queue, not yet assigned to a running batch.
    Waiting,
    /// In some instance's running batch.
    Running,
    /// Evicted from a running batch back to the waiting queue; its KV may
    /// still be parked in the source instance's CPU memory.
    Evicted,
    /// Final token emitted.
    Completed,
    /// Refused by admission control (or retired as unservable) — never
    /// served; counts as an SLO violation in metrics.
    Shed,
}

/// A queued LLM request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub model: ModelId,
    pub class: SloClass,
    /// TTFT + TPOT bounds relative to arrival / first token.
    pub slo: SloTarget,
    pub input_tokens: u32,
    /// Ground truth output length — execution backend only.
    pub output_tokens_hidden: u32,
    pub arrival_s: f64,
    pub mega: bool,
    pub state: RequestState,
    /// Tokens already generated (nonzero after an eviction).
    pub generated: u32,
    /// Instance holding this request's evicted KV, if any.
    pub evicted_from: Option<crate::backend::InstanceId>,
    /// First-token timestamp, once produced.
    pub first_token_s: Option<f64>,
    /// Completion timestamp.
    pub completed_s: Option<f64>,
}

impl Request {
    pub fn from_trace(id: u64, t: &TraceRequest) -> Self {
        Request {
            id,
            model: t.model,
            class: t.class,
            slo: t.slo,
            input_tokens: t.input_tokens,
            output_tokens_hidden: t.output_tokens,
            arrival_s: t.arrival_s,
            mega: t.mega,
            state: RequestState::Waiting,
            generated: 0,
            evicted_from: None,
            first_token_s: None,
            completed_s: None,
        }
    }

    /// Absolute deadline for the first token (the TTFT dimension drives
    /// queue ordering; TPOT is policed at decode time).
    pub fn deadline(&self) -> f64 {
        self.arrival_s + self.slo.ttft_s
    }

    /// TTFT if the first token has been produced.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }

    /// Did the request meet its TTFT SLO? Unfinished requests count as
    /// violations once `now` passes the deadline.
    pub fn slo_met(&self, now: f64) -> Option<bool> {
        match self.ttft() {
            Some(t) => Some(t <= self.slo.ttft_s),
            None if now > self.deadline() => Some(false),
            None => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(arrival: f64, ttft_slo: f64) -> Request {
        Request::from_trace(
            1,
            &TraceRequest {
                arrival_s: arrival,
                model: ModelId(0),
                class: SloClass::Interactive,
                slo: SloTarget::new(ttft_slo, 0.25),
                input_tokens: 100,
                output_tokens: 50,
                mega: false,
            },
        )
    }

    #[test]
    fn deadline_is_arrival_plus_ttft_slo() {
        let r = mk(10.0, 20.0);
        assert_eq!(r.deadline(), 30.0);
    }

    #[test]
    fn ttft_and_slo() {
        let mut r = mk(10.0, 20.0);
        assert_eq!(r.ttft(), None);
        assert_eq!(r.slo_met(15.0), None);
        assert_eq!(r.slo_met(31.0), Some(false));
        r.first_token_s = Some(25.0);
        assert_eq!(r.ttft(), Some(15.0));
        assert_eq!(r.slo_met(100.0), Some(true));
        r.first_token_s = Some(35.0);
        assert_eq!(r.slo_met(100.0), Some(false));
    }

    #[test]
    fn from_trace_copies_fields() {
        let r = mk(1.0, 20.0);
        assert_eq!(r.state, RequestState::Waiting);
        assert_eq!(r.input_tokens, 100);
        assert_eq!(r.output_tokens_hidden, 50);
        assert_eq!(r.generated, 0);
        assert_eq!(r.slo.tpot_s, 0.25);
    }
}
