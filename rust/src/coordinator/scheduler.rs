//! The Global Scheduler (§7): assigns request groups to virtual queues
//! and orders them to maximize SLO attainment, given RWT estimates.
//!
//! This file is the thin façade every call site imports through; the
//! implementation is the layered core under [`crate::coordinator::sched`]
//! (see its module docs for the layer diagram and invariants):
//!
//! * [`sched::pricing`] — [`GroupPricing`](sched::pricing), the single
//!   `price_group`/`append_score` scoring path, and the `reprice_queue`
//!   walk that records violation slopes + crossing times;
//! * [`sched::cache`] — the plan cache (`SchedCache`/`CachedQueue`),
//!   the constant-time penalty re-anchor with its crossing scan, and
//!   view-set invalidation;
//! * [`sched::plan`] — [`Assignment`], the affinity-EDF comparator and
//!   both ordering paths, order patches, unservable retirement;
//! * [`sched::solve`] — orchestration: the greedy full solve, the
//!   incremental delta patch, exact-MILP refinement (Eqs. 6–13), and
//!   every fallback trigger between them.
//!
//! Two solver paths (see [`SolverKind`]): the **exact MILP** — the
//! paper's formulation, binary assignment of groups to queue positions
//! minimizing total SLO violation — and the **greedy heuristic** —
//! deadline-ordered assignment with model affinity, linear in groups,
//! which is what scales to the 400K-request queues of Fig. 20. On top
//! of both, the **incremental delta path**
//! ([`GlobalScheduler::try_schedule_delta`]) patches the cached plan
//! with one pass's dirty set instead of re-solving the table; failure
//! events, instance-set changes, the exact-MILP solver, and dirtiness
//! above [`SchedulerConfig::incremental_dirty_frac`] fall back to a
//! full solve, which refreshes the cache.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::backend::{InstanceId, ModelId};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::rwt::RwtEstimator;
use crate::coordinator::sched;
use crate::coordinator::sched::cache::SchedCache;
use crate::util::WorkerPool;

pub use crate::coordinator::sched::plan::Assignment;
pub use crate::coordinator::sched::{
    InstanceView, MILP_HARD_CAP, SchedDelta, SchedulerConfig, SolveStats, SolverKind,
    UNSERVABLE_PENALTY_S,
};

/// The global scheduler.
#[derive(Debug, Clone)]
pub struct GlobalScheduler {
    pub cfg: SchedulerConfig,
    pub estimator: RwtEstimator,
    /// Last plan, for the incremental delta path. Interior mutability so
    /// `schedule` (&self, shared by benches and the engine) can refresh it.
    pub(crate) cache: RefCell<Option<SchedCache>>,
    /// Lanes for the parallel repricing walk. Built through the
    /// simulator this is the *shared* per-`Simulation` pool (one set of
    /// workers serves both the view refresh and the repricing walk);
    /// standalone construction spawns its own from `cfg.threads`.
    pub(crate) pool: Arc<WorkerPool>,
}

impl GlobalScheduler {
    pub fn new(cfg: SchedulerConfig, estimator: RwtEstimator) -> Self {
        let pool = Arc::new(WorkerPool::new(cfg.threads));
        Self::with_pool(cfg, estimator, pool)
    }

    /// Construct over an existing worker pool — the simulator path,
    /// where one pool per `Simulation` serves every parallel pass.
    pub fn with_pool(cfg: SchedulerConfig, estimator: RwtEstimator, pool: Arc<WorkerPool>) -> Self {
        GlobalScheduler {
            cfg,
            estimator,
            cache: RefCell::new(None),
            pool,
        }
    }

    /// The cached per-instance orders from the last pass (full or
    /// delta), if any — observability for tests and the bench harness.
    pub fn cached_orders(&self) -> Option<BTreeMap<InstanceId, Vec<GroupId>>> {
        self.cache
            .borrow()
            .as_ref()
            .map(|c| c.queues.iter().map(|q| (q.id, q.order.clone())).collect())
    }

    /// Model-affinity EDF ordering of one queue's groups: cluster by
    /// model, order clusters by earliest deadline, EDF within cluster —
    /// the Fig. 5 "Oracle" structure that avoids swap thrashing.
    /// (Delegates to [`sched::plan::affinity_order`], the one
    /// comparator both ordering paths share.)
    pub fn affinity_order(groups: &mut [&RequestGroup], active: Option<ModelId>) {
        sched::plan::affinity_order(groups, active);
    }
}
