//! The Global Scheduler (§7): assigns request groups to virtual queues
//! and orders them to maximize SLO attainment, given RWT estimates.
//!
//! Two solver paths:
//!
//! * **Exact MILP** — the paper's formulation (Eqs. 6–13): binary
//!   assignment x_{i,j} of groups to queue positions, model values m_j
//!   (Eq. 7), big-M switch indicators t_j (Eq. 9), accumulated waiting
//!   times wt_j (Eq. 10), and penalties p_j = wt_j − slo_j (Eq. 11),
//!   minimizing total violation (Eq. 13). SLO satisfaction (Eq. 12) is
//!   soft-constrained through violation variables v_j ≥ p_j so the solver
//!   still returns the least-bad ordering when demand exceeds capacity
//!   (the paper falls back to EDF/scale-up in that regime, §9).
//!   The model-dependent swap time in Eq. 10's product term is
//!   conservatively uniformized to max_i S_i to stay linear (the exact
//!   product would need n² extra binaries).
//!
//! * **Greedy heuristic** — deadline-ordered assignment with model
//!   affinity, linear in groups; this is what scales to the 400K-request
//!   queues of Fig. 20 and is the default for large instances (Design
//!   Principle #1).
//!
//! On top of both, an **incremental delta path**
//! ([`GlobalScheduler::try_schedule_delta`]): the steady-state regime of
//! a 100K-request queue is "one group arrived / one group drained", and
//! re-solving the whole table for that is O(groups × instances) per
//! pass. The scheduler caches its last plan (per-instance orders, tail
//! queue state, and per-group service prices) and a pass that only
//! carries a small dirty set re-prices and re-inserts just the dirty
//! groups; clean groups keep their queue position. Failure events,
//! instance-set changes, the exact-MILP solver, and dirtiness above
//! `SchedulerConfig::incremental_dirty_frac` fall back to a full solve,
//! which refreshes the cache.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};

use crate::backend::{InstanceId, ModelId, PerfModel};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::rwt::RwtEstimator;
use crate::solver::{Cmp, Lp, Milp, MilpResult};

/// Scheduler's view of one serving instance.
#[derive(Debug, Clone)]
pub struct InstanceView {
    pub id: InstanceId,
    pub active_model: Option<ModelId>,
    /// Profiled perf per servable model (absent ⇒ model can't run here,
    /// e.g. Llama-70B on an A10 — hardware heterogeneity, §8.3).
    pub perf_for: HashMap<ModelId, PerfModel>,
    /// Swap-in latency per model from its current tier.
    pub swap_time: HashMap<ModelId, f64>,
    /// Group currently executing — pinned (no preemptive migration, §5).
    pub executing: Option<GroupId>,
}

impl InstanceView {
    pub fn can_serve(&self, m: ModelId) -> bool {
        self.perf_for.contains_key(&m)
    }

    fn swap_s(&self, m: ModelId) -> f64 {
        self.swap_time.get(&m).copied().unwrap_or(0.0)
    }
}

/// Which solver the global scheduler uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Greedy,
    /// Exact per-queue MILP refinement after greedy assignment.
    ExactMilp,
    /// Greedy, with MILP refinement only for queues small enough.
    Auto,
}

/// Hard safety cap on the exact-MILP queue size. The dense tableau is
/// O(n²) variables with O(n) rows of that width, so honoring
/// `ExactMilp` *unbounded* would allocate gigabytes at Fig. 20 queue
/// sizes; beyond this cap the heuristic ordering stands in even under
/// `ExactMilp`. 64 groups ⇒ ~4k binaries, ~10 MB of tableau — the
/// practical ceiling of the branch-and-bound anyway.
pub const MILP_HARD_CAP: usize = 64;

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    pub solver: SolverKind,
    /// Max groups per queue for the `Auto` MILP refinement path
    /// (`ExactMilp` refines regardless, up to [`MILP_HARD_CAP`]).
    pub milp_max_groups: usize,
    pub node_limit: usize,
    /// Incremental passes fall back to a full solve when
    /// (dirty + removed) exceeds this fraction of the live group table —
    /// past that point re-walking everything is cheaper than patching.
    ///
    /// Default tuned with `cargo bench -- dirty_frac` against the
    /// `scale`-scenario shape (1562 groups, 10 instances): the delta
    /// pass skips the global deadline sort and the re-insertion of
    /// every *clean* group even when most queues end up touched, so it
    /// stays ahead of the full solve well past the old 0.25 threshold;
    /// the crossover sits near half the table dirty.
    pub incremental_dirty_frac: f64,
    /// Master switch for the delta path. Off ⇒ `try_schedule_delta`
    /// always bails and full solves never store a plan cache (they
    /// still price plans with the same shared walk).
    pub incremental: bool,
    /// Worker threads for the per-queue repricing walk of a full solve
    /// (each queue's walk is independent; results are merged in index
    /// order, so the plan and the summed penalty are bit-identical to
    /// the serial pass). 1 = serial; wired from `SimConfig::threads`.
    pub threads: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            solver: SolverKind::Auto,
            milp_max_groups: 6,
            node_limit: 20_000,
            incremental_dirty_frac: 0.5,
            incremental: true,
            threads: 1,
        }
    }
}

/// Penalty charged per member of a group no instance can serve
/// (misconfigured fleet). Large but *finite*: the old behavior parked
/// such groups at a queue head, where `queue_penalty` returned
/// `f64::INFINITY` and poisoned `total_penalty_s` for every comparison.
pub const UNSERVABLE_PENALTY_S: f64 = 1e6;

/// Solve statistics for overhead studies (Fig. 20).
#[derive(Debug, Clone, Copy, Default)]
pub struct SolveStats {
    pub groups: usize,
    pub milp_nodes: usize,
    pub used_milp: bool,
    /// This pass went down the cached delta path.
    pub incremental: bool,
    /// Dirty groups re-inserted by the delta path.
    pub dirty: usize,
    /// Instances whose queue changed this pass.
    pub touched_instances: usize,
}

/// Scheduler output: per-instance virtual-queue orderings.
///
/// A full solve emits an order for every instance; an incremental pass
/// emits orders only for instances whose queue actually changed, so
/// callers apply `orders` as a patch (clean queues keep their position).
#[derive(Debug, Clone)]
pub struct Assignment {
    pub orders: HashMap<InstanceId, Vec<GroupId>>,
    /// True iff every group's estimated completion meets its SLO.
    pub feasible: bool,
    /// Σ max(0, estimated completion − budget) across groups, seconds,
    /// plus [`UNSERVABLE_PENALTY_S`] per member of each unservable group.
    pub total_penalty_s: f64,
    /// Groups no instance can serve, reported separately instead of
    /// being parked on an arbitrary queue.
    pub unservable: Vec<GroupId>,
    pub stats: SolveStats,
}

/// One scheduler pass's worth of group-table changes, produced by the
/// engine's dirty tracking and consumed by the incremental path.
#[derive(Debug, Clone, Default)]
pub struct SchedDelta<'a> {
    /// Groups whose membership, deadline anchor, or member states
    /// changed since the last pass — re-priced and re-inserted.
    pub dirty: Vec<&'a RequestGroup>,
    /// Groups that drained or were dissolved since the last pass.
    pub removed: Vec<GroupId>,
    /// Live group count (for the full-solve dirtiness threshold).
    pub total_groups: usize,
}

/// Cached per-group pricing from the pass that last (re)assigned it —
/// everything the delta path needs to reorder and re-price a queue
/// without touching the group table.
#[derive(Debug, Clone, Copy)]
struct GroupPricing {
    model: ModelId,
    deadline: f64,
    /// Mean service time including prefill, on the assigned instance.
    svc_s: f64,
    len: u32,
    /// Instance whose cached order holds this group — lets a removal
    /// touch only the owning queue instead of scanning every order, so
    /// a delta pass stays O(dirty), independent of total queue size.
    owner: InstanceId,
}

/// Aggregate tail state of one cached queue (what a greedy append sees).
#[derive(Debug, Clone, Copy, Default)]
struct QTail {
    wait: f64,
    tail_model: Option<ModelId>,
    load: f64,
}

#[derive(Debug, Clone)]
struct CachedQueue {
    id: InstanceId,
    order: Vec<GroupId>,
    tail: QTail,
    penalty: f64,
    /// The `now` the penalty was last priced at (full walk), advanced
    /// by the constant-time re-anchor on untouched delta passes.
    priced_at: f64,
    /// Groups violating at the last walk — the penalty's d/dt slope
    /// (each violating group's penalty grows one second per second).
    viol_groups: u32,
    active_model: Option<ModelId>,
    executing: Option<GroupId>,
}

/// The scheduler's memory between passes: last plan + pricing.
#[derive(Debug, Clone, Default)]
struct SchedCache {
    queues: Vec<CachedQueue>,
    pricing: HashMap<GroupId, GroupPricing>,
    /// (group, member count) pairs currently unservable.
    unservable: Vec<(GroupId, u32)>,
}

/// The global scheduler.
#[derive(Debug, Clone)]
pub struct GlobalScheduler {
    pub cfg: SchedulerConfig,
    pub estimator: RwtEstimator,
    /// Last plan, for the incremental delta path. Interior mutability so
    /// `schedule` (&self, shared by benches and the engine) can refresh it.
    cache: RefCell<Option<SchedCache>>,
}

impl GlobalScheduler {
    pub fn new(cfg: SchedulerConfig, estimator: RwtEstimator) -> Self {
        GlobalScheduler {
            cfg,
            estimator,
            cache: RefCell::new(None),
        }
    }

    /// Score appending `g` behind tail `t` of `v`'s queue: returns
    /// (penalty, completion). The one implementation shared by the
    /// full-solve assignment loop and the delta insertion loop — the
    /// two must score identically or their plans drift.
    fn append_score(
        &self,
        t: &QTail,
        g: &RequestGroup,
        v: &InstanceView,
        perf: &PerfModel,
        now: f64,
    ) -> (f64, f64) {
        let swap = if t.tail_model != Some(g.model) {
            v.swap_s(g.model)
        } else {
            0.0
        };
        let (svc, _) = self.estimator.group_service(g, perf);
        let completion = t.wait + swap + perf.prefill_s + svc;
        let pen = (completion - (g.deadline() - now)).max(0.0);
        (pen, completion)
    }

    /// Price one group on `perf` for the cache: mean service including
    /// prefill, deadline, size, and the queue that will hold it. The
    /// single constructor for [`GroupPricing`] — the full-solve cache
    /// rebuild and both delta-path insertion sites must price
    /// identically or the two paths drift.
    fn price_group(&self, g: &RequestGroup, perf: &PerfModel, owner: InstanceId) -> GroupPricing {
        let (svc, _) = self.estimator.group_service(g, perf);
        GroupPricing {
            model: g.model,
            deadline: g.deadline(),
            svc_s: svc + perf.prefill_s,
            len: g.len() as u32,
            owner,
        }
    }

    /// The cached per-instance orders from the last pass (full or
    /// delta), if any — observability for tests and the bench harness.
    pub fn cached_orders(&self) -> Option<HashMap<InstanceId, Vec<GroupId>>> {
        self.cache
            .borrow()
            .as_ref()
            .map(|c| c.queues.iter().map(|q| (q.id, q.order.clone())).collect())
    }

    /// Penalty of an ordering on one instance: Σ max(0, completion − budget).
    pub fn queue_penalty(&self, order: &[&RequestGroup], view: &InstanceView, now: f64) -> f64 {
        if order.is_empty() {
            return 0.0;
        }
        // Perf is per-model; use the head group's model for Θ (groups on
        // one queue in one walk segment share the instance's device).
        let Some(perf) = view.perf_for.get(&order[0].model) else {
            return f64::INFINITY;
        };
        let est = self.estimator.estimate_queue(
            order,
            perf,
            view.active_model,
            |m| view.swap_s(m),
        );
        order
            .iter()
            .zip(&est)
            .map(|(g, e)| (e.completion_mean_s - (g.deadline() - now)).max(0.0))
            .sum()
    }

    /// Model-affinity EDF ordering of one queue's groups: cluster by
    /// model, order clusters by earliest deadline, EDF within cluster —
    /// the Fig. 5 "Oracle" structure that avoids swap thrashing.
    pub fn affinity_order(groups: &mut [&RequestGroup], active: Option<ModelId>) {
        // Cluster key: model; cluster deadline: min member deadline.
        let mut cluster_deadline: HashMap<ModelId, f64> = HashMap::new();
        for g in groups.iter() {
            let e = cluster_deadline.entry(g.model).or_insert(f64::INFINITY);
            *e = e.min(g.deadline());
        }
        // Active-model cluster first on deadline ties (swap-free). The
        // active-model flag must compare *before* the raw model-id
        // tie-break: with the old order, equal models made the flags
        // trivially equal and the preference was unreachable.
        let key = |g: &RequestGroup| -> AffinityKey {
            (
                cluster_deadline[&g.model],
                Some(g.model) != active,
                g.model,
                g.deadline(),
                g.id,
            )
        };
        groups.sort_by(|a, b| affinity_cmp(&key(a), &key(b)));
    }

    /// Main entry: assign + order all schedulable groups.
    ///
    /// Takes group *references* so callers holding groups in a table
    /// (the simulator's live group map) schedule without deep-cloning
    /// every member list per invocation (§Perf).
    pub fn schedule(
        &self,
        groups: &[&RequestGroup],
        instances: &[InstanceView],
        now: f64,
    ) -> Assignment {
        // One scheduler invocation = one memo epoch for service pricing.
        self.estimator.begin_epoch();
        let by_id: HashMap<GroupId, &RequestGroup> =
            groups.iter().map(|g| (g.id, *g)).collect();
        let mut orders: HashMap<InstanceId, Vec<GroupId>> = HashMap::new();
        let mut unservable: Vec<(GroupId, u32)> = Vec::new();
        let mut stats = SolveStats {
            groups: groups.len(),
            ..Default::default()
        };

        // 1. Pin executing groups to their instances' heads.
        let mut pinned: HashMap<GroupId, InstanceId> = HashMap::new();
        for v in instances {
            let order = orders.entry(v.id).or_default();
            if let Some(g) = v.executing {
                if by_id.contains_key(&g) {
                    order.push(g);
                    pinned.insert(g, v.id);
                }
            }
        }

        // 2. Deadline-ordered greedy assignment of the rest.
        let mut todo: Vec<&RequestGroup> = groups
            .iter()
            .copied()
            .filter(|g| !pinned.contains_key(&g.id))
            .collect();
        todo.sort_by(|a, b| {
            a.deadline()
                .partial_cmp(&b.deadline())
                .unwrap()
                .then(a.id.cmp(&b.id))
        });

        // §Perf: incremental O(G·V) assignment — each candidate append is
        // priced from cached per-queue state (accumulated wait, tail
        // model) instead of re-walking the whole queue (which made the
        // assignment quadratic in groups; see EXPERIMENTS.md §Perf).
        let mut qstate: HashMap<InstanceId, QTail> = instances
            .iter()
            .map(|v| {
                let mut st = QTail {
                    wait: 0.0,
                    tail_model: v.active_model,
                    load: 0.0,
                };
                // Seed with the pinned executing group, if any.
                if let Some(gid) = v.executing {
                    if let Some(g) = by_id.get(&gid) {
                        if let Some(perf) = v.perf_for.get(&g.model) {
                            let (svc, _) = self.estimator.group_service(g, perf);
                            st.wait += svc + perf.prefill_s;
                            st.tail_model = Some(g.model);
                            st.load += g.len() as f64;
                        }
                    }
                }
                (v.id, st)
            })
            .collect();

        for g in todo {
            let mut best: Option<(InstanceId, f64, f64, f64)> = None; // (id, pen, completion, load)
            for v in instances {
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                let st = qstate[&v.id];
                let (pen, completion) = self.append_score(&st, g, v, perf, now);
                if candidate_improves(
                    best.map(|(_, p, c, l)| (p, c, l)),
                    pen,
                    completion,
                    st.load,
                ) {
                    best = Some((v.id, pen, completion, st.load));
                }
            }
            match best {
                Some((id, _, completion, _)) => {
                    orders.get_mut(&id).unwrap().push(g.id);
                    let st = qstate.get_mut(&id).unwrap();
                    st.wait = completion;
                    st.tail_model = Some(g.model);
                    st.load += g.len() as f64;
                }
                None => {
                    // No instance can serve this model (misconfigured
                    // fleet): report separately with a large finite
                    // penalty. Parking it on an arbitrary queue made
                    // `queue_penalty` go infinite at the queue head,
                    // rendering the penalty signal useless.
                    unservable.push((g.id, g.len() as u32));
                }
            }
        }

        // 3. Per-queue ordering: affinity-EDF, optionally MILP-refined.
        for v in instances {
            let ids = orders.get_mut(&v.id).unwrap();
            let all: Vec<&RequestGroup> =
                ids.iter().filter_map(|id| by_id.get(id).copied()).collect();
            let (head, mut rest) = split_pinned(&all, v.executing);
            Self::affinity_order(&mut rest, v.active_model);

            // `ExactMilp` is honored past `milp_max_groups` (the old
            // code silently fell back to the heuristic there), bounded
            // only by [`MILP_HARD_CAP`] — the node limit bounds the
            // search but not tableau construction, and the heuristic-
            // regression guard below keeps truncated searches harmless.
            let use_milp = rest.len() >= 2
                && match self.cfg.solver {
                    SolverKind::Greedy => false,
                    SolverKind::ExactMilp => rest.len() <= MILP_HARD_CAP,
                    SolverKind::Auto => {
                        rest.len() <= self.cfg.milp_max_groups.min(MILP_HARD_CAP)
                    }
                };

            if use_milp {
                if let Some((order, nodes)) = self.milp_order(&rest, v, now) {
                    stats.milp_nodes += nodes;
                    stats.used_milp = true;
                    // Accept MILP order only if it doesn't regress the
                    // heuristic (node-limit exhaustion can truncate search).
                    let full_h: Vec<&RequestGroup> =
                        head.iter().copied().chain(rest.iter().copied()).collect();
                    let full_m: Vec<&RequestGroup> = head
                        .iter()
                        .copied()
                        .chain(order.iter().map(|&i| rest[i]))
                        .collect();
                    if self.queue_penalty(&full_m, v, now)
                        <= self.queue_penalty(&full_h, v, now) + 1e-9
                    {
                        rest = full_m[head.len()..].to_vec();
                    }
                }
            }

            let full: Vec<&RequestGroup> =
                head.into_iter().chain(rest.into_iter()).collect();
            *ids = full.iter().map(|g| g.id).collect();
        }

        // Penalty: per-group pricing via the same `reprice_queue` walk
        // the delta path uses, so full and delta passes report one
        // consistent signal (head-perf `queue_penalty` stays as the
        // MILP acceptance metric above). The walk doubles as the cache
        // rebuild; ExactMilp never feeds the delta path (it always
        // bails to preserve exactness), so it skips the cache and
        // prices with `queue_penalty` instead.
        let mut total_penalty = if self.cfg.solver != SolverKind::ExactMilp {
            self.store_cache(&orders, &by_id, instances, now, unservable.clone())
        } else {
            instances
                .iter()
                .map(|v| {
                    let refs: Vec<&RequestGroup> = orders[&v.id]
                        .iter()
                        .filter_map(|id| by_id.get(id).copied())
                        .collect();
                    self.queue_penalty(&refs, v, now)
                })
                .sum()
        };
        total_penalty += unservable
            .iter()
            .map(|&(_, n)| UNSERVABLE_PENALTY_S * n as f64)
            .sum::<f64>();

        let mut unservable: Vec<GroupId> = unservable.into_iter().map(|(g, _)| g).collect();
        unservable.sort_unstable();

        Assignment {
            feasible: total_penalty <= 1e-9,
            total_penalty_s: total_penalty,
            orders,
            unservable,
            stats,
        }
    }

    /// Rebuild the incremental cache from a just-computed full plan:
    /// price every queued group (cheap — the services were just
    /// memoized), then run the shared [`reprice_queue`] walk per queue
    /// for tail state and penalty. Returns the summed queue penalty so
    /// full solves report the exact signal delta passes will maintain.
    fn store_cache(
        &self,
        orders: &HashMap<InstanceId, Vec<GroupId>>,
        by_id: &HashMap<GroupId, &RequestGroup>,
        instances: &[InstanceView],
        now: f64,
        unservable: Vec<(GroupId, u32)>,
    ) -> f64 {
        let mut pricing = HashMap::with_capacity(by_id.len());
        let mut queues = Vec::with_capacity(instances.len());
        for v in instances {
            let order = orders.get(&v.id).cloned().unwrap_or_default();
            for gid in &order {
                let Some(g) = by_id.get(gid) else { continue };
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                pricing.insert(g.id, self.price_group(g, perf, v.id));
            }
            queues.push(CachedQueue {
                id: v.id,
                order,
                tail: QTail::default(),
                penalty: 0.0,
                priced_at: now,
                viol_groups: 0,
                active_model: v.active_model,
                executing: v.executing,
            });
        }
        // §Perf: each queue's repricing walk is independent of every
        // other's (it reads only the shared pricing table), so the
        // walks fan out over the shared scoped-thread primitive
        // (`util::par_chunks_mut`, same gate and chunking as the
        // engine's view refresh). Queues stay in instance order and the
        // penalty is summed sequentially afterwards, so the result is
        // bit-identical to the serial pass whatever the thread count.
        let view_of: HashMap<InstanceId, &InstanceView> =
            instances.iter().map(|v| (v.id, v)).collect();
        let pricing_ref = &pricing;
        crate::util::par_chunks_mut(&mut queues, self.cfg.threads, |cq| {
            reprice_queue(cq, pricing_ref, view_of[&cq.id], now);
        });
        let total: f64 = queues.iter().map(|q| q.penalty).sum();
        // With the delta path disabled there is no consumer for the
        // plan cache — the walk above still ran (it *is* the penalty
        // computation), but keep no state a disabled path could read.
        if self.cfg.incremental {
            *self.cache.borrow_mut() = Some(SchedCache {
                queues,
                pricing,
                unservable,
            });
        }
        total
    }

    /// Incremental pass: patch the cached plan with one pass's dirty
    /// set instead of re-solving the whole group table.
    ///
    /// Returns `None` when a full solve is required — no cache yet, the
    /// instance set changed (failures), the solver demands exactness, or
    /// dirtiness exceeds `incremental_dirty_frac` — and the caller then
    /// runs [`Self::schedule`], which refreshes the cache.
    ///
    /// Cost is O(dirty × instances + touched queue lengths); clean
    /// queues keep their order and tail state, and their last-priced
    /// penalty is *re-anchored* to `now` in constant time: each
    /// violating group's penalty grows exactly one second per second,
    /// so the queue's penalty advances by `(now − priced_at) ×
    /// viol_groups` without a walk. (Groups that newly *cross into*
    /// violation between walks are still picked up only when the queue
    /// is touched — the remaining, second-order amortization.)
    /// Per-queue ordering on touched queues is greedy affinity-EDF
    /// only; `Auto`-mode MILP refinement re-applies at the next full
    /// solve.
    pub fn try_schedule_delta(
        &self,
        delta: &SchedDelta,
        instances: &[InstanceView],
        now: f64,
    ) -> Option<Assignment> {
        if !self.cfg.incremental || self.cfg.solver == SolverKind::ExactMilp {
            return None;
        }
        let mut guard = self.cache.borrow_mut();
        let cache = guard.as_mut()?;
        if cache.queues.len() != instances.len()
            || cache.queues.iter().zip(instances).any(|(c, v)| c.id != v.id)
        {
            return None;
        }
        let changed = delta.dirty.len() + delta.removed.len();
        if changed as f64 > self.cfg.incremental_dirty_frac * delta.total_groups.max(1) as f64 {
            return None;
        }
        let SchedCache {
            queues,
            pricing,
            unservable,
        } = cache;

        // Executing groups stay pinned at their heads even when dirty.
        let pinned: HashMap<GroupId, usize> = instances
            .iter()
            .enumerate()
            .filter_map(|(k, v)| v.executing.map(|g| (g, k)))
            .collect();

        // Everything leaving its current queue position.
        let mut gone: HashSet<GroupId> = delta.removed.iter().copied().collect();
        for g in &delta.dirty {
            if !pinned.contains_key(&g.id) {
                gone.insert(g.id);
            }
        }
        unservable.retain(|(g, _)| !gone.contains(g));

        let mut touched = vec![false; instances.len()];
        let idx_of: HashMap<InstanceId, usize> = instances
            .iter()
            .enumerate()
            .map(|(k, v)| (v.id, k))
            .collect();

        // Only queues that actually hold a departing group need their
        // order rewritten — the owner index keeps this O(dirty) instead
        // of O(total groups) (see `GroupPricing::owner`).
        for gid in &gone {
            if let Some(p) = pricing.get(gid) {
                if let Some(&k) = idx_of.get(&p.owner) {
                    touched[k] = true;
                }
            }
        }
        for gid in &delta.removed {
            pricing.remove(gid);
        }

        // 1. Drop departing groups; sync pinning and active-model state.
        for (k, v) in instances.iter().enumerate() {
            let cq = &mut queues[k];
            if touched[k] {
                cq.order.retain(|g| !gone.contains(g));
            }
            if cq.executing != v.executing {
                cq.executing = v.executing;
                touched[k] = true;
            }
            if let Some(e) = v.executing {
                if cq.order.first() != Some(&e) && cq.order.contains(&e) {
                    cq.order.retain(|&g| g != e);
                    cq.order.insert(0, e);
                    touched[k] = true;
                }
            }
            if cq.active_model != v.active_model {
                cq.active_model = v.active_model;
                touched[k] = true; // head-swap pricing changed
            }
        }

        // 2. Re-price pinned dirty groups in place.
        for g in &delta.dirty {
            let Some(&k) = pinned.get(&g.id) else { continue };
            touched[k] = true;
            if let Some(perf) = instances[k].perf_for.get(&g.model) {
                pricing.insert(g.id, self.price_group(g, perf, instances[k].id));
            }
            if !queues[k].order.contains(&g.id) {
                queues[k].order.insert(0, g.id);
            }
        }

        // 2.5 Refresh tail state of every queue touched so far, *before*
        //     scoring insertions: without this, step 3 would price
        //     candidates against tails that still include the groups
        //     just removed above, steering arrivals away from queues
        //     that freed capacity this very pass.
        for (k, v) in instances.iter().enumerate() {
            if touched[k] {
                reprice_queue(&mut queues[k], pricing, v, now);
            }
        }

        // 3. Greedy re-insertion of dirty groups in deadline order —
        //    identical candidate scoring to the full solve, priced
        //    against cached queue tails.
        let mut todo: Vec<&RequestGroup> = delta
            .dirty
            .iter()
            .copied()
            .filter(|g| !pinned.contains_key(&g.id))
            .collect();
        todo.sort_by(|a, b| {
            a.deadline()
                .partial_cmp(&b.deadline())
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        for g in todo {
            let mut best: Option<(usize, f64, f64, f64)> = None;
            for (k, v) in instances.iter().enumerate() {
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                let t = queues[k].tail;
                let (pen, completion) = self.append_score(&t, g, v, perf, now);
                if candidate_improves(
                    best.map(|(_, p, c, l)| (p, c, l)),
                    pen,
                    completion,
                    t.load,
                ) {
                    best = Some((k, pen, completion, t.load));
                }
            }
            match best {
                Some((k, _, completion, _)) => {
                    let v = &instances[k];
                    let perf = v.perf_for[&g.model];
                    pricing.insert(g.id, self.price_group(g, &perf, v.id));
                    let cq = &mut queues[k];
                    cq.order.push(g.id);
                    cq.tail.wait = completion;
                    cq.tail.tail_model = Some(g.model);
                    cq.tail.load += g.len() as f64;
                    touched[k] = true;
                }
                None => unservable.push((g.id, g.len() as u32)),
            }
        }

        // 4. Reorder + re-price touched queues from cached pricing;
        //    re-anchor untouched queues' penalties to `now` via the
        //    constant-time epoch offset (violating groups accrue one
        //    second of penalty per second — no walk needed).
        for (k, v) in instances.iter().enumerate() {
            if touched[k] {
                let cq = &mut queues[k];
                reorder_cached(cq, pricing);
                reprice_queue(cq, pricing, v, now);
            } else {
                let cq = &mut queues[k];
                let dt = now - cq.priced_at;
                if dt > 0.0 {
                    cq.penalty += dt * cq.viol_groups as f64;
                    cq.priced_at = now;
                }
            }
        }

        // 5. Assemble the patch: orders only for queues that changed.
        let mut orders = HashMap::new();
        for (k, cq) in queues.iter().enumerate() {
            if touched[k] {
                orders.insert(cq.id, cq.order.clone());
            }
        }
        let mut total_penalty: f64 = queues.iter().map(|q| q.penalty).sum();
        total_penalty += unservable
            .iter()
            .map(|&(_, n)| UNSERVABLE_PENALTY_S * n as f64)
            .sum::<f64>();
        let mut unservable_ids: Vec<GroupId> =
            unservable.iter().map(|&(g, _)| g).collect();
        unservable_ids.sort_unstable();
        let touched_instances = touched.iter().filter(|&&t| t).count();
        Some(Assignment {
            feasible: total_penalty <= 1e-9,
            total_penalty_s: total_penalty,
            orders,
            unservable: unservable_ids,
            stats: SolveStats {
                groups: delta.total_groups,
                incremental: true,
                dirty: delta.dirty.len(),
                touched_instances,
                ..Default::default()
            },
        })
    }

    /// Exact ordering of `groups` on instance `v` via the §7 MILP.
    /// Returns the permutation (indices into `groups`) and node count.
    pub fn milp_order(
        &self,
        groups: &[&RequestGroup],
        v: &InstanceView,
        now: f64,
    ) -> Option<(Vec<usize>, usize)> {
        let n = groups.len();
        if n == 0 {
            return Some((Vec::new(), 0));
        }
        let perf = v.perf_for.get(&groups[0].model)?;
        // Per-group constants.
        let svc: Vec<f64> = groups
            .iter()
            .map(|g| {
                let (m, _) = self.estimator.group_service(g, perf);
                m + perf.prefill_s
            })
            .collect();
        let budget: Vec<f64> = groups.iter().map(|g| g.deadline() - now).collect();
        let model_val: Vec<f64> = groups.iter().map(|g| g.model.0 as f64 + 1.0).collect();
        let active_val = v.active_model.map(|m| m.0 as f64 + 1.0).unwrap_or(0.0);
        let swap_s = groups
            .iter()
            .map(|g| v.swap_s(g.model))
            .fold(0.0_f64, f64::max); // uniformized S (see module docs)
        let big_m = model_val.iter().fold(active_val, |a, &b| a.max(b)) + 2.0;

        // Variable layout.
        let x = |i: usize, j: usize| i * n + j;
        let m_of = |j: usize| n * n + j;
        let t_of = |j: usize| n * n + n + j;
        let w_of = |j: usize| n * n + 2 * n + j;
        let v_of = |j: usize| n * n + 3 * n + j;
        let nv = n * n + 4 * n;

        let mut lp = Lp::new(nv);
        // Objective (Eq. 13): minimize Σ v_j + tiny swap regularizer.
        let mut obj = vec![0.0; nv];
        for j in 0..n {
            obj[v_of(j)] = -1.0;
            obj[t_of(j)] = -0.001 * swap_s.max(1e-3);
        }
        // Tie-break: when several orderings are penalty-free, prefer
        // placing larger-budget groups later (EDF within feasibility).
        let max_budget = budget.iter().cloned().fold(1.0_f64, f64::max).max(1.0);
        for i in 0..n {
            for j in 0..n {
                obj[x(i, j)] = 1e-5 * (budget[i] / max_budget) * j as f64 / n as f64;
            }
        }
        lp.set_objective(obj);

        // Eq. 6: assignment bijection.
        for i in 0..n {
            let mut row = vec![0.0; nv];
            for j in 0..n {
                row[x(i, j)] = 1.0;
            }
            lp.add(row, Cmp::Eq, 1.0);
        }
        for j in 0..n {
            let mut row = vec![0.0; nv];
            for i in 0..n {
                row[x(i, j)] = 1.0;
            }
            lp.add(row, Cmp::Eq, 1.0);
        }
        // Eq. 7: m_j = Σ_i model_i x_{i,j}.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            for i in 0..n {
                row[x(i, j)] = model_val[i];
            }
            row[m_of(j)] = -1.0;
            lp.add(row, Cmp::Eq, 0.0);
        }
        // Eq. 9 via big-M: |m_j − m_{j−1}| ≤ M t_j (m_{-1} = active).
        for j in 0..n {
            let mut r1 = vec![0.0; nv];
            let mut r2 = vec![0.0; nv];
            r1[m_of(j)] = 1.0;
            r2[m_of(j)] = -1.0;
            let rhs = if j == 0 { active_val } else { 0.0 };
            if j > 0 {
                r1[m_of(j - 1)] = -1.0;
                r2[m_of(j - 1)] = 1.0;
            }
            r1[t_of(j)] = -big_m;
            r2[t_of(j)] = -big_m;
            lp.add(r1, Cmp::Le, rhs);
            lp.add(r2, Cmp::Le, -rhs);
        }
        // Eq. 10: w_0 = S·t_0; w_j = w_{j−1} + Σ_i svc_i x_{i,j−1} + S·t_j.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            row[w_of(j)] = 1.0;
            row[t_of(j)] = -swap_s;
            if j > 0 {
                row[w_of(j - 1)] = -1.0;
                for i in 0..n {
                    row[x(i, j - 1)] = -svc[i];
                }
            }
            lp.add(row, Cmp::Eq, 0.0);
        }
        // Eq. 11/12 softened: w_j + Σ_i (svc_i − budget_i) x_{i,j} − v_j ≤ 0.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            row[w_of(j)] = 1.0;
            for i in 0..n {
                row[x(i, j)] = svc[i] - budget[i];
            }
            row[v_of(j)] = -1.0;
            lp.add(row, Cmp::Le, 0.0);
        }

        let mut binaries: Vec<usize> = (0..n * n).collect();
        binaries.extend((0..n).map(t_of));
        let mut milp = Milp::new(lp, binaries);
        milp.node_limit = self.cfg.node_limit;
        match milp.solve() {
            MilpResult::Optimal { x: sol, nodes, .. } => {
                let mut perm = vec![0usize; n];
                for j in 0..n {
                    for i in 0..n {
                        if sol[x(i, j)] > 0.5 {
                            perm[j] = i;
                        }
                    }
                }
                Some((perm, nodes))
            }
            MilpResult::Infeasible => None,
        }
    }
}

/// The better-candidate predicate shared by both greedy assignment
/// loops: lower penalty, then earlier completion, then lighter load
/// (1e-9 epsilons throughout). `best` carries (pen, completion, load).
fn candidate_improves(best: Option<(f64, f64, f64)>, pen: f64, completion: f64, load: f64) -> bool {
    match best {
        None => true,
        Some((bp, bc, bl)) => {
            pen < bp - 1e-9
                || ((pen - bp).abs() < 1e-9
                    && (completion < bc - 1e-9
                        || ((completion - bc).abs() < 1e-9 && load < bl)))
        }
    }
}

/// The affinity-EDF sort key: (cluster deadline, non-active-model flag,
/// model id, deadline, group id).
type AffinityKey = (f64, bool, ModelId, f64, GroupId);

/// The one comparator behind both ordering paths — `affinity_order`
/// (full solve, over groups) and `reorder_cached` (delta path, over the
/// pricing table). Keeping it in one place is what guarantees the two
/// paths produce the same plan for the same state.
fn affinity_cmp(a: &AffinityKey, b: &AffinityKey) -> std::cmp::Ordering {
    a.0.partial_cmp(&b.0)
        .unwrap()
        .then(a.1.cmp(&b.1))
        .then(a.2.cmp(&b.2))
        .then(a.3.partial_cmp(&b.3).unwrap())
        .then(a.4.cmp(&b.4))
}

/// Affinity-EDF over cached pricing — driven by the pricing table so
/// the delta path never touches the group table. The pinned executing
/// head, if present, is left in place.
fn reorder_cached(cq: &mut CachedQueue, pricing: &HashMap<GroupId, GroupPricing>) {
    let start =
        usize::from(cq.executing.is_some() && cq.order.first() == cq.executing.as_ref());
    let active = cq.active_model;
    let rest = &mut cq.order[start..];
    let mut cluster_deadline: HashMap<ModelId, f64> = HashMap::new();
    for gid in rest.iter() {
        if let Some(p) = pricing.get(gid) {
            let e = cluster_deadline.entry(p.model).or_insert(f64::INFINITY);
            *e = e.min(p.deadline);
        }
    }
    let key = |gid: &GroupId| -> AffinityKey {
        match pricing.get(gid) {
            Some(p) => (
                cluster_deadline
                    .get(&p.model)
                    .copied()
                    .unwrap_or(f64::INFINITY),
                Some(p.model) != active,
                p.model,
                p.deadline,
                *gid,
            ),
            // Unpriced ids (shouldn't happen) sink to the back, stably.
            None => (f64::INFINITY, true, ModelId(u32::MAX), f64::INFINITY, *gid),
        }
    };
    rest.sort_by(|a, b| affinity_cmp(&key(a), &key(b)));
}

/// Walk a cached order front-to-back, recomputing the queue's tail
/// state (what a greedy append sees) and its penalty from the pricing
/// table alone. Also records the pricing epoch (`priced_at`) and the
/// violating-group count — the slope the delta path uses to re-anchor
/// this queue's penalty to a later `now` in constant time.
fn reprice_queue(
    cq: &mut CachedQueue,
    pricing: &HashMap<GroupId, GroupPricing>,
    v: &InstanceView,
    now: f64,
) {
    let mut tail = QTail {
        wait: 0.0,
        tail_model: v.active_model,
        load: 0.0,
    };
    let mut penalty = 0.0;
    let mut viol = 0u32;
    for gid in &cq.order {
        let Some(p) = pricing.get(gid) else { continue };
        if tail.tail_model != Some(p.model) {
            tail.wait += v.swap_s(p.model);
        }
        tail.tail_model = Some(p.model);
        let pen = (tail.wait + p.svc_s - (p.deadline - now)).max(0.0);
        if pen > 0.0 {
            viol += 1;
        }
        penalty += pen;
        tail.wait += p.svc_s;
        tail.load += p.len as f64;
    }
    cq.tail = tail;
    cq.penalty = penalty;
    cq.priced_at = now;
    cq.viol_groups = viol;
}

/// Split a queue into (pinned executing head, reorderable rest).
fn split_pinned<'a>(
    all: &[&'a RequestGroup],
    executing: Option<GroupId>,
) -> (Vec<&'a RequestGroup>, Vec<&'a RequestGroup>) {
    let mut head = Vec::new();
    let mut rest = Vec::new();
    for &g in all {
        if Some(g.id) == executing {
            head.push(g);
        } else {
            rest.push(g);
        }
    }
    (head, rest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{GpuKind, ModelCatalog};
    use crate::coordinator::rwt::ProfileTable;
    use crate::workload::{SloClass, Trace, WorkloadSpec};
    use std::collections::VecDeque;

    fn estimator() -> RwtEstimator {
        let spec = WorkloadSpec::w_a(ModelId(0), 100.0, 2000);
        let trace = Trace::generate(&spec, 11);
        RwtEstimator::new(ProfileTable::from_trace(&trace))
    }

    fn view(id: u32, models: &[u32], active: Option<u32>) -> InstanceView {
        let catalog = ModelCatalog::paper_multi_model();
        let mut perf_for = HashMap::new();
        let mut swap_time = HashMap::new();
        for &m in models {
            let p = PerfModel::profile(catalog.get(ModelId(m)), GpuKind::A100, 161.0);
            perf_for.insert(ModelId(m), p);
            swap_time.insert(ModelId(m), p.swap_cpu_gpu_s);
        }
        InstanceView {
            id: InstanceId(id),
            active_model: active.map(ModelId),
            perf_for,
            swap_time,
            executing: None,
        }
    }

    fn grp(id: u64, model: u32, n: usize, arrival: f64, slo: f64) -> RequestGroup {
        RequestGroup {
            id: GroupId(id),
            model: ModelId(model),
            class: if slo <= 20.0 {
                SloClass::Interactive
            } else {
                SloClass::Batch1
            },
            slo_s: slo,
            earliest_arrival_s: arrival,
            members: VecDeque::from_iter(0..n as u64),
            mega: false,
        }
    }

    #[test]
    fn affinity_order_groups_same_model_together() {
        let g1 = grp(1, 0, 8, 0.0, 60.0);
        let g2 = grp(2, 1, 8, 1.0, 61.0);
        let g3 = grp(3, 0, 8, 2.0, 62.0);
        let g4 = grp(4, 1, 8, 3.0, 63.0);
        let mut v = vec![&g4, &g3, &g2, &g1];
        GlobalScheduler::affinity_order(&mut v, None);
        let models: Vec<u32> = v.iter().map(|g| g.model.0).collect();
        // Same-model groups contiguous ⇒ exactly one transition.
        let transitions = models.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 1, "order {models:?}");
    }

    #[test]
    fn tight_slo_scheduled_ahead() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let big = grp(1, 0, 200, 0.0, 3600.0);
        let tight = grp(2, 0, 4, 0.0, 20.0);
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&[&big, &tight], &views, 0.0);
        let order = &a.orders[&InstanceId(0)];
        assert_eq!(order[0], GroupId(2), "interactive group must lead");
    }

    #[test]
    fn multi_instance_load_balances() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let groups: Vec<RequestGroup> =
            (0..8).map(|i| grp(i, 0, 64, 0.0, 60.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        let l0 = a.orders[&InstanceId(0)].len();
        let l1 = a.orders[&InstanceId(1)].len();
        assert_eq!(l0 + l1, 8);
        assert!(l0 >= 2 && l1 >= 2, "unbalanced {l0}/{l1}");
    }

    #[test]
    fn respects_model_servability() {
        // Llama-70B (model 2) can only run on instance 1.
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let groups = vec![grp(1, 2, 8, 0.0, 3600.0), grp(2, 0, 8, 0.0, 3600.0)];
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0, 2], None)];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(a.orders[&InstanceId(1)].contains(&GroupId(1)));
        assert!(!a.orders[&InstanceId(0)].contains(&GroupId(1)));
    }

    #[test]
    fn pinned_group_stays_at_head() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let executing = grp(7, 0, 32, 0.0, 3600.0);
        let urgent = grp(8, 0, 4, 0.0, 10.0);
        let mut v = view(0, &[0], Some(0));
        v.executing = Some(GroupId(7));
        let a = sched.schedule(&[&executing, &urgent], &[v], 0.0);
        let order = &a.orders[&InstanceId(0)];
        assert_eq!(order[0], GroupId(7), "executing group pinned");
        assert_eq!(order[1], GroupId(8));
    }

    #[test]
    fn repeated_schedules_reuse_service_memo() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // 8 groups: enough to stay on the greedy path (no MILP) while
        // still exercising the assignment + penalty pricing.
        let groups: Vec<RequestGroup> =
            (0..8).map(|i| grp(i, 0, 32, 0.0, 600.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        let b = sched.schedule(&refs, &views, 0.0);
        assert_eq!(a.orders, b.orders, "identical inputs, identical plan");
        let (hits, misses) = sched.estimator.memo_stats();
        assert!(hits > 0, "second invocation must hit the memo");
        assert!(
            hits >= misses,
            "unchanged groups should mostly hit: {hits} hits / {misses} misses"
        );
    }

    #[test]
    fn milp_orders_by_deadline_single_model() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 4,
                node_limit: 50_000,
                ..Default::default()
            },
            estimator(),
        );
        let g1 = grp(1, 0, 16, 0.0, 3600.0);
        let g2 = grp(2, 0, 16, 0.0, 30.0);
        let g3 = grp(3, 0, 16, 0.0, 600.0);
        let v = view(0, &[0], Some(0));
        let refs = vec![&g1, &g2, &g3];
        let (perm, _) = sched.milp_order(&refs, &v, 0.0).unwrap();
        // Tightest (g2) first.
        assert_eq!(perm[0], 1, "perm {perm:?}");
    }

    #[test]
    fn milp_avoids_needless_swaps() {
        // Two models, relaxed SLOs: optimal order clusters by model
        // (1 swap), not interleaved (3 swaps).
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 4,
                node_limit: 50_000,
                ..Default::default()
            },
            estimator(),
        );
        let g1 = grp(1, 0, 16, 0.0, 7200.0);
        let g2 = grp(2, 3, 16, 0.0, 7200.0);
        let g3 = grp(3, 0, 16, 0.0, 7200.0);
        let g4 = grp(4, 3, 16, 0.0, 7200.0);
        let v = view(0, &[0, 3], Some(0));
        let refs = vec![&g1, &g2, &g3, &g4];
        let (perm, _) = sched.milp_order(&refs, &v, 0.0).unwrap();
        let models: Vec<u32> = perm.iter().map(|&i| refs[i].model.0).collect();
        let transitions = models.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 1, "models {models:?}");
    }

    #[test]
    fn infeasible_flagged_when_capacity_exceeded() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // Enormous backlog with tiny SLOs.
        let groups: Vec<RequestGroup> =
            (0..20).map(|i| grp(i, 0, 256, 0.0, 5.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(!a.feasible);
        assert!(a.total_penalty_s > 0.0);
    }

    #[test]
    fn affinity_order_active_model_cluster_leads_on_deadline_tie() {
        // Regression: the active-model preference used to sit *after*
        // the raw model-id tie-break, making it unreachable — deadline-
        // tied clusters ordered by model id and swapped needlessly.
        let g1 = grp(1, 0, 8, 0.0, 60.0);
        let g2 = grp(2, 1, 8, 0.0, 60.0); // same cluster deadline as model 0
        let g3 = grp(3, 0, 8, 0.0, 60.0);
        let g4 = grp(4, 1, 8, 0.0, 60.0);
        let mut v = vec![&g1, &g2, &g3, &g4];
        GlobalScheduler::affinity_order(&mut v, Some(ModelId(1)));
        let models: Vec<u32> = v.iter().map(|g| g.model.0).collect();
        assert_eq!(
            models,
            vec![1, 1, 0, 0],
            "active model-1 cluster must lead on a deadline tie"
        );
    }

    #[test]
    fn unservable_group_reported_with_finite_penalty() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // Model 2 (Llama-70B) is not servable by the only instance.
        let lost = grp(1, 2, 8, 0.0, 60.0);
        let ok = grp(2, 0, 8, 0.0, 3600.0);
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&[&lost, &ok], &views, 0.0);
        assert!(
            a.total_penalty_s.is_finite(),
            "unservable group must not poison the penalty signal"
        );
        assert!(a.total_penalty_s >= UNSERVABLE_PENALTY_S);
        assert!(!a.feasible);
        assert_eq!(a.unservable, vec![GroupId(1)]);
        assert!(
            !a.orders[&InstanceId(0)].contains(&GroupId(1)),
            "unservable group must not be parked on a queue"
        );
        assert!(a.orders[&InstanceId(0)].contains(&GroupId(2)));
    }

    #[test]
    fn exact_milp_honored_beyond_milp_max_groups() {
        // Regression: ExactMilp used to silently fall back to the
        // heuristic when a queue exceeded `milp_max_groups`.
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 2,
                node_limit: 50_000,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<RequestGroup> =
            (0..4).map(|i| grp(i, 0, 16, 0.0, 600.0 + i as f64)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(
            a.stats.used_milp,
            "ExactMilp must refine queues larger than milp_max_groups"
        );
    }

    /// Deterministic Fisher–Yates driven by a splitmix-style LCG.
    fn lcg_shuffle<T>(v: &mut [T], seed: &mut u64) {
        for i in (1..v.len()).rev() {
            *seed = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = ((*seed >> 33) as usize) % (i + 1);
            v.swap(i, j);
        }
    }

    #[test]
    fn schedule_invariant_to_group_slice_order() {
        // Property: the plan is a function of the group *set*, not the
        // iteration order of the slice handed in (which comes from a
        // HashMap in the engine).
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<RequestGroup> = (0..24)
            .map(|i| {
                let slo = 30.0 + (i % 7) as f64 * 200.0;
                grp(i, (i % 2) as u32 * 3, 16 + (i % 5) as usize, i as f64, slo)
            })
            .collect();
        let views = vec![
            view(0, &[0, 3], Some(0)),
            view(1, &[0, 3], Some(3)),
            view(2, &[0], None),
        ];
        let base_refs: Vec<&RequestGroup> = groups.iter().collect();
        let base = sched.schedule(&base_refs, &views, 0.0);
        let mut seed = 0xC0FFEE_u64;
        for _ in 0..5 {
            let mut refs = base_refs.clone();
            lcg_shuffle(&mut refs, &mut seed);
            let a = sched.schedule(&refs, &views, 0.0);
            assert_eq!(a.orders, base.orders, "plan depends on slice order");
            assert!((a.total_penalty_s - base.total_penalty_s).abs() < 1e-9);
        }
    }

    #[test]
    fn delta_without_cache_falls_back_to_full() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let views = vec![view(0, &[0], Some(0))];
        let d = SchedDelta::default();
        assert!(sched.try_schedule_delta(&d, &views, 0.0).is_none());
    }

    #[test]
    fn delta_with_empty_dirty_set_changes_nothing() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<RequestGroup> =
            (0..8).map(|i| grp(i, 0, 32, 0.0, 60.0 + i as f64)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let full = sched.schedule(&refs, &views, 0.0);
        let d = SchedDelta {
            total_groups: groups.len(),
            ..Default::default()
        };
        let a = sched
            .try_schedule_delta(&d, &views, 0.0)
            .expect("cache is warm");
        assert!(a.stats.incremental);
        assert!(
            a.orders.is_empty(),
            "identical inputs must produce an empty patch"
        );
        assert_eq!(
            sched.cached_orders().unwrap(),
            full.orders,
            "cached plan must still equal the full solve"
        );
    }

    #[test]
    fn delta_inserts_new_group_like_a_full_solve() {
        let mk_sched = || {
            GlobalScheduler::new(
                SchedulerConfig {
                    solver: SolverKind::Greedy,
                    ..Default::default()
                },
                estimator(),
            )
        };
        let mut groups: Vec<RequestGroup> =
            (0..6).map(|i| grp(i, 0, 32, 0.0, 100.0 + 50.0 * i as f64)).collect();
        let views = vec![view(0, &[0], Some(0))];
        // Warm the incremental scheduler on the first 6 groups, then
        // deliver group 6 via the delta path.
        let inc = mk_sched();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        inc.schedule(&refs, &views, 0.0);
        groups.push(grp(6, 0, 32, 0.0, 900.0));
        let d = SchedDelta {
            dirty: vec![groups.last().unwrap()],
            removed: vec![],
            total_groups: groups.len(),
        };
        let a = inc.try_schedule_delta(&d, &views, 0.0).expect("warm cache");
        assert!(a.stats.incremental);
        assert_eq!(a.stats.dirty, 1);
        // A fresh full solve over all 7 groups lands on the same plan.
        let full = mk_sched();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let b = full.schedule(&refs, &views, 0.0);
        assert_eq!(inc.cached_orders().unwrap(), b.orders);
    }

    #[test]
    fn delta_invariant_to_dirty_iteration_order() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                incremental_dirty_frac: 1.0,
                ..Default::default()
            },
            estimator(),
        );
        let base: Vec<RequestGroup> =
            (0..10).map(|i| grp(i, 0, 32, 0.0, 60.0 + 10.0 * i as f64)).collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let fresh: Vec<RequestGroup> = (10..14)
            .map(|i| grp(i, 0, 32, 0.0, 45.0 + 5.0 * i as f64))
            .collect();
        let run = |dirty: Vec<&RequestGroup>| {
            let refs: Vec<&RequestGroup> = base.iter().collect();
            sched.schedule(&refs, &views, 0.0);
            let d = SchedDelta {
                dirty,
                removed: vec![],
                total_groups: base.len() + fresh.len(),
            };
            sched.try_schedule_delta(&d, &views, 0.0).expect("warm");
            sched.cached_orders().unwrap()
        };
        let fwd = run(fresh.iter().collect());
        let rev = run(fresh.iter().rev().collect());
        assert_eq!(fwd, rev, "delta plan depends on dirty iteration order");
    }

    #[test]
    fn delta_removed_group_leaves_its_queue() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<RequestGroup> =
            (0..6).map(|i| grp(i, 0, 32, 0.0, 60.0 + i as f64)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        sched.schedule(&refs, &views, 0.0);
        let d = SchedDelta {
            dirty: vec![],
            removed: vec![GroupId(3)],
            total_groups: 5,
        };
        let a = sched.try_schedule_delta(&d, &views, 0.0).expect("warm");
        let order = &a.orders[&InstanceId(0)];
        assert!(!order.contains(&GroupId(3)));
        assert_eq!(order.len(), 5);
    }

    #[test]
    fn delta_dirtiness_beyond_threshold_forces_full_solve() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                incremental_dirty_frac: 0.25,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<RequestGroup> =
            (0..8).map(|i| grp(i, 0, 32, 0.0, 60.0 + i as f64)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        sched.schedule(&refs, &views, 0.0);
        let d = SchedDelta {
            dirty: groups.iter().take(4).collect(),
            removed: vec![],
            total_groups: groups.len(),
        };
        assert!(
            sched.try_schedule_delta(&d, &views, 0.0).is_none(),
            "4/8 dirty exceeds the 25% threshold"
        );
    }

    #[test]
    fn delta_reanchors_untouched_queue_penalties() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        // Every group violating at t=0: 256-member groups, 5 s SLOs —
        // each violating group's penalty grows one second per second.
        let groups: Vec<RequestGroup> = (0..8).map(|i| grp(i, 0, 256, 0.0, 5.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let full = sched.schedule(&refs, &views, 0.0);
        assert!(full.total_penalty_s > 0.0);
        let d = SchedDelta {
            total_groups: groups.len(),
            ..Default::default()
        };
        // An empty delta 10 s later must re-anchor the untouched queues:
        // 8 violating groups × 10 s of extra lateness.
        let a = sched.try_schedule_delta(&d, &views, 10.0).expect("warm");
        assert!(
            (a.total_penalty_s - (full.total_penalty_s + 80.0)).abs() < 1e-6,
            "expected {} + 80, got {}",
            full.total_penalty_s,
            a.total_penalty_s
        );
        // A second pass advances from the new anchor, not from t=0.
        let b = sched.try_schedule_delta(&d, &views, 15.0).expect("warm");
        assert!(
            (b.total_penalty_s - (a.total_penalty_s + 40.0)).abs() < 1e-6,
            "expected {} + 40, got {}",
            a.total_penalty_s,
            b.total_penalty_s
        );
    }

    #[test]
    fn parallel_repricing_is_bit_identical_to_serial() {
        let mk = |threads: usize| {
            GlobalScheduler::new(
                SchedulerConfig {
                    solver: SolverKind::Greedy,
                    threads,
                    ..Default::default()
                },
                estimator(),
            )
        };
        let groups: Vec<RequestGroup> = (0..48)
            .map(|i| {
                let slo = 30.0 + (i % 7) as f64 * 150.0;
                grp(i, (i % 2) as u32 * 3, 16 + (i % 5) as usize, i as f64 * 0.1, slo)
            })
            .collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views: Vec<InstanceView> = (0..8).map(|i| view(i, &[0, 3], Some(0))).collect();
        let serial = mk(1).schedule(&refs, &views, 3.0);
        let par = mk(4).schedule(&refs, &views, 3.0);
        assert_eq!(serial.orders, par.orders, "plan must not depend on threads");
        assert_eq!(
            serial.total_penalty_s.to_bits(),
            par.total_penalty_s.to_bits(),
            "penalty must be bit-identical across thread counts"
        );
    }

    #[test]
    fn delta_instance_set_change_forces_full_solve() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            estimator(),
        );
        let groups: Vec<RequestGroup> =
            (0..4).map(|i| grp(i, 0, 32, 0.0, 60.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        sched.schedule(&refs, &views, 0.0);
        // Instance 1 failed: the survivor-only view set must not patch.
        let survivors = vec![view(0, &[0], Some(0))];
        let d = SchedDelta {
            total_groups: groups.len(),
            ..Default::default()
        };
        assert!(sched.try_schedule_delta(&d, &survivors, 0.0).is_none());
    }
}
