//! The Global Scheduler (§7): assigns request groups to virtual queues
//! and orders them to maximize SLO attainment, given RWT estimates.
//!
//! Two solver paths:
//!
//! * **Exact MILP** — the paper's formulation (Eqs. 6–13): binary
//!   assignment x_{i,j} of groups to queue positions, model values m_j
//!   (Eq. 7), big-M switch indicators t_j (Eq. 9), accumulated waiting
//!   times wt_j (Eq. 10), and penalties p_j = wt_j − slo_j (Eq. 11),
//!   minimizing total violation (Eq. 13). SLO satisfaction (Eq. 12) is
//!   soft-constrained through violation variables v_j ≥ p_j so the solver
//!   still returns the least-bad ordering when demand exceeds capacity
//!   (the paper falls back to EDF/scale-up in that regime, §9).
//!   The model-dependent swap time in Eq. 10's product term is
//!   conservatively uniformized to max_i S_i to stay linear (the exact
//!   product would need n² extra binaries).
//!
//! * **Greedy heuristic** — deadline-ordered assignment with model
//!   affinity, linear in groups; this is what scales to the 400K-request
//!   queues of Fig. 20 and is the default for large instances (Design
//!   Principle #1).

use std::collections::HashMap;

use crate::backend::{InstanceId, ModelId, PerfModel};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::rwt::RwtEstimator;
use crate::solver::{Cmp, Lp, Milp, MilpResult};

/// Scheduler's view of one serving instance.
#[derive(Debug, Clone)]
pub struct InstanceView {
    pub id: InstanceId,
    pub active_model: Option<ModelId>,
    /// Profiled perf per servable model (absent ⇒ model can't run here,
    /// e.g. Llama-70B on an A10 — hardware heterogeneity, §8.3).
    pub perf_for: HashMap<ModelId, PerfModel>,
    /// Swap-in latency per model from its current tier.
    pub swap_time: HashMap<ModelId, f64>,
    /// Group currently executing — pinned (no preemptive migration, §5).
    pub executing: Option<GroupId>,
}

impl InstanceView {
    pub fn can_serve(&self, m: ModelId) -> bool {
        self.perf_for.contains_key(&m)
    }

    fn swap_s(&self, m: ModelId) -> f64 {
        self.swap_time.get(&m).copied().unwrap_or(0.0)
    }
}

/// Which solver the global scheduler uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Greedy,
    /// Exact per-queue MILP refinement after greedy assignment.
    ExactMilp,
    /// Greedy, with MILP refinement only for queues small enough.
    Auto,
}

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    pub solver: SolverKind,
    /// Max groups per queue for the exact MILP path.
    pub milp_max_groups: usize,
    pub node_limit: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            solver: SolverKind::Auto,
            milp_max_groups: 6,
            node_limit: 20_000,
        }
    }
}

/// Solve statistics for overhead studies (Fig. 20).
#[derive(Debug, Clone, Copy, Default)]
pub struct SolveStats {
    pub groups: usize,
    pub milp_nodes: usize,
    pub used_milp: bool,
}

/// Scheduler output: per-instance virtual-queue orderings.
#[derive(Debug, Clone)]
pub struct Assignment {
    pub orders: HashMap<InstanceId, Vec<GroupId>>,
    /// True iff every group's estimated completion meets its SLO.
    pub feasible: bool,
    /// Σ max(0, estimated completion − budget) across groups, seconds.
    pub total_penalty_s: f64,
    pub stats: SolveStats,
}

/// The global scheduler.
#[derive(Debug, Clone)]
pub struct GlobalScheduler {
    pub cfg: SchedulerConfig,
    pub estimator: RwtEstimator,
}

impl GlobalScheduler {
    pub fn new(cfg: SchedulerConfig, estimator: RwtEstimator) -> Self {
        GlobalScheduler { cfg, estimator }
    }

    /// Penalty of an ordering on one instance: Σ max(0, completion − budget).
    pub fn queue_penalty(&self, order: &[&RequestGroup], view: &InstanceView, now: f64) -> f64 {
        if order.is_empty() {
            return 0.0;
        }
        // Perf is per-model; use the head group's model for Θ (groups on
        // one queue in one walk segment share the instance's device).
        let Some(perf) = view.perf_for.get(&order[0].model) else {
            return f64::INFINITY;
        };
        let est = self.estimator.estimate_queue(
            order,
            perf,
            view.active_model,
            |m| view.swap_s(m),
        );
        order
            .iter()
            .zip(&est)
            .map(|(g, e)| (e.completion_mean_s - (g.deadline() - now)).max(0.0))
            .sum()
    }

    /// Model-affinity EDF ordering of one queue's groups: cluster by
    /// model, order clusters by earliest deadline, EDF within cluster —
    /// the Fig. 5 "Oracle" structure that avoids swap thrashing.
    pub fn affinity_order(groups: &mut [&RequestGroup], active: Option<ModelId>) {
        // Cluster key: model; cluster deadline: min member deadline.
        let mut cluster_deadline: HashMap<ModelId, f64> = HashMap::new();
        for g in groups.iter() {
            let e = cluster_deadline.entry(g.model).or_insert(f64::INFINITY);
            *e = e.min(g.deadline());
        }
        groups.sort_by(|a, b| {
            let ca = cluster_deadline[&a.model];
            let cb = cluster_deadline[&b.model];
            // Active-model cluster first on deadline ties (swap-free).
            let aa = (Some(a.model) != active) as u8;
            let ab = (Some(b.model) != active) as u8;
            ca.partial_cmp(&cb)
                .unwrap()
                .then(a.model.cmp(&b.model))
                .then(aa.cmp(&ab))
                .then(a.deadline().partial_cmp(&b.deadline()).unwrap())
                .then(a.id.cmp(&b.id))
        });
    }

    /// Main entry: assign + order all schedulable groups.
    ///
    /// Takes group *references* so callers holding groups in a table
    /// (the simulator's live group map) schedule without deep-cloning
    /// every member list per invocation (§Perf).
    pub fn schedule(
        &self,
        groups: &[&RequestGroup],
        instances: &[InstanceView],
        now: f64,
    ) -> Assignment {
        // One scheduler invocation = one memo epoch for service pricing.
        self.estimator.begin_epoch();
        let by_id: HashMap<GroupId, &RequestGroup> =
            groups.iter().map(|g| (g.id, *g)).collect();
        let mut orders: HashMap<InstanceId, Vec<GroupId>> = HashMap::new();
        let mut stats = SolveStats {
            groups: groups.len(),
            ..Default::default()
        };

        // 1. Pin executing groups to their instances' heads.
        let mut pinned: HashMap<GroupId, InstanceId> = HashMap::new();
        for v in instances {
            let order = orders.entry(v.id).or_default();
            if let Some(g) = v.executing {
                if by_id.contains_key(&g) {
                    order.push(g);
                    pinned.insert(g, v.id);
                }
            }
        }

        // 2. Deadline-ordered greedy assignment of the rest.
        let mut todo: Vec<&RequestGroup> = groups
            .iter()
            .copied()
            .filter(|g| !pinned.contains_key(&g.id))
            .collect();
        todo.sort_by(|a, b| {
            a.deadline()
                .partial_cmp(&b.deadline())
                .unwrap()
                .then(a.id.cmp(&b.id))
        });

        // §Perf: incremental O(G·V) assignment — each candidate append is
        // priced from cached per-queue state (accumulated wait, tail
        // model) instead of re-walking the whole queue (which made the
        // assignment quadratic in groups; see EXPERIMENTS.md §Perf).
        #[derive(Clone, Copy)]
        struct QState {
            wait: f64,
            tail_model: Option<ModelId>,
            load: f64,
        }
        let mut qstate: HashMap<InstanceId, QState> = instances
            .iter()
            .map(|v| {
                let mut st = QState {
                    wait: 0.0,
                    tail_model: v.active_model,
                    load: 0.0,
                };
                // Seed with the pinned executing group, if any.
                if let Some(gid) = v.executing {
                    if let Some(g) = by_id.get(&gid) {
                        if let Some(perf) = v.perf_for.get(&g.model) {
                            let (svc, _) = self.estimator.group_service(g, perf);
                            st.wait += svc + perf.prefill_s;
                            st.tail_model = Some(g.model);
                            st.load += g.len() as f64;
                        }
                    }
                }
                (v.id, st)
            })
            .collect();

        for g in todo {
            let mut best: Option<(InstanceId, f64, f64, f64)> = None; // (id, pen, completion, load)
            for v in instances {
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                let st = qstate[&v.id];
                let swap = if st.tail_model != Some(g.model) {
                    v.swap_s(g.model)
                } else {
                    0.0
                };
                let (svc, _) = self.estimator.group_service(g, perf);
                let completion = st.wait + swap + perf.prefill_s + svc;
                let pen = (completion - (g.deadline() - now)).max(0.0);
                let better = match &best {
                    None => true,
                    Some((_, bp, bc, bl)) => {
                        pen < bp - 1e-9
                            || ((pen - bp).abs() < 1e-9
                                && (completion < bc - 1e-9
                                    || ((completion - bc).abs() < 1e-9 && st.load < *bl)))
                    }
                };
                if better {
                    best = Some((v.id, pen, completion, st.load));
                }
            }
            match best {
                Some((id, _, completion, _)) => {
                    orders.get_mut(&id).unwrap().push(g.id);
                    let st = qstate.get_mut(&id).unwrap();
                    st.wait = completion;
                    st.tail_model = Some(g.model);
                    st.load += g.len() as f64;
                }
                None => {
                    if let Some(v0) = instances.first() {
                        // No instance can serve this model (misconfigured
                        // fleet): park it; it will surface as penalty.
                        orders.get_mut(&v0.id).unwrap().push(g.id);
                    }
                }
            }
        }

        // 3. Per-queue ordering: affinity-EDF, optionally MILP-refined.
        let mut total_penalty = 0.0;
        for v in instances {
            let ids = orders.get_mut(&v.id).unwrap();
            let all: Vec<&RequestGroup> =
                ids.iter().filter_map(|id| by_id.get(id).copied()).collect();
            let (head, mut rest) = split_pinned(&all, v.executing);
            Self::affinity_order(&mut rest, v.active_model);

            let use_milp = match self.cfg.solver {
                SolverKind::Greedy => false,
                SolverKind::ExactMilp => true,
                SolverKind::Auto => rest.len() <= self.cfg.milp_max_groups,
            } && rest.len() >= 2
                && rest.len() <= self.cfg.milp_max_groups;

            if use_milp {
                if let Some((order, nodes)) = self.milp_order(&rest, v, now) {
                    stats.milp_nodes += nodes;
                    stats.used_milp = true;
                    // Accept MILP order only if it doesn't regress the
                    // heuristic (node-limit exhaustion can truncate search).
                    let full_h: Vec<&RequestGroup> =
                        head.iter().copied().chain(rest.iter().copied()).collect();
                    let full_m: Vec<&RequestGroup> = head
                        .iter()
                        .copied()
                        .chain(order.iter().map(|&i| rest[i]))
                        .collect();
                    if self.queue_penalty(&full_m, v, now)
                        <= self.queue_penalty(&full_h, v, now) + 1e-9
                    {
                        rest = full_m[head.len()..].to_vec();
                    }
                }
            }

            let full: Vec<&RequestGroup> =
                head.into_iter().chain(rest.into_iter()).collect();
            total_penalty += self.queue_penalty(&full, v, now);
            *ids = full.iter().map(|g| g.id).collect();
        }

        Assignment {
            feasible: total_penalty <= 1e-9,
            total_penalty_s: total_penalty,
            orders,
            stats,
        }
    }

    /// Exact ordering of `groups` on instance `v` via the §7 MILP.
    /// Returns the permutation (indices into `groups`) and node count.
    pub fn milp_order(
        &self,
        groups: &[&RequestGroup],
        v: &InstanceView,
        now: f64,
    ) -> Option<(Vec<usize>, usize)> {
        let n = groups.len();
        if n == 0 {
            return Some((Vec::new(), 0));
        }
        let perf = v.perf_for.get(&groups[0].model)?;
        // Per-group constants.
        let svc: Vec<f64> = groups
            .iter()
            .map(|g| {
                let (m, _) = self.estimator.group_service(g, perf);
                m + perf.prefill_s
            })
            .collect();
        let budget: Vec<f64> = groups.iter().map(|g| g.deadline() - now).collect();
        let model_val: Vec<f64> = groups.iter().map(|g| g.model.0 as f64 + 1.0).collect();
        let active_val = v.active_model.map(|m| m.0 as f64 + 1.0).unwrap_or(0.0);
        let swap_s = groups
            .iter()
            .map(|g| v.swap_s(g.model))
            .fold(0.0_f64, f64::max); // uniformized S (see module docs)
        let big_m = model_val.iter().fold(active_val, |a, &b| a.max(b)) + 2.0;

        // Variable layout.
        let x = |i: usize, j: usize| i * n + j;
        let m_of = |j: usize| n * n + j;
        let t_of = |j: usize| n * n + n + j;
        let w_of = |j: usize| n * n + 2 * n + j;
        let v_of = |j: usize| n * n + 3 * n + j;
        let nv = n * n + 4 * n;

        let mut lp = Lp::new(nv);
        // Objective (Eq. 13): minimize Σ v_j + tiny swap regularizer.
        let mut obj = vec![0.0; nv];
        for j in 0..n {
            obj[v_of(j)] = -1.0;
            obj[t_of(j)] = -0.001 * swap_s.max(1e-3);
        }
        // Tie-break: when several orderings are penalty-free, prefer
        // placing larger-budget groups later (EDF within feasibility).
        let max_budget = budget.iter().cloned().fold(1.0_f64, f64::max).max(1.0);
        for i in 0..n {
            for j in 0..n {
                obj[x(i, j)] = 1e-5 * (budget[i] / max_budget) * j as f64 / n as f64;
            }
        }
        lp.set_objective(obj);

        // Eq. 6: assignment bijection.
        for i in 0..n {
            let mut row = vec![0.0; nv];
            for j in 0..n {
                row[x(i, j)] = 1.0;
            }
            lp.add(row, Cmp::Eq, 1.0);
        }
        for j in 0..n {
            let mut row = vec![0.0; nv];
            for i in 0..n {
                row[x(i, j)] = 1.0;
            }
            lp.add(row, Cmp::Eq, 1.0);
        }
        // Eq. 7: m_j = Σ_i model_i x_{i,j}.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            for i in 0..n {
                row[x(i, j)] = model_val[i];
            }
            row[m_of(j)] = -1.0;
            lp.add(row, Cmp::Eq, 0.0);
        }
        // Eq. 9 via big-M: |m_j − m_{j−1}| ≤ M t_j (m_{-1} = active).
        for j in 0..n {
            let mut r1 = vec![0.0; nv];
            let mut r2 = vec![0.0; nv];
            r1[m_of(j)] = 1.0;
            r2[m_of(j)] = -1.0;
            let rhs = if j == 0 { active_val } else { 0.0 };
            if j > 0 {
                r1[m_of(j - 1)] = -1.0;
                r2[m_of(j - 1)] = 1.0;
            }
            r1[t_of(j)] = -big_m;
            r2[t_of(j)] = -big_m;
            lp.add(r1, Cmp::Le, rhs);
            lp.add(r2, Cmp::Le, -rhs);
        }
        // Eq. 10: w_0 = S·t_0; w_j = w_{j−1} + Σ_i svc_i x_{i,j−1} + S·t_j.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            row[w_of(j)] = 1.0;
            row[t_of(j)] = -swap_s;
            if j > 0 {
                row[w_of(j - 1)] = -1.0;
                for i in 0..n {
                    row[x(i, j - 1)] = -svc[i];
                }
            }
            lp.add(row, Cmp::Eq, 0.0);
        }
        // Eq. 11/12 softened: w_j + Σ_i (svc_i − budget_i) x_{i,j} − v_j ≤ 0.
        for j in 0..n {
            let mut row = vec![0.0; nv];
            row[w_of(j)] = 1.0;
            for i in 0..n {
                row[x(i, j)] = svc[i] - budget[i];
            }
            row[v_of(j)] = -1.0;
            lp.add(row, Cmp::Le, 0.0);
        }

        let mut binaries: Vec<usize> = (0..n * n).collect();
        binaries.extend((0..n).map(t_of));
        let mut milp = Milp::new(lp, binaries);
        milp.node_limit = self.cfg.node_limit;
        match milp.solve() {
            MilpResult::Optimal { x: sol, nodes, .. } => {
                let mut perm = vec![0usize; n];
                for j in 0..n {
                    for i in 0..n {
                        if sol[x(i, j)] > 0.5 {
                            perm[j] = i;
                        }
                    }
                }
                Some((perm, nodes))
            }
            MilpResult::Infeasible => None,
        }
    }
}

/// Split a queue into (pinned executing head, reorderable rest).
fn split_pinned<'a>(
    all: &[&'a RequestGroup],
    executing: Option<GroupId>,
) -> (Vec<&'a RequestGroup>, Vec<&'a RequestGroup>) {
    let mut head = Vec::new();
    let mut rest = Vec::new();
    for &g in all {
        if Some(g.id) == executing {
            head.push(g);
        } else {
            rest.push(g);
        }
    }
    (head, rest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{GpuKind, ModelCatalog};
    use crate::coordinator::rwt::ProfileTable;
    use crate::workload::{SloClass, Trace, WorkloadSpec};
    use std::collections::VecDeque;

    fn estimator() -> RwtEstimator {
        let spec = WorkloadSpec::w_a(ModelId(0), 100.0, 2000);
        let trace = Trace::generate(&spec, 11);
        RwtEstimator::new(ProfileTable::from_trace(&trace))
    }

    fn view(id: u32, models: &[u32], active: Option<u32>) -> InstanceView {
        let catalog = ModelCatalog::paper_multi_model();
        let mut perf_for = HashMap::new();
        let mut swap_time = HashMap::new();
        for &m in models {
            let p = PerfModel::profile(catalog.get(ModelId(m)), GpuKind::A100, 161.0);
            perf_for.insert(ModelId(m), p);
            swap_time.insert(ModelId(m), p.swap_cpu_gpu_s);
        }
        InstanceView {
            id: InstanceId(id),
            active_model: active.map(ModelId),
            perf_for,
            swap_time,
            executing: None,
        }
    }

    fn grp(id: u64, model: u32, n: usize, arrival: f64, slo: f64) -> RequestGroup {
        RequestGroup {
            id: GroupId(id),
            model: ModelId(model),
            class: if slo <= 20.0 {
                SloClass::Interactive
            } else {
                SloClass::Batch1
            },
            slo_s: slo,
            earliest_arrival_s: arrival,
            members: VecDeque::from_iter(0..n as u64),
            mega: false,
        }
    }

    #[test]
    fn affinity_order_groups_same_model_together() {
        let g1 = grp(1, 0, 8, 0.0, 60.0);
        let g2 = grp(2, 1, 8, 1.0, 61.0);
        let g3 = grp(3, 0, 8, 2.0, 62.0);
        let g4 = grp(4, 1, 8, 3.0, 63.0);
        let mut v = vec![&g4, &g3, &g2, &g1];
        GlobalScheduler::affinity_order(&mut v, None);
        let models: Vec<u32> = v.iter().map(|g| g.model.0).collect();
        // Same-model groups contiguous ⇒ exactly one transition.
        let transitions = models.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 1, "order {models:?}");
    }

    #[test]
    fn tight_slo_scheduled_ahead() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let big = grp(1, 0, 200, 0.0, 3600.0);
        let tight = grp(2, 0, 4, 0.0, 20.0);
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&[&big, &tight], &views, 0.0);
        let order = &a.orders[&InstanceId(0)];
        assert_eq!(order[0], GroupId(2), "interactive group must lead");
    }

    #[test]
    fn multi_instance_load_balances() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let groups: Vec<RequestGroup> =
            (0..8).map(|i| grp(i, 0, 64, 0.0, 60.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        let l0 = a.orders[&InstanceId(0)].len();
        let l1 = a.orders[&InstanceId(1)].len();
        assert_eq!(l0 + l1, 8);
        assert!(l0 >= 2 && l1 >= 2, "unbalanced {l0}/{l1}");
    }

    #[test]
    fn respects_model_servability() {
        // Llama-70B (model 2) can only run on instance 1.
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let groups = vec![grp(1, 2, 8, 0.0, 3600.0), grp(2, 0, 8, 0.0, 3600.0)];
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0)), view(1, &[0, 2], None)];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(a.orders[&InstanceId(1)].contains(&GroupId(1)));
        assert!(!a.orders[&InstanceId(0)].contains(&GroupId(1)));
    }

    #[test]
    fn pinned_group_stays_at_head() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        let executing = grp(7, 0, 32, 0.0, 3600.0);
        let urgent = grp(8, 0, 4, 0.0, 10.0);
        let mut v = view(0, &[0], Some(0));
        v.executing = Some(GroupId(7));
        let a = sched.schedule(&[&executing, &urgent], &[v], 0.0);
        let order = &a.orders[&InstanceId(0)];
        assert_eq!(order[0], GroupId(7), "executing group pinned");
        assert_eq!(order[1], GroupId(8));
    }

    #[test]
    fn repeated_schedules_reuse_service_memo() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // 8 groups: enough to stay on the greedy path (no MILP) while
        // still exercising the assignment + penalty pricing.
        let groups: Vec<RequestGroup> =
            (0..8).map(|i| grp(i, 0, 32, 0.0, 600.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        let b = sched.schedule(&refs, &views, 0.0);
        assert_eq!(a.orders, b.orders, "identical inputs, identical plan");
        let (hits, misses) = sched.estimator.memo_stats();
        assert!(hits > 0, "second invocation must hit the memo");
        assert!(
            hits >= misses,
            "unchanged groups should mostly hit: {hits} hits / {misses} misses"
        );
    }

    #[test]
    fn milp_orders_by_deadline_single_model() {
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 4,
                node_limit: 50_000,
            },
            estimator(),
        );
        let g1 = grp(1, 0, 16, 0.0, 3600.0);
        let g2 = grp(2, 0, 16, 0.0, 30.0);
        let g3 = grp(3, 0, 16, 0.0, 600.0);
        let v = view(0, &[0], Some(0));
        let refs = vec![&g1, &g2, &g3];
        let (perm, _) = sched.milp_order(&refs, &v, 0.0).unwrap();
        // Tightest (g2) first.
        assert_eq!(perm[0], 1, "perm {perm:?}");
    }

    #[test]
    fn milp_avoids_needless_swaps() {
        // Two models, relaxed SLOs: optimal order clusters by model
        // (1 swap), not interleaved (3 swaps).
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::ExactMilp,
                milp_max_groups: 4,
                node_limit: 50_000,
            },
            estimator(),
        );
        let g1 = grp(1, 0, 16, 0.0, 7200.0);
        let g2 = grp(2, 3, 16, 0.0, 7200.0);
        let g3 = grp(3, 0, 16, 0.0, 7200.0);
        let g4 = grp(4, 3, 16, 0.0, 7200.0);
        let v = view(0, &[0, 3], Some(0));
        let refs = vec![&g1, &g2, &g3, &g4];
        let (perm, _) = sched.milp_order(&refs, &v, 0.0).unwrap();
        let models: Vec<u32> = perm.iter().map(|&i| refs[i].model.0).collect();
        let transitions = models.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 1, "models {models:?}");
    }

    #[test]
    fn infeasible_flagged_when_capacity_exceeded() {
        let sched = GlobalScheduler::new(SchedulerConfig::default(), estimator());
        // Enormous backlog with tiny SLOs.
        let groups: Vec<RequestGroup> =
            (0..20).map(|i| grp(i, 0, 256, 0.0, 5.0)).collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let views = vec![view(0, &[0], Some(0))];
        let a = sched.schedule(&refs, &views, 0.0);
        assert!(!a.feasible);
        assert!(a.total_penalty_s > 0.0);
    }
}
