//! Small self-contained utilities: deterministic RNG, statistics,
//! histograms, and linear algebra helpers used across the crate.
//!
//! We implement our own RNG/stat substrate (rather than pulling `rand` /
//! `statrs`) so that every simulation in the paper-reproduction harness is
//! bit-reproducible from a seed across platforms.

pub mod rng;
pub mod stats;
pub mod histogram;
pub mod kmeans;
pub mod par;
pub mod pool;

pub use par::par_chunks_mut;
pub use pool::WorkerPool;
pub use rng::Rng;
pub use stats::{linear_fit, mean, percentile, r_squared, stddev, variance, OnlineStats};
pub use histogram::Histogram;
