//! Fixed-bin latency histogram with exact-percentile support via a bounded
//! reservoir — used for TTFT distributions in metrics and Fig. 8 (token
//! distribution plots).

/// Linear-bin histogram over [0, max) plus an overflow bin.
#[derive(Debug, Clone)]
pub struct Histogram {
    bins: Vec<u64>,
    bin_width: f64,
    max: f64,
    count: u64,
    sum: f64,
}

impl Histogram {
    /// `n_bins` linear bins covering [0, max); values >= max land in the
    /// final overflow bin.
    pub fn new(max: f64, n_bins: usize) -> Self {
        assert!(max > 0.0 && n_bins > 0);
        Self {
            bins: vec![0; n_bins + 1],
            bin_width: max / n_bins as f64,
            max,
            count: 0,
            sum: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        let v = v.max(0.0);
        let idx = if v >= self.max {
            self.bins.len() - 1
        } else {
            (v / self.bin_width) as usize
        };
        self.bins[idx] += 1;
        self.count += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate percentile from bin midpoints.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                if i == self.bins.len() - 1 {
                    return self.max;
                }
                return (i as f64 + 0.5) * self.bin_width;
            }
        }
        self.max
    }

    /// Fraction of samples at or below `v`.
    pub fn cdf(&self, v: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mut acc = 0u64;
        let cut = if v >= self.max {
            self.bins.len() - 1
        } else {
            (v / self.bin_width) as usize
        };
        for &c in &self.bins[..=cut] {
            acc += c;
        }
        acc as f64 / self.count as f64
    }

    /// (bin_center, count) rows for plotting / figure output.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| ((i as f64 + 0.5) * self.bin_width, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_counts() {
        let mut h = Histogram::new(10.0, 10);
        for i in 0..10 {
            h.record(i as f64 + 0.5);
        }
        assert_eq!(h.count(), 10);
        assert!((h.mean() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn overflow_bin() {
        let mut h = Histogram::new(10.0, 10);
        h.record(100.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(50.0), 10.0);
    }

    #[test]
    fn percentile_approx() {
        let mut h = Histogram::new(100.0, 1000);
        for i in 0..1000 {
            h.record(i as f64 / 10.0);
        }
        let p50 = h.percentile(50.0);
        assert!((p50 - 50.0).abs() < 1.0, "p50={p50}");
        let p99 = h.percentile(99.0);
        assert!((p99 - 99.0).abs() < 1.5, "p99={p99}");
    }

    #[test]
    fn cdf_monotone() {
        let mut h = Histogram::new(10.0, 20);
        for i in 0..100 {
            h.record((i % 10) as f64);
        }
        assert!(h.cdf(2.0) <= h.cdf(5.0));
        assert!((h.cdf(20.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn negative_values_clamped() {
        let mut h = Histogram::new(10.0, 10);
        h.record(-5.0);
        assert_eq!(h.count(), 1);
        assert!(h.cdf(0.5) > 0.99);
    }
}
