//! Scoped-thread fan-out: the spawn-per-pass baseline the persistent
//! [`crate::util::WorkerPool`] is measured against.
//!
//! The production parallel passes (engine view refresh, scheduler queue
//! repricing) moved to the pool — scoped spawn pays ~20–50 µs per
//! thread on *every* pass, which caps the threading win at small
//! fleets. This primitive stays as the comparison baseline for `cargo
//! bench -- par_views` (pool-vs-scoped no-regression gate, digests
//! hard-gated equal)
//! and as the reference semantics both implementations share: items are
//! split into at most `threads` index-ordered chunks, each worker
//! mutates only its own chunk, and nothing is reduced across workers
//! (callers fold results serially afterwards). The engagement gate
//! (`len ≥ 2 × threads`) is identical in both — below it, dispatch cost
//! dominates the work and the pass runs serially.

/// Apply `f` to every item, fanning out over `threads` scoped workers
/// when there are enough items to split. `threads ≤ 1` (or too few
/// items) runs serially; either way `f` sees each item exactly once,
/// in a deterministic per-chunk order.
pub fn par_chunks_mut<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let threads = threads.max(1);
    if threads <= 1 || items.len() < 2 * threads {
        for t in items.iter_mut() {
            f(t);
        }
        return;
    }
    let chunk = items.len().div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        for slice in items.chunks_mut(chunk) {
            s.spawn(move || {
                for t in slice {
                    f(t);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_threaded_visit_every_item_once() {
        for threads in [1, 2, 4, 7] {
            let mut items: Vec<u64> = (0..97).collect();
            par_chunks_mut(&mut items, threads, |x| *x += 1000);
            let want: Vec<u64> = (1000..1097).collect();
            assert_eq!(items, want, "threads={threads}");
        }
    }

    #[test]
    fn small_inputs_stay_serial_but_complete() {
        let mut items = vec![1u64, 2, 3];
        par_chunks_mut(&mut items, 8, |x| *x *= 2);
        assert_eq!(items, vec![2, 4, 6]);
    }
}
