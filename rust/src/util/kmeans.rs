//! Lightweight k-means (Lloyd's algorithm with k-means++ seeding) over
//! small feature vectors.
//!
//! QLM's request-group creation (paper §4, Algorithm 1) clusters requests
//! by (model, SLO, input/output token distribution). Model identity is a
//! hard partition handled by the caller; this module clusters the numeric
//! features (SLO value, token-length statistics).

use crate::util::Rng;

/// Result of a clustering run.
#[derive(Debug, Clone)]
pub struct KMeans {
    pub centroids: Vec<Vec<f64>>,
    pub assignment: Vec<usize>,
    pub inertia: f64,
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Run k-means with k-means++ seeding. `points` are row vectors of equal
/// dimension. Returns centroids, per-point assignment, and inertia.
/// Deterministic given `rng` state.
pub fn kmeans(points: &[Vec<f64>], k: usize, max_iters: usize, rng: &mut Rng) -> KMeans {
    assert!(!points.is_empty());
    let k = k.min(points.len()).max(1);
    let dim = points[0].len();

    // k-means++ seeding.
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(points[rng.usize(points.len())].clone());
    let mut d2: Vec<f64> = points.iter().map(|p| sq_dist(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            points[rng.usize(points.len())].clone()
        } else {
            let mut u = rng.f64() * total;
            let mut idx = points.len() - 1;
            for (i, &d) in d2.iter().enumerate() {
                u -= d;
                if u <= 0.0 {
                    idx = i;
                    break;
                }
            }
            points[idx].clone()
        };
        for (i, p) in points.iter().enumerate() {
            d2[i] = d2[i].min(sq_dist(p, &next));
        }
        centroids.push(next);
    }

    let mut assignment = vec![0usize; points.len()];
    for _ in 0..max_iters {
        // Assign.
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let d = sq_dist(p, cent);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // Update.
        let mut sums = vec![vec![0.0; dim]; centroids.len()];
        let mut counts = vec![0usize; centroids.len()];
        for (i, p) in points.iter().enumerate() {
            let c = assignment[i];
            counts[c] += 1;
            for (s, v) in sums[c].iter_mut().zip(p) {
                *s += v;
            }
        }
        for (c, cent) in centroids.iter_mut().enumerate() {
            if counts[c] > 0 {
                for (ci, s) in cent.iter_mut().zip(&sums[c]) {
                    *ci = s / counts[c] as f64;
                }
            }
        }
        if !changed {
            break;
        }
    }

    let inertia = points
        .iter()
        .zip(&assignment)
        .map(|(p, &a)| sq_dist(p, &centroids[a]))
        .sum();
    KMeans {
        centroids,
        assignment,
        inertia,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_obvious_clusters() {
        let mut rng = Rng::new(1);
        let mut pts = Vec::new();
        for _ in 0..50 {
            pts.push(vec![rng.normal(0.0, 0.1), rng.normal(0.0, 0.1)]);
        }
        for _ in 0..50 {
            pts.push(vec![rng.normal(10.0, 0.1), rng.normal(10.0, 0.1)]);
        }
        let km = kmeans(&pts, 2, 50, &mut rng);
        let a0 = km.assignment[0];
        assert!(km.assignment[..50].iter().all(|&a| a == a0));
        assert!(km.assignment[50..].iter().all(|&a| a != a0));
    }

    #[test]
    fn k_clamped_to_point_count() {
        let mut rng = Rng::new(2);
        let pts = vec![vec![1.0], vec![2.0]];
        let km = kmeans(&pts, 10, 10, &mut rng);
        assert!(km.centroids.len() <= 2);
    }

    #[test]
    fn identical_points_zero_inertia() {
        let mut rng = Rng::new(3);
        let pts = vec![vec![5.0, 5.0]; 20];
        let km = kmeans(&pts, 3, 10, &mut rng);
        assert!(km.inertia < 1e-12);
    }

    #[test]
    fn single_cluster() {
        let mut rng = Rng::new(4);
        let pts: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let km = kmeans(&pts, 1, 10, &mut rng);
        assert_eq!(km.centroids.len(), 1);
        assert!((km.centroids[0][0] - 4.5).abs() < 1e-9);
    }
}
