//! The persistent worker pool behind every parallel pass (engine view
//! refresh, scheduler queue repricing).
//!
//! [`super::par_chunks_mut`] fans out over `std::thread::scope`, which
//! spawns (and joins) an OS thread per chunk on *every* pass — ~20–50 µs
//! per thread, paid thousands of times over a run, which caps the
//! threading win at small fleets (the ROADMAP note this module closes).
//! [`WorkerPool`] spawns its workers **once** (per [`crate::sim::Simulation`]);
//! between jobs they park on a condvar, and a pass costs one lock +
//! notify instead of N spawns. `cargo bench -- par_views` measures the
//! pool against the scoped-spawn baseline and gates the comparison.
//!
//! Semantics are identical to the scoped primitive, deliberately rigid
//! so "threaded ≡ serial bit-for-bit" holds at every call site: the same
//! engagement gate (`len ≥ 2 × threads`, below it the pass runs serially
//! on the caller), index-ordered chunking, each lane mutates only its
//! own claimed chunks, and nothing is reduced across lanes (callers fold
//! results serially afterwards). Which lane runs which chunk cannot
//! affect the result: chunks are disjoint `&mut` slices and the items
//! never move.
//!
//! Lanes **work-steal**: instead of pre-assigning one `div_ceil` chunk
//! per lane, the input is cut into [`STEAL_CHUNKS_PER_LANE`]× more
//! chunks than lanes and every lane claims the next unclaimed chunk from
//! a shared counter until none remain. With one fixed chunk per lane, a
//! skewed pass — one mega virtual queue among many near-empty ones —
//! serialized on whichever lane drew the expensive chunk while the rest
//! idled; with the finer steal queue the fast lanes drain the cheap
//! chunks and converge on the expensive tail. The claim counter was
//! always raced under the pool lock (caller and workers alike), so
//! stealing is purely a chunk-geometry change: the digest-equality and
//! panic-safety guarantees are untouched.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::thread::JoinHandle;

/// One published chunked job, type-erased so the pool is not generic.
///
/// `ctx` points at a [`ChunkJob`] on the submitting thread's stack.
/// Safety: [`WorkerPool::run_chunks_mut`] does not return — by normal
/// exit *or* by unwind — until `remaining == 0` (every lane runs its
/// chunk under `catch_unwind` and decrements even when the closure
/// panics; the submitter re-raises the first captured payload only
/// after the job is fully drained and cleared). So the pointee outlives
/// every dereference, and the chunks handed out are disjoint `&mut`
/// slices of the caller's buffer.
struct Job {
    ctx: *const (),
    // SAFETY: callers of this fn pointer must pass the `ctx` stored
    // alongside it and a chunk index claimed under the pool lock; it is
    // only ever set to `call_chunk::<T, F>` paired with a `ctx` that
    // points at a live `ChunkJob<T, F>` (see `run_chunks_mut`).
    call: unsafe fn(*const (), usize),
    /// Next chunk index to claim (caller and workers race under the lock).
    next: usize,
    /// Chunks published but not yet completed.
    remaining: usize,
    chunks: usize,
    /// First panic payload raised by any lane's chunk — re-thrown on
    /// the submitting thread once the job drains, preserving the
    /// panic-propagation semantics of the `std::thread::scope`
    /// primitive this pool replaced (a swallowed worker panic would
    /// otherwise hang the submitter forever).
    payload: Option<Box<dyn std::any::Any + Send>>,
}

// SAFETY: see `Job` — the raw pointer is only dereferenced while the
// submitting call blocks, and every dereference targets a disjoint chunk.
unsafe impl Send for Job {}

struct State {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitting thread parks here until the last chunk completes.
    done: Condvar,
}

/// The borrowed, typed side of a job: base pointer + chunk geometry +
/// the per-item closure. Lives on the submitter's stack for the duration
/// of the call; lanes reconstruct their disjoint `&mut [T]` from it.
struct ChunkJob<'f, T, F> {
    base: *mut T,
    len: usize,
    chunk: usize,
    f: &'f F,
}

/// Steal-queue granularity: chunks per lane. Finer chunks bound the
/// idle tail on skewed inputs (a lane is stuck behind at most one
/// expensive chunk ~1/4 the lane's nominal share) while keeping claim
/// traffic — one pool-lock acquisition per chunk — negligible.
const STEAL_CHUNKS_PER_LANE: usize = 4;

/// Chunk geometry for a stealing pass: `(chunk_len, chunk_count)`.
/// Chunks tile `[0, len)` in index order; the last may be short.
fn chunk_geometry(len: usize, threads: usize) -> (usize, usize) {
    let chunk = len.div_ceil(threads * STEAL_CHUNKS_PER_LANE).max(1);
    (chunk, len.div_ceil(chunk))
}

/// Run chunk `idx` of the job behind `ctx`. SAFETY: `ctx` must point at
/// a live `ChunkJob<T, F>` and `idx` must be claimed by exactly one lane
/// (the claim counter under the pool lock guarantees both).
unsafe fn call_chunk<T: Send, F: Fn(&mut T) + Sync>(ctx: *const (), idx: usize) {
    // SAFETY: the fn-level contract — `ctx` points at a live
    // `ChunkJob<T, F>` kept alive by the blocked submitter.
    let job = unsafe { &*(ctx as *const ChunkJob<'_, T, F>) };
    let start = idx * job.chunk;
    let end = (start + job.chunk).min(job.len);
    // SAFETY: `idx` was claimed by exactly one lane, chunks are
    // disjoint index ranges of the caller's buffer, and `end` is
    // clamped to `len`, so this `&mut` slice aliases nothing.
    let slice = unsafe { std::slice::from_raw_parts_mut(job.base.add(start), end - start) };
    for t in slice {
        (job.f)(t);
    }
}

/// A persistent pool of `threads - 1` parked worker threads; the calling
/// thread is the remaining lane, so `threads = 1` spawns nothing and
/// runs fully serial. Spawned once, reused for every pass, shut down and
/// joined on drop.
pub struct WorkerPool {
    shared: std::sync::Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Jobs dispatched through the parallel path (observability: the
    /// reuse tests assert many jobs ran on the same fixed worker set).
    jobs: AtomicU64,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .field("workers", &self.workers.len())
            .finish()
    }
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = std::sync::Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|_| {
                let shared = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        WorkerPool {
            shared,
            workers,
            threads,
            jobs: AtomicU64::new(0),
        }
    }

    /// Configured lane count (workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// OS threads the pool owns — fixed at construction, never respawned.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Jobs that went down the parallel path since construction.
    pub fn jobs_run(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Apply `f` to every item, fanning out over the pool's lanes when
    /// there are enough items to split (same engagement gate as
    /// [`super::par_chunks_mut`]; finer work-stealing chunks — see the
    /// module docs). Either way `f` sees each item exactly once; chunks
    /// stay in index order and are disjoint, so the result is
    /// bit-identical to the serial pass whatever the lane count.
    pub fn run_chunks_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        if self.threads <= 1 || items.len() < 2 * self.threads || self.workers.is_empty() {
            for t in items.iter_mut() {
                f(t);
            }
            return;
        }
        let (chunk, chunks) = chunk_geometry(items.len(), self.threads);
        let job = ChunkJob {
            base: items.as_mut_ptr(),
            len: items.len(),
            chunk,
            f: &f,
        };
        let ctx = &job as *const ChunkJob<'_, T, F> as *const ();
        self.jobs.fetch_add(1, Ordering::Relaxed);

        let mut guard = self.shared.state.lock().unwrap();
        // The engine and the scheduler share one pool on one thread, so
        // the slot is normally free; if another thread is mid-job, queue
        // behind it rather than clobbering its in-flight state.
        while guard.job.is_some() {
            guard = self.shared.done.wait(guard).unwrap();
        }
        guard.job = Some(Job {
            ctx,
            call: call_chunk::<T, F>,
            next: 0,
            remaining: chunks,
            chunks,
            payload: None,
        });
        self.shared.work.notify_all();
        // The caller is a lane too: claim chunks alongside the workers,
        // then park on `done` until the last chunk (wherever it ran)
        // completes. Not returning before `remaining == 0` — even when a
        // chunk panics (caught below, re-raised after the drain) — is
        // what makes the borrow-erasing `ctx` pointer sound.
        loop {
            let claimed = guard.job.as_mut().and_then(|j| {
                (j.next < j.chunks).then(|| {
                    let i = j.next;
                    j.next += 1;
                    i
                })
            });
            match claimed {
                Some(i) => {
                    drop(guard);
                    // SAFETY: `ctx` points at `job` on this very stack
                    // frame (alive until this call returns) and chunk
                    // `i` was claimed under the lock by this lane only.
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                        call_chunk::<T, F>(ctx, i)
                    }));
                    guard = self.shared.state.lock().unwrap();
                    let j = guard.job.as_mut().expect("job lives until the submitter clears it");
                    if let Err(p) = res {
                        j.payload.get_or_insert(p);
                    }
                    j.remaining -= 1;
                    if j.remaining == 0 {
                        break;
                    }
                }
                None => {
                    if guard.job.as_ref().map(|j| j.remaining) == Some(0) {
                        break;
                    }
                    guard = self.shared.done.wait(guard).unwrap();
                }
            }
        }
        let payload = guard.job.as_mut().and_then(|j| j.payload.take());
        guard.job = None;
        // Free the slot for any submitter queued behind this job.
        self.shared.done.notify_all();
        drop(guard);
        if let Some(p) = payload {
            // A lane's closure panicked: the job has fully drained (no
            // worker still holds `ctx`), so propagate on the submitting
            // thread exactly as the scoped-spawn primitive did.
            std::panic::resume_unwind(p);
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut guard = shared.state.lock().unwrap();
    loop {
        if guard.shutdown {
            return;
        }
        let claimed = guard.job.as_mut().and_then(|j| {
            (j.next < j.chunks).then(|| {
                let i = j.next;
                j.next += 1;
                (j.ctx, j.call, i)
            })
        });
        match claimed {
            Some((ctx, call, i)) => {
                drop(guard);
                // SAFETY: the chunk index was claimed under the lock, so
                // this lane is its only visitor; the submitter blocks
                // until `remaining == 0`, keeping `ctx` alive. The catch
                // keeps a panicking closure from killing the worker (or
                // leaking an undecremented chunk, which would hang the
                // submitter); the payload is re-thrown submitter-side.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                    call(ctx, i)
                }));
                guard = shared.state.lock().unwrap();
                if let Some(j) = guard.job.as_mut() {
                    if let Err(p) = res {
                        j.payload.get_or_insert(p);
                    }
                    j.remaining -= 1;
                    if j.remaining == 0 {
                        shared.done.notify_all();
                    }
                }
            }
            None => {
                guard = shared.work.wait(guard).unwrap();
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut guard = self.shared.state.lock().unwrap();
            guard.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_matches_serial_for_every_lane_count() {
        for threads in [1, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let mut items: Vec<u64> = (0..97).collect();
            pool.run_chunks_mut(&mut items, |x| *x += 1000);
            let want: Vec<u64> = (1000..1097).collect();
            assert_eq!(items, want, "threads={threads}");
        }
    }

    #[test]
    fn small_inputs_stay_serial_but_complete() {
        let pool = WorkerPool::new(8);
        let mut items = vec![1u64, 2, 3];
        pool.run_chunks_mut(&mut items, |x| *x *= 2);
        assert_eq!(items, vec![2, 4, 6]);
        assert_eq!(pool.jobs_run(), 0, "below the gate the pool is bypassed");
    }

    #[test]
    fn workers_are_reused_across_many_passes() {
        // The whole point of the pool: one spawn, many jobs. The worker
        // set is fixed at construction; 200 passes dispatch 200 jobs
        // through the same 3 parked workers, with no respawn path in
        // between (`workers()` is the owned-thread count, constant by
        // construction — a scoped-spawn implementation would have paid
        // 600 spawns here).
        let pool = WorkerPool::new(4);
        assert_eq!(pool.workers(), 3);
        let mut items: Vec<u64> = (0..64).collect();
        for _ in 0..200 {
            pool.run_chunks_mut(&mut items, |x| *x = x.wrapping_add(1));
        }
        assert_eq!(pool.jobs_run(), 200);
        assert_eq!(pool.workers(), 3);
        let want: Vec<u64> = (0..64u64).map(|x| x + 200).collect();
        assert_eq!(items, want);
    }

    #[test]
    fn chunk_geometry_tiles_the_input_and_over_partitions() {
        for (len, threads) in [(8, 4), (97, 4), (131, 3), (2048, 4), (1_000_000, 8)] {
            let (chunk, chunks) = chunk_geometry(len, threads);
            assert!(chunk >= 1);
            // Index-ordered chunks must tile [0, len) exactly.
            assert!((chunks - 1) * chunk < len, "len={len} threads={threads}");
            assert!(chunks * chunk >= len, "len={len} threads={threads}");
            // Stealing needs more chunks than lanes whenever the input
            // is large enough to cut that fine.
            if len >= threads * STEAL_CHUNKS_PER_LANE {
                assert_eq!(chunks, threads * STEAL_CHUNKS_PER_LANE, "len={len} threads={threads}");
            }
        }
    }

    #[test]
    fn skewed_chunk_costs_still_produce_identical_results() {
        // One "mega" item orders of magnitude costlier than the rest:
        // the steal queue reassigns the cheap chunks to idle lanes, and
        // the output must stay identical to the serial pass regardless.
        let pool = WorkerPool::new(4);
        let mut items: Vec<u64> = (0..256).collect();
        pool.run_chunks_mut(&mut items, |x| {
            let spins = if *x == 0 { 20_000 } else { 10 };
            let mut acc = *x;
            for _ in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            }
            *x = acc;
        });
        let want: Vec<u64> = (0..256u64)
            .map(|x| {
                let spins = if x == 0 { 20_000 } else { 10 };
                let mut acc = x;
                for _ in 0..spins {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                }
                acc
            })
            .collect();
        assert_eq!(items, want);
    }

    #[test]
    fn pool_agrees_with_scoped_baseline() {
        // The pool steals over finer chunks than the scoped-spawn
        // primitive's one-per-lane split, but chunks are disjoint index
        // ranges either way, so both must transform any buffer
        // identically.
        for threads in [2, 3, 4] {
            let pool = WorkerPool::new(threads);
            let mut a: Vec<u64> = (0..131).map(|x| x * 7).collect();
            let mut b = a.clone();
            pool.run_chunks_mut(&mut a, |x| *x = x.wrapping_mul(31) ^ 5);
            super::super::par_chunks_mut(&mut b, threads, |x| *x = x.wrapping_mul(31) ^ 5);
            assert_eq!(a, b, "threads={threads}");
        }
    }

    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        // A panicking chunk closure must behave like the scoped-spawn
        // primitive it replaced: the panic reaches the submitter (no
        // silent hang, no use-after-free of the job context), and the
        // pool — workers included — stays serviceable afterwards.
        let pool = WorkerPool::new(4);
        let mut items: Vec<u64> = (0..64).collect();
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks_mut(&mut items, |x| {
                assert!(*x != 13, "boom");
            });
        }));
        assert!(hit.is_err(), "the chunk panic must propagate");
        let mut again: Vec<u64> = (0..64).collect();
        pool.run_chunks_mut(&mut again, |x| *x += 1);
        let want: Vec<u64> = (1..=64).collect();
        assert_eq!(again, want, "pool must survive a panicked job");
        assert_eq!(pool.workers(), 3);
    }

    #[test]
    fn single_thread_pool_spawns_nothing() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.workers(), 0);
        let mut items: Vec<u64> = (0..32).collect();
        pool.run_chunks_mut(&mut items, |x| *x += 1);
        assert_eq!(items[31], 32);
    }
}
