//! Descriptive statistics used by the RWT estimator, metrics collection,
//! and the figure harnesses (R², percentiles, online moments).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Coefficient of determination between predictions and observations —
/// the paper reports R² = 0.99 for the RWT estimator (Fig. 3, Fig. 18).
pub fn r_squared(predicted: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    if actual.is_empty() {
        return 0.0;
    }
    let m = mean(actual);
    let ss_tot: f64 = actual.iter().map(|y| (y - m) * (y - m)).sum();
    let ss_res: f64 = predicted
        .iter()
        .zip(actual)
        .map(|(p, y)| (y - p) * (y - p))
        .sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Ordinary least squares fit y = a + b x; returns (intercept, slope).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    if sxx == 0.0 || n < 2.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Welford online mean/variance accumulator — used where streaming metrics
/// must not buffer every sample (per-token latencies in the hot loop).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn variance_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let y = [1.0, 2.0, 3.0, 4.0];
        assert!((r_squared(&y, &y) - 1.0).abs() < 1e-12);
        let mean_pred = [2.5; 4];
        assert!(r_squared(&mean_pred, &y).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn online_matches_batch() {
        let mut r = crate::util::Rng::new(5);
        let xs: Vec<f64> = (0..10_000).map(|_| r.normal(3.0, 1.5)).collect();
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-9);
        assert!((o.variance() - variance(&xs)).abs() < 1e-6);
    }

    #[test]
    fn online_merge_matches_single_stream() {
        let mut r = crate::util::Rng::new(6);
        let xs: Vec<f64> = (0..1_000).map(|_| r.f64()).collect();
        let mut whole = OnlineStats::new();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for (i, &x) in xs.iter().enumerate() {
            whole.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }
}
