//! Hand-rolled JSON primitives for the observability subsystem.
//!
//! The crate is dependency-free, so trace/telemetry lines are built (and
//! `qlm report` reads them back) with these helpers instead of serde.
//! Two invariants matter more than generality:
//!
//! * **Byte-stable floats.** Every float is rendered with a fixed
//!   `{:.6}` width, so identical runs produce identical bytes — the
//!   trace-determinism suite compares whole files with `==`.
//! * **Flat objects only.** Trace lines are one-level objects (telemetry
//!   nests one level, but no string field ever contains `"`, `,`, `}`
//!   beyond what [`esc`] escapes), so [`field`] can extract values by
//!   key scan without a full parser.

/// Render a float with fixed six-decimal precision (byte-stable across
/// runs and platforms for the magnitudes the sim produces).
pub fn f(x: f64) -> String {
    format!("{x:.6}")
}

/// Render an `Option<f64>`: `null` when absent.
pub fn opt_f(x: Option<f64>) -> String {
    match x {
        Some(v) => f(v),
        None => "null".into(),
    }
}

/// Escape a string for inclusion inside JSON quotes. The sim only emits
/// identifier-like strings, but `qlm report` must never produce a
/// malformed file even if a scenario name grows odd characters.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Extract the raw value of `"key":` from a flat JSON object line.
///
/// Returns the value token with surrounding quotes stripped for strings
/// (`None` when the key is missing). Good enough for the lines this
/// module writes: keys are unique per line and values are scalars.
pub fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\":");
    let start = line.find(&needle)? + needle.len();
    let rest = &line[start..];
    if let Some(stripped) = rest.strip_prefix('"') {
        let end = stripped.find('"')?;
        return Some(&stripped[..end]);
    }
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    Some(rest[..end].trim())
}

/// [`field`] narrowed to an `f64`; `null` and parse failures map to `None`.
pub fn field_f64(line: &str, key: &str) -> Option<f64> {
    let raw = field(line, key)?;
    if raw == "null" {
        return None;
    }
    raw.parse().ok()
}

/// [`field`] narrowed to a `u64`.
pub fn field_u64(line: &str, key: &str) -> Option<u64> {
    field(line, key)?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floats_are_fixed_width() {
        assert_eq!(f(0.0), "0.000000");
        assert_eq!(f(1.5), "1.500000");
        assert_eq!(f(-2.25), "-2.250000");
        assert_eq!(opt_f(None), "null");
        assert_eq!(opt_f(Some(3.0)), "3.000000");
    }

    #[test]
    fn escaping_round_trips_identifiers() {
        assert_eq!(esc("mixed-slo"), "mixed-slo");
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn field_extraction() {
        let line = r#"{"t":1.500000,"req":7,"ev":"pulled","inst":2,"wait_s":null}"#;
        assert_eq!(field(line, "ev"), Some("pulled"));
        assert_eq!(field_u64(line, "req"), Some(7));
        assert_eq!(field_f64(line, "t"), Some(1.5));
        assert_eq!(field_f64(line, "wait_s"), None);
        assert_eq!(field(line, "missing"), None);
    }

    #[test]
    fn field_handles_last_value_in_object() {
        let line = r#"{"a":1,"b":2}"#;
        assert_eq!(field(line, "b"), Some("2"));
    }
}
