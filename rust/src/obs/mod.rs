//! Observability: the flight recorder, fleet telemetry sampler, and
//! RWT-accuracy ledger.
//!
//! `RunMetrics` answers *how did the run end*; this module answers
//! *what happened along the way* — per-request lifecycle events
//! ([`recorder`]), a fixed-cadence fleet time series ([`telemetry`]),
//! and an online predicted-vs-actual waiting-time join ([`ledger`],
//! the paper's Fig. 3 validation). [`report`] renders the recorded
//! trace back into tables for the `qlm report` subcommand, and
//! [`json`] is the shared hand-rolled JSONL layer.
//!
//! Contract with the engine (enforced by `tests/obs.rs` and the
//! `qlm audit` determinism rules, which cover this directory):
//!
//! * **Off by default, free when off.** The engine holds
//!   `Option<Box<ObsState>>`; every hook is behind one `if let`. A run
//!   with observability disabled executes the same instructions it did
//!   before this module existed.
//! * **Record, never steer.** Nothing here feeds back into scheduling,
//!   so golden digests are bit-identical whether tracing is on or off.
//! * **Deterministic bytes.** Events are recorded on the event-loop
//!   thread in dispatch order and floats render at fixed width, so the
//!   JSONL is byte-identical across re-runs and `--threads` lane counts.
//! * **Simulated time only.** Every stamp is sim-clock time; the audit
//!   wall-clock rule applies to this directory.

pub mod json;
pub mod ledger;
pub mod recorder;
pub mod report;
pub mod telemetry;

pub use ledger::{predict_wait, ClassError, RwtLedger};
pub use recorder::{FlightRecorder, TraceEvent, TraceEventKind};
pub use report::{render, ReportOptions};
pub use telemetry::{InstanceSample, SchedMix, TelemetryLog, TelemetrySample};

/// What the engine should observe. Default: nothing.
#[derive(Debug, Clone, Copy, Default)]
pub struct ObsConfig {
    /// Record per-request lifecycle events (and the RWT ledger, which
    /// rides on the same submit/pull hooks).
    pub trace: bool,
    /// Sample fleet telemetry every this many simulated seconds.
    pub telemetry_every_s: Option<f64>,
}

impl ObsConfig {
    pub fn enabled(&self) -> bool {
        self.trace || self.telemetry_every_s.is_some()
    }
}

/// Live observer state owned by the engine while a run executes.
#[derive(Debug)]
pub struct ObsState {
    pub recorder: FlightRecorder,
    /// Present iff a sampling cadence was configured.
    pub telemetry: Option<TelemetryLog>,
    pub ledger: RwtLedger,
    /// Scheduler pass-mix accumulator (also snapshotted per telemetry
    /// sample).
    pub sched: SchedMix,
    /// Whether lifecycle events should be recorded (mirrors
    /// [`ObsConfig::trace`]; telemetry can run without the recorder).
    pub trace: bool,
}

impl ObsState {
    pub fn new(cfg: &ObsConfig) -> Self {
        ObsState {
            recorder: FlightRecorder::default(),
            telemetry: cfg.telemetry_every_s.map(TelemetryLog::new),
            ledger: RwtLedger::default(),
            sched: SchedMix::default(),
            trace: cfg.trace,
        }
    }

    /// Record one lifecycle event (no-op when tracing is off — the
    /// state may exist for telemetry alone).
    pub fn record(&mut self, t: f64, req: u64, kind: TraceEventKind) {
        if self.trace {
            self.recorder.record(t, req, kind);
        }
    }

    pub fn into_report(self) -> ObsReport {
        ObsReport {
            trace_jsonl: self.recorder.to_jsonl(),
            telemetry_jsonl: self.telemetry.as_ref().map(TelemetryLog::to_jsonl),
            rwt_errors: self.ledger.per_class_errors(),
            sched: self.sched,
        }
    }
}

/// Everything a finished run observed, ready for export.
#[derive(Debug)]
pub struct ObsReport {
    /// Flight-recorder JSONL (empty string when tracing was off).
    pub trace_jsonl: String,
    /// Telemetry JSONL, when a cadence was configured.
    pub telemetry_jsonl: Option<String>,
    /// Per-class RWT prediction error (MAE + p90), classes in SLO order.
    pub rwt_errors: Vec<ClassError>,
    /// Final scheduler pass-mix counters.
    pub sched: SchedMix,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_config_is_disabled() {
        assert!(!ObsConfig::default().enabled());
        assert!(ObsConfig { trace: true, ..Default::default() }.enabled());
        assert!(ObsConfig { telemetry_every_s: Some(5.0), ..Default::default() }.enabled());
    }

    #[test]
    fn record_respects_trace_flag() {
        let mut on = ObsState::new(&ObsConfig { trace: true, telemetry_every_s: None });
        let mut off = ObsState::new(&ObsConfig { trace: false, telemetry_every_s: Some(1.0) });
        on.record(1.0, 0, TraceEventKind::Shed);
        off.record(1.0, 0, TraceEventKind::Shed);
        assert_eq!(on.recorder.len(), 1);
        assert_eq!(off.recorder.len(), 0);
        assert!(off.telemetry.is_some());
    }

    #[test]
    fn report_carries_trace_and_telemetry() {
        let mut st = ObsState::new(&ObsConfig { trace: true, telemetry_every_s: Some(2.0) });
        st.record(0.5, 7, TraceEventKind::Shed);
        let sample = TelemetrySample { t: 2.0, ..Default::default() };
        st.telemetry.as_mut().unwrap().record(&sample);
        let rep = st.into_report();
        assert!(rep.trace_jsonl.contains(r#""ev":"shed""#));
        assert!(rep.telemetry_jsonl.unwrap().starts_with(r#"{"t":2.000000"#));
    }
}
