//! Fleet telemetry: a fixed-cadence time series of queue, instance,
//! autoscaler, admission, and scheduler-pass state.
//!
//! The sampler fires on simulated-time boundaries (`t = k · every_s`)
//! interleaved with the event loop, so the series is as deterministic
//! as the run itself: same seed ⇒ byte-identical JSONL, any lane count.
//! Most of what it captures already existed as counters that were
//! dropped on the floor — `SolveStats`, the estimator memo hit rate,
//! the event core's wake dedup stats — now kept as a trajectory.

use crate::obs::json;
use crate::workload::SloClass;

/// Cumulative scheduler pass-mix counters, accumulated per pass from
/// [`crate::baselines::PassStats`]. `memo_*` are snapshots of the
/// estimator's cumulative memo counters at the latest pass rather than
/// sums (the estimator already accumulates across its lifetime).
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedMix {
    /// Scheduler passes observed (invocations that produced a plan).
    pub passes: u64,
    /// Passes that ran the full solve.
    pub full: u64,
    /// Passes that went down the cached delta path.
    pub delta: u64,
    /// Dirty groups re-inserted, summed across delta passes.
    pub dirty: u64,
    /// Instances whose queue changed, summed across delta passes.
    pub touched_instances: u64,
    /// Branch-and-bound nodes expanded by MILP refinement.
    pub milp_nodes: u64,
    /// Penalty-table crossings drained by delta-pass re-anchoring.
    pub crossings_drained: u64,
    /// RWT estimator group-service memo hits (cumulative snapshot).
    pub memo_hits: u64,
    /// RWT estimator group-service memo misses (cumulative snapshot).
    pub memo_misses: u64,
}

impl SchedMix {
    /// Fold one pass's stats in (memo counters replace, others add).
    pub fn absorb(&mut self, stats: &crate::baselines::PassStats) {
        self.passes += 1;
        if stats.incremental {
            self.delta += 1;
        } else {
            self.full += 1;
        }
        self.dirty += stats.dirty as u64;
        self.touched_instances += stats.touched_instances as u64;
        self.milp_nodes += stats.milp_nodes as u64;
        self.crossings_drained += stats.crossings_drained as u64;
        self.memo_hits = stats.memo_hits;
        self.memo_misses = stats.memo_misses;
    }

    fn to_json(&self) -> String {
        format!(
            r#"{{"passes":{},"full":{},"delta":{},"dirty":{},"touched":{},"milp_nodes":{},"crossings_drained":{},"memo_hits":{},"memo_misses":{}}}"#,
            self.passes,
            self.full,
            self.delta,
            self.dirty,
            self.touched_instances,
            self.milp_nodes,
            self.crossings_drained,
            self.memo_hits,
            self.memo_misses
        )
    }
}

/// One instance's occupancy at a sample instant.
#[derive(Debug, Clone, Copy)]
pub struct InstanceSample {
    pub id: u32,
    /// Active model, if one is resident.
    pub model: Option<u32>,
    /// Sequences in the running batch.
    pub running: usize,
    /// Sequences swapped out to host memory.
    pub swapped: usize,
    /// KV-cache utilization in [0, 1].
    pub kv: f64,
}

/// Everything captured at one sample instant.
#[derive(Debug, Clone, Default)]
pub struct TelemetrySample {
    pub t: f64,
    /// Waiting requests per class (classes in SLO order).
    pub waiting: Vec<(SloClass, i64)>,
    /// Alive instances, id order.
    pub instances: Vec<InstanceSample>,
    pub active: usize,
    pub warming: usize,
    pub draining: usize,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Classes admission control is currently shedding.
    pub shedding: Vec<SloClass>,
    pub sched: SchedMix,
    /// Event-core wake dedup counters (honored, stale-dropped).
    pub wakes_honored: u64,
    pub wakes_stale: u64,
}

impl TelemetrySample {
    /// Render as one JSON line (flat except for the named sub-objects).
    pub fn to_json_line(&self) -> String {
        let waiting: Vec<String> = self
            .waiting
            .iter()
            .map(|(c, n)| format!(r#""{}":{}"#, c.name(), n))
            .collect();
        let instances: Vec<String> = self
            .instances
            .iter()
            .map(|i| {
                format!(
                    r#"{{"id":{},"model":{},"running":{},"swapped":{},"kv":{}}}"#,
                    i.id,
                    i.model.map_or("null".into(), |m| m.to_string()),
                    i.running,
                    i.swapped,
                    json::f(i.kv)
                )
            })
            .collect();
        let shedding: Vec<String> =
            self.shedding.iter().map(|c| format!(r#""{}""#, c.name())).collect();
        format!(
            r#"{{"t":{},"waiting":{{{}}},"instances":[{}],"fleet":{{"active":{},"warming":{},"draining":{},"scale_ups":{},"scale_downs":{}}},"admission":{{"shedding":[{}]}},"sched":{},"wakes":{{"honored":{},"stale":{}}}}}"#,
            json::f(self.t),
            waiting.join(","),
            instances.join(","),
            self.active,
            self.warming,
            self.draining,
            self.scale_ups,
            self.scale_downs,
            shedding.join(","),
            self.sched.to_json(),
            self.wakes_honored,
            self.wakes_stale
        )
    }
}

/// The sampler's accumulated output plus its cadence state.
#[derive(Debug)]
pub struct TelemetryLog {
    /// Sampling period in simulated seconds.
    pub every_s: f64,
    /// Next sample boundary (the engine samples every boundary ≤ the
    /// event about to be processed, so quiet stretches still sample).
    pub next_s: f64,
    lines: Vec<String>,
    samples: usize,
}

impl TelemetryLog {
    pub fn new(every_s: f64) -> Self {
        // First sample at t = every_s: a t = 0 sample would observe the
        // fleet mid-construction and say nothing.
        TelemetryLog { every_s, next_s: every_s, lines: Vec::new(), samples: 0 }
    }

    pub fn record(&mut self, sample: &TelemetrySample) {
        self.lines.push(sample.to_json_line());
        self.samples += 1;
    }

    pub fn len(&self) -> usize {
        self.samples
    }

    pub fn is_empty(&self) -> bool {
        self.samples == 0
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::with_capacity(self.lines.len() * 160);
        for line in &self.lines {
            out.push_str(line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_renders_stable_json() {
        let s = TelemetrySample {
            t: 10.0,
            waiting: vec![(SloClass::Interactive, 3), (SloClass::Batch1, 0)],
            instances: vec![InstanceSample { id: 0, model: Some(1), running: 12, swapped: 2, kv: 0.43 }],
            active: 1,
            warming: 0,
            draining: 0,
            scale_ups: 2,
            scale_downs: 1,
            shedding: vec![SloClass::Batch2],
            sched: SchedMix { passes: 5, full: 1, delta: 4, ..Default::default() },
            wakes_honored: 9,
            wakes_stale: 1,
        };
        let line = s.to_json_line();
        assert!(line.starts_with(r#"{"t":10.000000,"waiting":{"interactive":3,"batch-1":0}"#));
        assert!(line.contains(r#""instances":[{"id":0,"model":1,"running":12,"swapped":2,"kv":0.430000}]"#));
        assert!(line.contains(r#""fleet":{"active":1,"warming":0,"draining":0,"scale_ups":2,"scale_downs":1}"#));
        assert!(line.contains(r#""admission":{"shedding":["batch-2"]}"#));
        assert!(line.contains(r#""sched":{"passes":5,"full":1,"delta":4"#));
        assert!(line.ends_with(r#""wakes":{"honored":9,"stale":1}}"#));
    }

    #[test]
    fn absorb_classifies_passes_and_snapshots_memo() {
        let mut mix = SchedMix::default();
        mix.absorb(&crate::baselines::PassStats {
            incremental: false,
            groups: 10,
            dirty: 0,
            touched_instances: 0,
            milp_nodes: 7,
            crossings_drained: 0,
            memo_hits: 4,
            memo_misses: 6,
        });
        mix.absorb(&crate::baselines::PassStats {
            incremental: true,
            groups: 10,
            dirty: 3,
            touched_instances: 2,
            milp_nodes: 0,
            crossings_drained: 5,
            memo_hits: 9,
            memo_misses: 7,
        });
        assert_eq!(mix.passes, 2);
        assert_eq!(mix.full, 1);
        assert_eq!(mix.delta, 1);
        assert_eq!(mix.dirty, 3);
        assert_eq!(mix.milp_nodes, 7);
        assert_eq!(mix.crossings_drained, 5);
        assert_eq!((mix.memo_hits, mix.memo_misses), (9, 7));
    }

    #[test]
    fn log_cadence_starts_after_zero() {
        let log = TelemetryLog::new(5.0);
        assert_eq!(log.next_s, 5.0);
        assert!(log.is_empty());
    }
}
