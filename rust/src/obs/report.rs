//! `qlm report` — render a flight-recorder JSONL file back into
//! human-readable tables: event-kind counts, the per-class RWT
//! prediction-error table, and per-request timelines.
//!
//! The parser is the flat key-scan from [`crate::obs::json`]; it reads
//! exactly the lines [`crate::obs::recorder`] writes. The RWT table is
//! recomputed offline from the trace itself (Submitted carries the
//! prediction, the first Pulled/Restored carries the measured wait) by
//! replaying the same [`RwtLedger`] join the engine runs online — one
//! aggregation code path, two feeding modes.

use std::collections::BTreeMap;

use crate::obs::json;
use crate::obs::ledger::RwtLedger;
use crate::workload::SloClass;

fn class_from_name(name: &str) -> Option<SloClass> {
    SloClass::ALL.into_iter().find(|c| c.name() == name)
}

/// What to render.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReportOptions {
    /// Render only this request's timeline (plus the aggregate tables).
    pub req: Option<u64>,
    /// How many request timelines to render when `req` is unset.
    pub timelines: usize,
}

/// One parsed trace line.
struct ParsedEvent<'a> {
    t: f64,
    req: u64,
    ev: &'a str,
    line: &'a str,
}

fn parse(trace_jsonl: &str) -> Vec<ParsedEvent<'_>> {
    let mut out = Vec::new();
    for line in trace_jsonl.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (Some(t), Some(req), Some(ev)) = (
            json::field_f64(line, "t"),
            json::field_u64(line, "req"),
            json::field(line, "ev"),
        ) else {
            continue;
        };
        out.push(ParsedEvent { t, req, ev, line });
    }
    out
}

/// The event's payload fields, rendered `key=value` for timeline rows.
fn payload(line: &str, ev: &str) -> String {
    let marker = format!(r#""ev":"{ev}""#);
    let Some(pos) = line.find(&marker) else { return String::new() };
    let rest = &line[pos + marker.len()..];
    let rest = rest.strip_suffix('}').unwrap_or(rest);
    rest.trim_start_matches(',')
        .split(',')
        .filter(|kv| !kv.is_empty())
        .map(|kv| kv.replace(&['"', ':'][..], " ").split_whitespace().collect::<Vec<_>>().join("="))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Render the full report for one trace file.
pub fn render(trace_jsonl: &str, opts: &ReportOptions) -> String {
    let events = parse(trace_jsonl);
    let mut out = String::new();

    let mut requests: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        requests.entry(ev.req).or_default().push(i);
        *counts.entry(ev.ev).or_insert(0) += 1;
    }
    out.push_str(&format!("trace: {} events, {} requests\n", events.len(), requests.len()));

    out.push_str("\nevent counts\n");
    for (ev, n) in &counts {
        out.push_str(&format!("  {ev:<14} {n}\n"));
    }

    // Replay the engine's online join: prediction at submit, measured
    // wait at the first pull (Restored first can't happen, but accept it
    // so a hand-edited trace still joins).
    let mut ledger = RwtLedger::default();
    for ev in &events {
        match ev.ev {
            "submitted" => {
                if let (Some(class), Some(predicted)) = (
                    json::field(ev.line, "class").and_then(class_from_name),
                    json::field_f64(ev.line, "predicted_wait_s"),
                ) {
                    ledger.note_predicted(ev.req, class, predicted);
                }
            }
            "pulled" | "restored" => {
                if let Some(wait) = json::field_f64(ev.line, "wait_s") {
                    ledger.note_actual(ev.req, wait);
                }
            }
            _ => {}
        }
    }
    out.push_str("\nRWT prediction error (predicted vs actual wait at first pull)\n");
    let rows = ledger.per_class_errors();
    if rows.is_empty() {
        out.push_str("  (no joined prediction/actual pairs in this trace)\n");
    } else {
        out.push_str(&format!("  {:<13} {:>6} {:>10} {:>10}\n", "class", "n", "mae_s", "p90_s"));
        for r in rows {
            out.push_str(&format!(
                "  {:<13} {:>6} {:>10.3} {:>10.3}\n",
                r.class.name(),
                r.n,
                r.mae_s,
                r.p90_s
            ));
        }
    }

    // Timelines: an explicit request, or the first few that completed.
    let picked: Vec<u64> = match opts.req {
        Some(id) => vec![id],
        None => requests
            .iter()
            .filter(|(_, idxs)| idxs.iter().any(|&i| events[i].ev == "completed"))
            .map(|(&id, _)| id)
            .take(opts.timelines)
            .collect(),
    };
    for id in picked {
        let Some(idxs) = requests.get(&id) else {
            out.push_str(&format!("\nrequest {id}: not in trace\n"));
            continue;
        };
        out.push_str(&format!("\nrequest {id} timeline\n"));
        for &i in idxs {
            let ev = &events[i];
            out.push_str(&format!("  {:>12.6}  {:<14} {}\n", ev.t, ev.ev, payload(ev.line, ev.ev)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{InstanceId, ModelId};
    use crate::obs::recorder::{FlightRecorder, TraceEventKind};

    fn sample_trace() -> String {
        let mut rec = FlightRecorder::default();
        rec.record(
            0.0,
            0,
            TraceEventKind::Submitted {
                model: ModelId(0),
                class: SloClass::Interactive,
                mega: false,
                predicted_wait_s: Some(1.0),
            },
        );
        rec.record(1.5, 0, TraceEventKind::Pulled { inst: InstanceId(0), wait_s: 1.5 });
        rec.record(2.0, 0, TraceEventKind::FirstToken { inst: InstanceId(0), ttft_s: 2.0 });
        rec.record(
            4.0,
            0,
            TraceEventKind::Completed { inst: InstanceId(0), generated: 64, e2e_s: 4.0 },
        );
        rec.record(
            0.5,
            1,
            TraceEventKind::Submitted {
                model: ModelId(0),
                class: SloClass::Batch1,
                mega: false,
                predicted_wait_s: None,
            },
        );
        rec.record(9.0, 1, TraceEventKind::Shed);
        rec.to_jsonl()
    }

    #[test]
    fn report_has_counts_rwt_table_and_timeline() {
        let r = render(&sample_trace(), &ReportOptions { req: None, timelines: 3 });
        assert!(r.contains("trace: 6 events, 2 requests"));
        assert!(r.contains("submitted      2"));
        assert!(r.contains("shed           1"));
        assert!(r.contains("RWT prediction error"));
        // |1.0 - 1.5| = 0.5 for the one joined interactive pair.
        assert!(r.contains("interactive"));
        assert!(r.contains("0.500"));
        // Request 1 never predicted (null) and never pulled: no batch-1 row.
        assert!(!r.contains("batch-1  "));
        // Only request 0 completed, so only its timeline renders.
        assert!(r.contains("request 0 timeline"));
        assert!(!r.contains("request 1 timeline"));
        assert!(r.contains("pulled"));
        assert!(r.contains("inst=0"));
    }

    #[test]
    fn explicit_request_renders_even_without_completion() {
        let r = render(&sample_trace(), &ReportOptions { req: Some(1), timelines: 0 });
        assert!(r.contains("request 1 timeline"));
        assert!(r.contains("shed"));
        let missing = render(&sample_trace(), &ReportOptions { req: Some(42), timelines: 0 });
        assert!(missing.contains("request 42: not in trace"));
    }

    #[test]
    fn payload_renders_key_value_pairs() {
        let line = r#"{"t":1.000000,"req":3,"ev":"pulled","inst":2,"wait_s":0.750000}"#;
        assert_eq!(payload(line, "pulled"), "inst=2 wait_s=0.750000");
    }
}
