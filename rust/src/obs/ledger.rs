//! The RWT-accuracy ledger: did the estimator's predicted waiting time
//! match what requests actually waited?
//!
//! The paper validates its central premise (Fig. 3 / Fig. 18) by
//! comparing predicted request waiting time against measured waiting
//! time. This ledger performs that join online: the engine records the
//! Eq. 2 forecast when a request is submitted and the measured wait when
//! the request is first pulled onto an instance, then reports per-class
//! MAE and p90 absolute error. Strictly record-only — predictions are
//! computed from the same cached views the scheduler already holds and
//! never influence a decision, so enabling the ledger cannot perturb
//! golden digests.

use std::collections::BTreeMap;

use crate::backend::ModelId;
use crate::coordinator::rwt::ProfileTable;
use crate::coordinator::scheduler::InstanceView;
use crate::workload::SloClass;

/// Fleet-level Eq. 2 forecast of a request's waiting time at submit.
///
/// Per-queue RWT (Eqs. 2–3) divides the output tokens queued *ahead* by
/// one instance's token throughput Θ. At submit time the request has no
/// queue position yet, so the fleet-level analogue aggregates every
/// alive view that can serve the model: Θ_fleet = ΣΘ_i and the in-flight
/// batch credit B_fleet = ΣB_i (requests already being served wait ~0).
/// `q_ahead` is the number of same-model requests waiting when this one
/// arrives. Returns `None` when no view serves the model — there is
/// nothing defensible to predict (e.g. before the autoscaler provisions
/// the first instance).
pub fn predict_wait(
    views: &[InstanceView],
    profiles: &ProfileTable,
    model: ModelId,
    class: SloClass,
    mega: bool,
    q_ahead: u64,
) -> Option<f64> {
    let profile = profiles.get(model, class, mega);
    let tok_per_req = profile.mean_tokens_per_req();
    let mut theta = 0.0;
    let mut batch: u64 = 0;
    for v in views {
        if let Some(perf) = v.perf_for.get(&model) {
            theta += perf.steady_throughput(tok_per_req);
            batch += perf.steady_batch(tok_per_req) as u64;
        }
    }
    if theta <= 0.0 {
        return None;
    }
    let pending = q_ahead.saturating_sub(batch);
    Some(pending as f64 * profile.mu_out / theta)
}

/// Per-class accuracy summary row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassError {
    pub class: SloClass,
    /// Joined (predicted, actual) pairs.
    pub n: usize,
    /// Mean absolute error |predicted − actual| in seconds.
    pub mae_s: f64,
    /// 90th percentile of the absolute error in seconds.
    pub p90_s: f64,
}

/// Online predicted-vs-actual join, keyed by request id.
#[derive(Debug, Default)]
pub struct RwtLedger {
    /// Requests predicted at submit, awaiting their first pull.
    pending: BTreeMap<u64, (SloClass, f64)>,
    /// Absolute errors per class, in join order.
    errors: BTreeMap<SloClass, Vec<f64>>,
}

impl RwtLedger {
    /// Record the forecast made when `req` entered the queue.
    pub fn note_predicted(&mut self, req: u64, class: SloClass, predicted_s: f64) {
        self.pending.insert(req, (class, predicted_s));
    }

    /// Record the measured wait when `req` was first pulled. Re-pulls
    /// after eviction don't reach here (the engine joins on the
    /// waiting→running edge only); unknown ids (no prediction was
    /// possible at submit) are ignored.
    pub fn note_actual(&mut self, req: u64, actual_s: f64) {
        if let Some((class, predicted)) = self.pending.remove(&req) {
            self.errors.entry(class).or_default().push((predicted - actual_s).abs());
        }
    }

    /// Joined pairs so far, across classes.
    pub fn joined(&self) -> usize {
        self.errors.values().map(Vec::len).sum()
    }

    /// Per-class MAE/p90 over every joined pair, classes in SLO order.
    pub fn per_class_errors(&self) -> Vec<ClassError> {
        self.errors
            .iter()
            .map(|(&class, errs)| ClassError {
                class,
                n: errs.len(),
                mae_s: crate::util::mean(errs),
                p90_s: crate::util::percentile(errs, 90.0),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{GpuKind, ModelCatalog, PerfModel};
    use crate::coordinator::rwt::WorkloadProfile;

    fn table() -> ProfileTable {
        let mut t = ProfileTable::default();
        t.insert(
            ModelId(0),
            SloClass::Interactive,
            false,
            WorkloadProfile {
                mu_in: 100.0,
                sigma_in: 10.0,
                mu_out: 200.0,
                sigma_out: 20.0,
                max_out: 512.0,
            },
        );
        t
    }

    fn view(id: u32) -> InstanceView {
        let catalog = ModelCatalog::paper();
        let perf = PerfModel::profile(catalog.get(ModelId(0)), GpuKind::A100, 300.0);
        let mut perf_for = std::collections::BTreeMap::new();
        perf_for.insert(ModelId(0), perf);
        InstanceView {
            id: crate::backend::InstanceId(id),
            active_model: Some(ModelId(0)),
            perf_for,
            swap_time: Default::default(),
            executing: None,
        }
    }

    #[test]
    fn no_serving_view_means_no_prediction() {
        let p = predict_wait(&[], &table(), ModelId(0), SloClass::Interactive, false, 10);
        assert_eq!(p, None);
    }

    #[test]
    fn empty_queue_predicts_zero_and_backlog_scales() {
        let views = [view(0)];
        let t = table();
        let empty = predict_wait(&views, &t, ModelId(0), SloClass::Interactive, false, 0).unwrap();
        assert_eq!(empty, 0.0);
        let shallow =
            predict_wait(&views, &t, ModelId(0), SloClass::Interactive, false, 500).unwrap();
        let deep =
            predict_wait(&views, &t, ModelId(0), SloClass::Interactive, false, 5000).unwrap();
        assert!(deep > shallow, "more backlog must predict more wait");
        // Two instances drain the same backlog about twice as fast.
        let two = [view(0), view(1)];
        let halved =
            predict_wait(&two, &t, ModelId(0), SloClass::Interactive, false, 5000).unwrap();
        assert!(halved < deep);
    }

    #[test]
    fn ledger_joins_and_summarizes() {
        let mut l = RwtLedger::default();
        l.note_predicted(1, SloClass::Interactive, 10.0);
        l.note_predicted(2, SloClass::Interactive, 4.0);
        l.note_predicted(3, SloClass::Batch1, 7.0);
        l.note_actual(1, 12.0); // err 2
        l.note_actual(2, 4.0); // err 0
        l.note_actual(3, 3.0); // err 4
        l.note_actual(99, 5.0); // never predicted: ignored
        assert_eq!(l.joined(), 3);
        let rows = l.per_class_errors();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].class, SloClass::Interactive);
        assert_eq!(rows[0].n, 2);
        assert!((rows[0].mae_s - 1.0).abs() < 1e-12);
        assert_eq!(rows[1].class, SloClass::Batch1);
        assert!((rows[1].mae_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn repull_does_not_double_count() {
        let mut l = RwtLedger::default();
        l.note_predicted(1, SloClass::Interactive, 1.0);
        l.note_actual(1, 2.0);
        l.note_actual(1, 50.0); // second pull of the same id: no pending entry
        assert_eq!(l.joined(), 1);
        assert!((l.per_class_errors()[0].mae_s - 1.0).abs() < 1e-12);
    }
}
