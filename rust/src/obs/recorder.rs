//! The flight recorder: an append-only log of per-request lifecycle
//! events, stamped with *simulated* time.
//!
//! Events are recorded on the engine's single event-loop thread, in
//! event-dispatch order — the same order regardless of worker-lane
//! count — so the rendered JSONL is byte-identical across `--threads`
//! and across re-runs of the same seed. The recorder never feeds back
//! into scheduling: it observes, it does not steer.

use crate::backend::{InstanceId, ModelId};
use crate::obs::json;
use crate::workload::SloClass;

/// What happened to a request at one instant of simulated time.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEventKind {
    /// Entered the global queue (or was shed at the door — see `Shed`).
    /// `predicted_wait_s` is the RWT estimator's fleet-level Eq. 2
    /// forecast captured at submit time; `None` when no alive instance
    /// serves the model yet (nothing to predict against).
    Submitted { model: ModelId, class: SloClass, mega: bool, predicted_wait_s: Option<f64> },
    /// First admission onto an instance; `wait_s` is time since submit.
    Pulled { inst: InstanceId, wait_s: f64 },
    /// One chunked-prefill installment of `tokens` prompt tokens.
    PrefillChunk { inst: InstanceId, tokens: u32 },
    /// Prefill finished; `ttft_s` is time since submit.
    FirstToken { inst: InstanceId, ttft_s: f64 },
    /// A decode slice expired at a migration point with `generated`
    /// output tokens produced so far.
    DecodeSlice { inst: InstanceId, generated: u32 },
    /// Evicted to host memory (LSO 2) with `generated` tokens of progress.
    Evicted { inst: InstanceId, generated: u32 },
    /// Re-admitted after eviction; `wait_s` is time since submit.
    Restored { inst: InstanceId, wait_s: f64 },
    /// Displaced by a model swap (LSO 4): the instance switched to
    /// `model` and this request went back to the queue.
    Swapped { inst: InstanceId, model: ModelId },
    /// Dropped by admission control or as unservable.
    Shed,
    /// Finished decoding; `e2e_s` is arrival-to-completion latency.
    Completed { inst: InstanceId, generated: u32, e2e_s: f64 },
}

impl TraceEventKind {
    /// Kebab-case tag written to the `"ev"` field.
    pub fn tag(&self) -> &'static str {
        match self {
            TraceEventKind::Submitted { .. } => "submitted",
            TraceEventKind::Pulled { .. } => "pulled",
            TraceEventKind::PrefillChunk { .. } => "prefill-chunk",
            TraceEventKind::FirstToken { .. } => "first-token",
            TraceEventKind::DecodeSlice { .. } => "decode-slice",
            TraceEventKind::Evicted { .. } => "evicted",
            TraceEventKind::Restored { .. } => "restored",
            TraceEventKind::Swapped { .. } => "swapped",
            TraceEventKind::Shed => "shed",
            TraceEventKind::Completed { .. } => "completed",
        }
    }
}

/// One trace line: time, request, what happened.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub req: u64,
    pub kind: TraceEventKind,
}

impl TraceEvent {
    /// Render as one flat JSON object (no trailing newline).
    pub fn to_json_line(&self) -> String {
        let mut s = format!(
            r#"{{"t":{},"req":{},"ev":"{}""#,
            json::f(self.t),
            self.req,
            self.kind.tag()
        );
        match &self.kind {
            TraceEventKind::Submitted { model, class, mega, predicted_wait_s } => {
                s.push_str(&format!(
                    r#","model":{},"class":"{}","mega":{},"predicted_wait_s":{}"#,
                    model.0,
                    class.name(),
                    mega,
                    json::opt_f(*predicted_wait_s)
                ));
            }
            TraceEventKind::Pulled { inst, wait_s } => {
                s.push_str(&format!(r#","inst":{},"wait_s":{}"#, inst.0, json::f(*wait_s)));
            }
            TraceEventKind::PrefillChunk { inst, tokens } => {
                s.push_str(&format!(r#","inst":{},"tokens":{}"#, inst.0, tokens));
            }
            TraceEventKind::FirstToken { inst, ttft_s } => {
                s.push_str(&format!(r#","inst":{},"ttft_s":{}"#, inst.0, json::f(*ttft_s)));
            }
            TraceEventKind::DecodeSlice { inst, generated } => {
                s.push_str(&format!(r#","inst":{},"generated":{}"#, inst.0, generated));
            }
            TraceEventKind::Evicted { inst, generated } => {
                s.push_str(&format!(r#","inst":{},"generated":{}"#, inst.0, generated));
            }
            TraceEventKind::Restored { inst, wait_s } => {
                s.push_str(&format!(r#","inst":{},"wait_s":{}"#, inst.0, json::f(*wait_s)));
            }
            TraceEventKind::Swapped { inst, model } => {
                s.push_str(&format!(r#","inst":{},"model":{}"#, inst.0, model.0));
            }
            TraceEventKind::Shed => {}
            TraceEventKind::Completed { inst, generated, e2e_s } => {
                s.push_str(&format!(
                    r#","inst":{},"generated":{},"e2e_s":{}"#,
                    inst.0,
                    generated,
                    json::f(*e2e_s)
                ));
            }
        }
        s.push('}');
        s
    }
}

/// Append-only event log for one simulation run.
#[derive(Debug, Default)]
pub struct FlightRecorder {
    events: Vec<TraceEvent>,
}

impl FlightRecorder {
    pub fn record(&mut self, t: f64, req: u64, kind: TraceEventKind) {
        self.events.push(TraceEvent { t, req, kind });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// The whole log as JSONL (one event per line, trailing newline).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::with_capacity(self.events.len() * 80);
        for ev in &self.events {
            out.push_str(&ev.to_json_line());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_are_flat_and_stable() {
        let mut rec = FlightRecorder::default();
        rec.record(
            0.25,
            3,
            TraceEventKind::Submitted {
                model: ModelId(1),
                class: SloClass::Interactive,
                mega: false,
                predicted_wait_s: Some(1.5),
            },
        );
        rec.record(1.0, 3, TraceEventKind::Pulled { inst: InstanceId(0), wait_s: 0.75 });
        rec.record(9.0, 3, TraceEventKind::Shed);
        let jsonl = rec.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(
            lines[0],
            r#"{"t":0.250000,"req":3,"ev":"submitted","model":1,"class":"interactive","mega":false,"predicted_wait_s":1.500000}"#
        );
        assert_eq!(lines[1], r#"{"t":1.000000,"req":3,"ev":"pulled","inst":0,"wait_s":0.750000}"#);
        assert_eq!(lines[2], r#"{"t":9.000000,"req":3,"ev":"shed"}"#);
    }

    #[test]
    fn null_prediction_renders_as_null() {
        let ev = TraceEvent {
            t: 0.0,
            req: 0,
            kind: TraceEventKind::Submitted {
                model: ModelId(0),
                class: SloClass::Batch1,
                mega: true,
                predicted_wait_s: None,
            },
        };
        assert!(ev.to_json_line().contains(r#""predicted_wait_s":null"#));
        assert!(ev.to_json_line().contains(r#""class":"batch-1""#));
    }

    #[test]
    fn identical_logs_render_identical_bytes() {
        let build = || {
            let mut rec = FlightRecorder::default();
            for i in 0..100u64 {
                rec.record(
                    i as f64 * 0.1,
                    i,
                    TraceEventKind::FirstToken { inst: InstanceId(2), ttft_s: 0.3 + i as f64 },
                );
            }
            rec.to_jsonl()
        };
        assert_eq!(build(), build());
    }
}
