//! vLLM baseline (§8, Experiment Setup): FCFS continuous batching onto
//! the statically pinned instance with least load — no reordering,
//! eviction, or swapping.

use std::collections::BTreeMap;

use crate::baselines::policy::{
    pin_executing, place_least_loaded, sorted_groups, PolicyCtx, PolicyPlan, SchedulingPolicy,
};

pub struct FcfsPolicy;

impl SchedulingPolicy for FcfsPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        // FCFS = earliest arrival first (group id breaks Dump-trace ties).
        let groups = sorted_groups(ctx, |g| g.earliest_arrival_s);
        let mut orders = BTreeMap::new();
        let pinned = pin_executing(ctx, &mut orders);
        let pinned_model = ctx.pinned_model;
        place_least_loaded(
            ctx,
            &groups,
            &pinned,
            &mut orders,
            |v, g| pinned_model.get(&v.id) == Some(&g.model),
            |g| g.len() as f64,
        );
        PolicyPlan {
            orders,
            unservable: Vec::new(),
            chunk_tokens: BTreeMap::new(),
            stats: None,
        }
    }
}
