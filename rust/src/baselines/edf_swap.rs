//! The paper's EDF + swap-penalty oracle (the Fig. 5 "Oracle" line).
//!
//! Plain EDF thrashes: deadline order interleaves models, and every
//! transition pays a swap (Insight #3). The oracle keeps EDF's deadline
//! order but *charges the swap before placing*: a candidate instance's
//! predicted finish is its accumulated device time, **plus the model
//! swap-in cost whenever the group's model differs from the queue's
//! tail model**, plus the group's predicted device time — so
//! deadline-adjacent groups of the same model gravitate to the same
//! instance and swap chains collapse, without the full affinity-cluster
//! machinery of QLM's global scheduler. Swap costs come from the
//! instance views' per-model swap-in times (profiled through the
//! engine's `ThetaCache` → perf pipeline — each model's *current
//! storage tier* prices its swap, exactly what the LSO actuator will
//! pay); device time comes from the scheduling core's pricing layer
//! ([`crate::coordinator::sched::pricing::device_time`]).

use std::collections::BTreeMap;

use crate::backend::ModelId;
use crate::baselines::policy::{
    pin_executing, sorted_groups, PolicyCtx, PolicyPlan, SchedulingPolicy,
};
use crate::coordinator::request_group::GroupId;
use crate::coordinator::rwt::RwtEstimator;
use crate::coordinator::sched::pricing::device_time;

pub struct EdfSwapPolicy {
    estimator: RwtEstimator,
}

impl EdfSwapPolicy {
    pub fn new(estimator: RwtEstimator) -> Self {
        EdfSwapPolicy { estimator }
    }
}

impl SchedulingPolicy for EdfSwapPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        // One pass = one pricing epoch, as in the global scheduler.
        self.estimator.begin_epoch();
        let mut orders = BTreeMap::new();
        let pinned = pin_executing(ctx, &mut orders);
        let groups = sorted_groups(ctx, |g| g.deadline());

        // Per-instance tail: (accumulated device time, tail model),
        // seeded from the live model and the pinned executing group —
        // the same seeding the global scheduler's assignment uses.
        let mut tails: Vec<(f64, Option<ModelId>)> = ctx
            .views
            .iter()
            .map(|v| (0.0, v.active_model))
            .collect();
        for (k, v) in ctx.views.iter().enumerate() {
            if let Some(gid) = v.executing {
                if let Some(g) = ctx.groups.get(&gid) {
                    if let Some(perf) = v.perf_for.get(&g.model) {
                        tails[k].0 += device_time(&self.estimator, g, perf);
                        tails[k].1 = Some(g.model);
                    }
                }
            }
        }

        for g in groups {
            if pinned.contains(&g.id) {
                continue;
            }
            // EDF chooses *where*, not *whether*: earliest predicted
            // finish including the swap charge; ties keep the lowest
            // instance index (strict `<`), so plans are deterministic.
            let mut best: Option<(usize, f64)> = None;
            for (k, v) in ctx.views.iter().enumerate() {
                let Some(perf) = v.perf_for.get(&g.model) else {
                    continue;
                };
                let (t, tail_model) = tails[k];
                let swap = if tail_model != Some(g.model) {
                    v.swap_s(g.model)
                } else {
                    0.0
                };
                let finish = t + swap + device_time(&self.estimator, g, perf);
                let better = match best {
                    None => true,
                    Some((_, bf)) => finish < bf,
                };
                if better {
                    best = Some((k, finish));
                }
            }
            if let Some((k, finish)) = best {
                orders.entry(ctx.views[k].id).or_default().push(g.id);
                tails[k] = (finish, Some(g.model));
            }
        }
        PolicyPlan {
            orders,
            unservable: Vec::new(),
            chunk_tokens: BTreeMap::new(),
            stats: None,
        }
    }

    fn group_removed(&mut self, gid: GroupId) {
        self.estimator.forget_group(gid);
    }
}
