//! SJF baseline: shortest-predicted-output-first.
//!
//! The length-prediction family of schedulers (SSJF / slice-level
//! scheduling, arXiv 2406.13511): requests whose *predicted* decode
//! length is shortest run first, which minimizes mean waiting time but
//! is SLO-blind — a long interactive request queues behind every short
//! batch job. Predictions come from the same per-(model, class, mega)
//! output moments the RWT estimator profiles offline (§6), i.e. a
//! class-granular proxy predictor. Placement is least predicted pending
//! tokens over compatible instances.
//!
//! Also the proof that the [`SchedulingPolicy`] seam is cheap: this
//! whole baseline is one self-contained file.

use std::collections::BTreeMap;

use crate::baselines::policy::{
    pin_executing, place_least_loaded, sorted_groups, PolicyCtx, PolicyPlan, SchedulingPolicy,
};
use crate::coordinator::rwt::ProfileTable;

pub struct SjfPolicy {
    profiles: ProfileTable,
}

impl SjfPolicy {
    pub fn new(profiles: ProfileTable) -> Self {
        SjfPolicy { profiles }
    }
}

impl SchedulingPolicy for SjfPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        let profiles = &self.profiles;
        // Shortest predicted output first; arrival breaks prediction
        // ties so equal-length requests stay FCFS.
        let groups = sorted_groups(ctx, |g| {
            (
                profiles.get(g.model, g.class, g.mega).mu_out,
                g.earliest_arrival_s,
            )
        });
        let mut orders = BTreeMap::new();
        let pinned = pin_executing(ctx, &mut orders);
        place_least_loaded(
            ctx,
            &groups,
            &pinned,
            &mut orders,
            |v, g| v.can_serve(g.model),
            |g| profiles.get(g.model, g.class, g.mega).mu_out * g.len() as f64,
        );
        PolicyPlan {
            orders,
            unservable: Vec::new(),
            chunk_tokens: BTreeMap::new(),
            stats: None,
        }
    }
}
