//! QLM / SHEPHERD behind the policy seam: the full global scheduler
//! (RWT estimation + greedy/MILP assignment + the incremental delta
//! path) wrapped as a [`SchedulingPolicy`].

use crate::baselines::policy::{PassStats, PolicyCtx, PolicyPlan, SchedulingPolicy};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::scheduler::{GlobalScheduler, SchedDelta};

/// Global scheduling over request groups (§7), incremental in steady
/// state.
///
/// §Perf: a pass with a small dirty set goes down the cached delta path
/// — only dirty groups are re-priced and re-inserted against the cached
/// plan, clean queues keep their position, and the returned orders are
/// a patch covering only changed instances. Cold caches, view-set
/// changes (`force_full`), and dirtiness above the configured threshold
/// fall back to the full solve, which refreshes the cache.
pub struct QlmPolicy {
    scheduler: GlobalScheduler,
    /// Refresh instance warm sets after a pass (model-swapping LSO on).
    warm_sets: bool,
}

impl QlmPolicy {
    pub fn new(scheduler: GlobalScheduler, warm_sets: bool) -> Self {
        QlmPolicy {
            scheduler,
            warm_sets,
        }
    }
}

impl SchedulingPolicy for QlmPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        let delta_try = if ctx.force_full || !self.scheduler.cfg.incremental {
            None
        } else {
            let dirty: Vec<&RequestGroup> = ctx
                .dirty
                .iter()
                .filter_map(|g| ctx.groups.get(g))
                .collect();
            let delta = SchedDelta {
                dirty,
                removed: ctx.removed.to_vec(),
                total_groups: ctx.groups.len(),
                groups: Some(ctx.groups),
            };
            self.scheduler.try_schedule_delta(&delta, ctx.views, ctx.now)
        };
        let assignment = match delta_try {
            Some(a) => a,
            None => {
                // Full solve. Pass references — the seed cloned every
                // group (and every member list) per invocation.
                let group_refs: Vec<&RequestGroup> = ctx.groups.values().collect();
                self.scheduler.schedule(&group_refs, ctx.views, ctx.now)
            }
        };
        let (memo_hits, memo_misses) = self.scheduler.estimator.memo_stats();
        PolicyPlan {
            orders: assignment.orders,
            unservable: assignment.unservable,
            chunk_tokens: Default::default(),
            stats: Some(PassStats {
                incremental: assignment.stats.incremental,
                groups: assignment.stats.groups,
                dirty: assignment.stats.dirty,
                touched_instances: assignment.stats.touched_instances,
                milp_nodes: assignment.stats.milp_nodes,
                crossings_drained: assignment.stats.crossings_drained,
                memo_hits,
                memo_misses,
            }),
        }
    }

    fn group_removed(&mut self, gid: GroupId) {
        // The group is gone: its memoized service prices go with it.
        self.scheduler.estimator.forget_group(gid);
    }

    fn refreshes_warm_sets(&self) -> bool {
        self.warm_sets
    }
}
