//! The `SchedulingPolicy` seam: one trait between the simulation engine
//! and every queue-ordering strategy.
//!
//! The paper's architecture is explicitly layered — the global scheduler
//! produces virtual-queue orderings, LSOs are "merely action actuators"
//! (§5) — so the engine dispatches each scheduling pass through this
//! trait and applies the returned orders verbatim. Adding a baseline or
//! ablation is a new `impl SchedulingPolicy` file (see `sjf.rs` for the
//! template), not an engine edit.

use std::collections::{BTreeMap, BTreeSet};

use crate::backend::{InstanceId, ModelId};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::scheduler::InstanceView;

/// Everything a policy may read when planning one pass. The engine owns
/// all of it; the context borrows, so a pass never clones the group
/// table (§Perf — the seed deep-cloned every group per invocation).
pub struct PolicyCtx<'a> {
    /// Live request groups (singleton groups for per-request policies).
    pub groups: &'a BTreeMap<GroupId, RequestGroup>,
    /// Scheduler views of the live, non-draining instances.
    pub views: &'a [InstanceView],
    /// Static model pinning for no-swap policies (vLLM baseline).
    pub pinned_model: &'a BTreeMap<InstanceId, ModelId>,
    /// Simulated time of this pass.
    pub now: f64,
    /// Groups whose membership, deadline anchor, or member states
    /// changed since the last pass (engine dirty tracking). Baselines
    /// that rebuild every queue per pass may ignore it.
    pub dirty: &'a BTreeSet<GroupId>,
    /// Groups that drained or dissolved since the last pass.
    pub removed: &'a [GroupId],
    /// The view set changed (failure / provision / drain): any cached
    /// plan is unusable and incremental paths must full-solve.
    pub force_full: bool,
}

/// One pass's plan. `orders` is a *patch*: instances present get their
/// virtual queue replaced, instances absent keep their current order
/// (full rebuilds simply emit every instance). `unservable` lists
/// groups no instance can serve, for the engine's admission path.
/// `chunk_tokens` overrides an instance's per-iteration prefill budget
/// (sliding-window chunk control); instances absent keep their current
/// budget — only chunk-aware policies populate it.
#[derive(Debug, Default)]
pub struct PolicyPlan {
    pub orders: BTreeMap<InstanceId, Vec<GroupId>>,
    pub unservable: Vec<GroupId>,
    pub chunk_tokens: BTreeMap<InstanceId, u32>,
    /// Pass-mix counters for the telemetry sampler (`None` from
    /// baselines that don't track their solve shape). Observability
    /// only — the engine never branches on it.
    pub stats: Option<PassStats>,
}

/// What one scheduler pass did, for the observability layer. A
/// policy-seam mirror of [`crate::coordinator::scheduler::SolveStats`]
/// plus the estimator's memo counters, so the engine can read the pass
/// mix without knowing which policy produced the plan.
#[derive(Debug, Clone, Copy, Default)]
pub struct PassStats {
    /// This pass went down the cached delta path.
    pub incremental: bool,
    /// Live groups at plan time.
    pub groups: usize,
    /// Dirty groups re-inserted by the delta path.
    pub dirty: usize,
    /// Instances whose queue changed this pass.
    pub touched_instances: usize,
    /// Branch-and-bound nodes expanded by MILP refinement.
    pub milp_nodes: usize,
    /// Violation crossings drained by delta-pass re-anchoring.
    pub crossings_drained: usize,
    /// RWT group-service memo hits, cumulative over the run.
    pub memo_hits: u64,
    /// RWT group-service memo misses, cumulative over the run.
    pub memo_misses: u64,
}

/// A queue-ordering strategy, dispatched from the engine's
/// `maybe_schedule`. Implementations may keep cross-pass state (the QLM
/// policy caches its incremental plan); the engine tells them about
/// group removals so caches never leak.
pub trait SchedulingPolicy {
    /// Plan one scheduler pass.
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan;

    /// A group drained or dissolved; drop any cached per-group state.
    fn group_removed(&mut self, _gid: GroupId) {}

    /// Whether the engine should refresh instance warm sets from the
    /// queues this plan touched (QLM's model-swapping path).
    fn refreshes_warm_sets(&self) -> bool {
        false
    }
}

/// Shared helper: pin each view's executing group at the head of its
/// order (no preemptive migration, §5) and return the pinned set.
pub(crate) fn pin_executing(
    ctx: &PolicyCtx<'_>,
    orders: &mut BTreeMap<InstanceId, Vec<GroupId>>,
) -> Vec<GroupId> {
    for v in ctx.views {
        let order = orders.entry(v.id).or_default();
        if let Some(g) = v.executing {
            if ctx.groups.contains_key(&g) {
                order.push(g);
            }
        }
    }
    ctx.views.iter().filter_map(|v| v.executing).collect()
}

/// Shared helper: place `groups` (already sorted by the policy's
/// priority) onto the least-loaded view accepted by `serves`, skipping
/// `pinned` executing groups; `load_of` prices a group's contribution
/// to its queue's load. One implementation behind the EDF/FCFS/SJF
/// baselines so placement semantics (including the `min_by` tie-break
/// and the silently-dropped-when-unserveable rule) cannot diverge.
pub(crate) fn place_least_loaded<S, L>(
    ctx: &PolicyCtx<'_>,
    groups: &[&RequestGroup],
    pinned: &[GroupId],
    orders: &mut BTreeMap<InstanceId, Vec<GroupId>>,
    serves: S,
    load_of: L,
) where
    S: Fn(&InstanceView, &RequestGroup) -> bool,
    L: Fn(&RequestGroup) -> f64,
{
    let mut load: BTreeMap<InstanceId, f64> = ctx.views.iter().map(|v| (v.id, 0.0)).collect();
    for g in groups {
        if pinned.contains(&g.id) {
            continue;
        }
        let best = ctx
            .views
            .iter()
            .filter(|v| serves(v, g))
            .min_by(|a, b| load[&a.id].total_cmp(&load[&b.id]));
        if let Some(v) = best {
            orders.entry(v.id).or_default().push(g.id);
            *load.entry(v.id).or_insert(0.0) += load_of(g);
        }
    }
}

/// Shared helper: live groups sorted by `key` (ascending), group id as
/// the final tie-break so plans are functions of the group *set*, not
/// of the store's insertion or iteration order.
pub(crate) fn sorted_groups<'a, K, F>(ctx: &PolicyCtx<'a>, key: F) -> Vec<&'a RequestGroup>
where
    K: PartialOrd,
    F: Fn(&RequestGroup) -> K,
{
    let mut groups: Vec<&RequestGroup> = ctx.groups.values().collect();
    // audit:allow(hot-path-panic): keys are profiled moments and deadlines,
    // finite by construction; a NaN here is a profiling bug worth crashing on.
    groups.sort_by(|a, b| key(a).partial_cmp(&key(b)).unwrap().then(a.id.cmp(&b.id)));
    groups
}
