//! EDF baseline (§8, Experiment Setup): deadline-sorted singleton
//! groups onto the least-loaded compatible instance. Swaps whenever the
//! head model differs — Insight #3's thrashing case.

use std::collections::BTreeMap;

use crate::baselines::policy::{
    pin_executing, place_least_loaded, sorted_groups, PolicyCtx, PolicyPlan, SchedulingPolicy,
};

pub struct EdfPolicy;

impl SchedulingPolicy for EdfPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        let groups = sorted_groups(ctx, |g| g.deadline());
        let mut orders = BTreeMap::new();
        let pinned = pin_executing(ctx, &mut orders);
        place_least_loaded(
            ctx,
            &groups,
            &pinned,
            &mut orders,
            |v, g| v.can_serve(g.model),
            |g| g.len() as f64,
        );
        PolicyPlan {
            orders,
            unservable: Vec::new(),
            chunk_tokens: BTreeMap::new(),
            stats: None,
        }
    }
}
