//! Load-balancing ablation (Fig. 15's round-robin comparator, and the
//! `-nolb` rows of Figs. 11/14): groups are dealt round-robin to
//! compatible instances with no RWT-informed placement; per-queue
//! ordering keeps deadline order.

use std::collections::BTreeMap;

use crate::backend::InstanceId;
use crate::baselines::policy::{
    pin_executing, sorted_groups, PolicyCtx, PolicyPlan, SchedulingPolicy,
};
use crate::coordinator::request_group::GroupId;

pub struct RoundRobinPolicy;

impl SchedulingPolicy for RoundRobinPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        let groups = sorted_groups(ctx, |g| g.deadline());
        let mut orders: BTreeMap<InstanceId, Vec<GroupId>> = BTreeMap::new();
        let pinned = pin_executing(ctx, &mut orders);
        let views = ctx.views;
        let mut rr = 0usize;
        for g in groups {
            if pinned.contains(&g.id) {
                continue;
            }
            // Next compatible instance in rotation, blind to load.
            let mut placed = false;
            for k in 0..views.len() {
                let v = &views[(rr + k) % views.len()];
                if v.can_serve(g.model) {
                    orders.entry(v.id).or_default().push(g.id);
                    rr = (rr + k + 1) % views.len();
                    placed = true;
                    break;
                }
            }
            if !placed {
                if let Some(v) = views.first() {
                    orders.entry(v.id).or_default().push(g.id);
                }
            }
        }
        PolicyPlan {
            orders,
            unservable: Vec::new(),
            chunk_tokens: BTreeMap::new(),
            stats: None,
        }
    }
}
