//! Priority-class weighted fair queuing (WFQ) baseline.
//!
//! The multi-SLO schedulers in the related work (SLOs-Serve, slice-level
//! load balancing) allocate *device time* across service classes rather
//! than ordering by deadline; this baseline is that family's simplest
//! member. Each SLO class carries a weight (interactive ≫ batch-1 >
//! batch-2) and a **deficit of predicted device time**: within a pass,
//! the global order repeatedly takes the head of the class whose
//! (served + next cost) / weight is smallest — so interactive traffic
//! gets an 8× share of predicted device seconds without starving batch
//! (pure priority would), and batch classes split the rest 2:1. Device
//! time comes from the scheduling core's pricing layer
//! ([`crate::coordinator::sched::pricing::device_time`]): the same mean
//! service + prefill scalar QLM's `GroupPricing` caches. Placement is
//! least-predicted-device-time over compatible instances, and the
//! per-instance order is the WFQ interleave restricted to that
//! instance.
//!
//! SLO-*aware* only through the class weights: unlike QLM it never
//! looks at deadlines, so a long-queued interactive request can still
//! miss while the class as a whole gets its share — which is exactly
//! the ablation the compare table is for.

use std::collections::{BTreeMap, VecDeque};

use crate::baselines::policy::{
    pin_executing, place_least_loaded, sorted_groups, PolicyCtx, PolicyPlan, SchedulingPolicy,
};
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::rwt::RwtEstimator;
use crate::coordinator::sched::pricing::device_time;
use crate::workload::SloClass;

/// Device-time share per class: interactive 8, batch-1 2, batch-2 1.
pub const CLASS_WEIGHTS: [f64; 3] = [8.0, 2.0, 1.0];

fn class_index(c: SloClass) -> usize {
    match c {
        SloClass::Interactive => 0,
        SloClass::Batch1 => 1,
        SloClass::Batch2 => 2,
    }
}

pub struct WfqPolicy {
    estimator: RwtEstimator,
}

impl WfqPolicy {
    pub fn new(estimator: RwtEstimator) -> Self {
        WfqPolicy { estimator }
    }
}

impl SchedulingPolicy for WfqPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        // One pass = one pricing epoch, as in the global scheduler.
        self.estimator.begin_epoch();
        let mut orders = BTreeMap::new();
        let pinned = pin_executing(ctx, &mut orders);

        // Predicted device time per group, priced on the first
        // compatible view (the interleave needs one placement-free
        // scalar per group; placement re-ranks instances below).
        // Groups no view can serve are dropped, matching the
        // least-loaded placement rule shared by every baseline.
        let fifo = sorted_groups(ctx, |g| g.earliest_arrival_s);
        let mut cost: BTreeMap<GroupId, f64> = BTreeMap::new();
        let mut classes: [VecDeque<&RequestGroup>; 3] =
            [VecDeque::new(), VecDeque::new(), VecDeque::new()];
        for g in fifo {
            let Some(perf) = ctx.views.iter().find_map(|v| v.perf_for.get(&g.model)) else {
                continue;
            };
            cost.insert(g.id, device_time(&self.estimator, g, perf));
            classes[class_index(g.class)].push_back(g);
        }

        // Weighted-deficit interleave: always take the class whose
        // normalized finish (served device time + head cost, over its
        // weight) is smallest; ties go to the tighter class (lower
        // index). Deterministic: inputs are id-tiebroken FIFO queues.
        let mut served = [0.0f64; 3];
        let mut order: Vec<&RequestGroup> = Vec::new();
        loop {
            let mut best: Option<(usize, f64)> = None;
            for (c, q) in classes.iter().enumerate() {
                if let Some(g) = q.front() {
                    let key = (served[c] + cost[&g.id]) / CLASS_WEIGHTS[c];
                    let better = match best {
                        None => true,
                        Some((_, bk)) => key < bk,
                    };
                    if better {
                        best = Some((c, key));
                    }
                }
            }
            let Some((c, _)) = best else { break };
            // audit:allow(hot-path-panic): `best` selects only non-empty class queues.
            let g = classes[c].pop_front().unwrap();
            served[c] += cost[&g.id];
            order.push(g);
        }

        // Least-predicted-device-time placement in interleave order.
        place_least_loaded(
            ctx,
            &order,
            &pinned,
            &mut orders,
            |v, g| v.can_serve(g.model),
            |g| cost.get(&g.id).copied().unwrap_or(0.0),
        );
        PolicyPlan {
            orders,
            unservable: Vec::new(),
            chunk_tokens: BTreeMap::new(),
            stats: None,
        }
    }

    fn group_removed(&mut self, gid: GroupId) {
        // Drop the group's memoized device-time prices with it.
        self.estimator.forget_group(gid);
    }
}
