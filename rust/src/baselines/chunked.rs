//! SLO-aware chunked-prefill policy: EDF-ordered singleton groups plus
//! a sliding-window chunk controller that shrinks an instance's
//! per-iteration prefill budget as waiting interactive work approaches
//! its TTFT deadline (the SLO-aware chunked-prefill family). Small
//! chunks keep iterations short, so urgent first tokens and steady
//! decode cadence interleave with a mega prompt's prefill instead of
//! stalling behind it; relaxed queues get the full budget back for
//! prefill efficiency.

use std::collections::BTreeMap;

use crate::baselines::policy::{
    pin_executing, place_least_loaded, sorted_groups, PolicyCtx, PolicyPlan, SchedulingPolicy,
};
use crate::workload::SloClass;

/// Default per-iteration prefill budget (tokens).
pub const DEFAULT_CHUNK_TOKENS: u32 = 256;
/// Default decode-slice length (tokens) — migration-point granularity.
pub const DEFAULT_SLICE_TOKENS: u32 = 64;
/// Floor the controller never shrinks below: chunks shorter than this
/// waste the per-iteration overhead without helping TTFT.
const MIN_CHUNK_TOKENS: u32 = 32;

pub struct ChunkedPolicy {
    base_chunk: u32,
}

impl ChunkedPolicy {
    pub fn new(base_chunk: u32) -> Self {
        ChunkedPolicy {
            base_chunk: base_chunk.max(MIN_CHUNK_TOKENS),
        }
    }

    /// Sliding-window control law: map the tightest interactive TTFT
    /// slack fraction on an instance's queue to that instance's chunk
    /// budget — full budget when relaxed, half under pressure, a
    /// quarter when the deadline is imminent.
    fn chunk_for(&self, min_slack_frac: f64) -> u32 {
        let c = if min_slack_frac <= 0.25 {
            self.base_chunk / 4
        } else if min_slack_frac <= 0.5 {
            self.base_chunk / 2
        } else {
            self.base_chunk
        };
        c.max(MIN_CHUNK_TOKENS)
    }
}

impl SchedulingPolicy for ChunkedPolicy {
    fn plan(&mut self, ctx: &PolicyCtx<'_>) -> PolicyPlan {
        let groups = sorted_groups(ctx, |g| g.deadline());
        let mut orders = BTreeMap::new();
        let pinned = pin_executing(ctx, &mut orders);
        place_least_loaded(
            ctx,
            &groups,
            &pinned,
            &mut orders,
            |v, g| v.can_serve(g.model),
            |g| g.len() as f64,
        );
        // Chunk controller: per instance, the tightest interactive slack
        // among the groups queued on it sets the prefill budget. Every
        // view has an entry in `orders` (pin_executing seeds them), so
        // pressure-free instances relax back to the base budget.
        let mut chunk_tokens = BTreeMap::new();
        for (&inst, order) in &orders {
            let mut min_frac = f64::INFINITY;
            for gid in order {
                let Some(g) = ctx.groups.get(gid) else { continue };
                if g.class != SloClass::Interactive {
                    continue;
                }
                let frac = (g.deadline() - ctx.now) / g.slo.ttft_s.max(1e-9);
                min_frac = min_frac.min(frac);
            }
            let chunk = if min_frac.is_finite() {
                self.chunk_for(min_frac)
            } else {
                self.base_chunk
            };
            chunk_tokens.insert(inst, chunk);
        }
        PolicyPlan {
            orders,
            unservable: Vec::new(),
            chunk_tokens,
            stats: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_law_shrinks_under_pressure() {
        let p = ChunkedPolicy::new(DEFAULT_CHUNK_TOKENS);
        assert_eq!(p.chunk_for(1.0), 256);
        assert_eq!(p.chunk_for(0.5), 128);
        assert_eq!(p.chunk_for(0.25), 64);
        assert_eq!(p.chunk_for(-1.0), 64); // past deadline: still floored
    }

    #[test]
    fn chunk_never_below_floor() {
        let p = ChunkedPolicy::new(40);
        assert_eq!(p.chunk_for(0.1), MIN_CHUNK_TOKENS);
        let tiny = ChunkedPolicy::new(1);
        assert_eq!(tiny.chunk_for(1.0), MIN_CHUNK_TOKENS);
    }
}
