//! Scheduling policies: QLM and the paper's three baselines (§8,
//! Experiment Setup).
//!
//! * **EDF** — requests sorted by SLO deadline; swaps whenever the head
//!   model differs (Insight #3's thrashing); no eviction.
//! * **vLLM** — default FCFS continuous batching; instances statically
//!   pinned to models; no reordering, eviction, or swapping.
//! * **SHEPHERD** — request groups with an ILP-style placement, but built
//!   on the DNN-serving assumptions the paper critiques: fixed-size
//!   batches with deterministic (worst-case) execution-time estimates and
//!   no continuous batching, which overestimates waiting time (Fig. 1).
//! * **QLM** — request groups + RWT estimator + global scheduler + all
//!   four LSOs.

use crate::coordinator::lso::LsoConfig;
use crate::coordinator::scheduler::SolverKind;

/// Which serving policy a simulation runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Full QLM with configurable LSO ablations and solver choice.
    Qlm {
        lso: LsoConfig,
        solver: SolverKind,
    },
    /// Earliest-deadline-first over individual requests.
    Edf,
    /// Vanilla vLLM: FCFS, static model placement.
    VllmFcfs,
    /// SHEPHERD-style: groups + placement, deterministic worst-case
    /// estimates, fixed batches, no eviction.
    Shepherd,
}

impl Policy {
    pub fn qlm() -> Self {
        Policy::Qlm {
            lso: LsoConfig::all(),
            solver: SolverKind::Greedy,
        }
    }

    pub fn qlm_with(lso: LsoConfig) -> Self {
        Policy::Qlm {
            lso,
            solver: SolverKind::Greedy,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Policy::Qlm { lso, .. } => {
                let mut n = "qlm".to_string();
                if !lso.eviction {
                    n.push_str("-noevict");
                }
                if !lso.model_swapping {
                    n.push_str("-noswap");
                }
                if !lso.load_balancing {
                    n.push_str("-nolb");
                }
                if !lso.ordered_pulling {
                    n.push_str("-nopull");
                }
                n
            }
            Policy::Edf => "edf".into(),
            Policy::VllmFcfs => "vllm".into(),
            Policy::Shepherd => "shepherd".into(),
        }
    }

    /// Effective LSO set for the policy (baselines disable LSOs).
    pub fn lso(&self) -> LsoConfig {
        match self {
            Policy::Qlm { lso, .. } => *lso,
            Policy::Edf => LsoConfig {
                ordered_pulling: true,
                eviction: false,
                load_balancing: true,
                model_swapping: true, // EDF swaps eagerly — the thrash case
            },
            Policy::VllmFcfs => LsoConfig {
                ordered_pulling: false,
                eviction: false,
                load_balancing: false,
                model_swapping: false,
            },
            Policy::Shepherd => LsoConfig {
                ordered_pulling: true,
                eviction: false,
                load_balancing: true,
                model_swapping: true,
            },
        }
    }

    /// Does this policy use request groups (vs per-request decisions)?
    pub fn uses_groups(&self) -> bool {
        matches!(self, Policy::Qlm { .. } | Policy::Shepherd)
    }

    /// Does the waiting-time estimate model continuous batching (QLM's
    /// RWT) or assume deterministic worst-case fixed batches (SHEPHERD /
    /// Clockwork-style)?
    pub fn conservative_estimator(&self) -> bool {
        matches!(self, Policy::Shepherd)
    }

    /// Fixed-batch serving (no continuous joining) — SHEPHERD's dynamic
    /// batching operates on whole batches.
    pub fn fixed_batches(&self) -> bool {
        matches!(self, Policy::Shepherd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_distinct() {
        let names: Vec<String> = [
            Policy::qlm(),
            Policy::Edf,
            Policy::VllmFcfs,
            Policy::Shepherd,
        ]
        .iter()
        .map(|p| p.name())
        .collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn ablation_names_encode_flags() {
        assert_eq!(
            Policy::qlm_with(LsoConfig::without_eviction()).name(),
            "qlm-noevict"
        );
        assert_eq!(
            Policy::qlm_with(LsoConfig::without_swapping()).name(),
            "qlm-noswap"
        );
    }

    #[test]
    fn vllm_disables_all_smart_lsos() {
        let l = Policy::VllmFcfs.lso();
        assert!(!l.eviction && !l.model_swapping && !l.load_balancing && !l.ordered_pulling);
    }

    #[test]
    fn shepherd_flags() {
        assert!(Policy::Shepherd.uses_groups());
        assert!(Policy::Shepherd.conservative_estimator());
        assert!(Policy::Shepherd.fixed_batches());
        assert!(!Policy::qlm().fixed_batches());
    }
}
