//! Scheduling policies: QLM and the paper's baselines (§8, Experiment
//! Setup), each behind the [`SchedulingPolicy`] seam the engine
//! dispatches through.
//!
//! * **EDF** — requests sorted by SLO deadline; swaps whenever the head
//!   model differs (Insight #3's thrashing); no eviction.
//! * **vLLM** — default FCFS continuous batching; instances statically
//!   pinned to models; no reordering, eviction, or swapping.
//! * **SJF** — shortest-predicted-output-first (the SSJF /
//!   length-prediction family): minimizes mean wait, SLO-blind.
//! * **WFQ** — priority-class weighted fair queuing: per-SLO-class
//!   weighted deficit over predicted device time (the multi-SLO
//!   share-allocation family — SLO-aware only through class weights).
//! * **EDF+swap** — the paper's Fig. 5 oracle: EDF order, but the model
//!   swap cost is charged before placement so deadline-adjacent
//!   same-model groups co-locate instead of thrashing.
//! * **SHEPHERD** — request groups with an ILP-style placement, but built
//!   on the DNN-serving assumptions the paper critiques: fixed-size
//!   batches with deterministic (worst-case) execution-time estimates and
//!   no continuous batching, which overestimates waiting time (Fig. 1).
//! * **QLM** — request groups + RWT estimator + global scheduler + all
//!   four LSOs.
//!
//! [`Policy`] is the cheap, copyable *name* of a strategy (CLI flags,
//! metrics labels, LSO flag derivation); [`build_policy`] turns it into
//! the stateful [`SchedulingPolicy`] implementation the engine drives.

pub mod chunked;
pub mod edf;
pub mod edf_swap;
pub mod fcfs;
pub mod policy;
pub mod qlm;
pub mod round_robin;
pub mod sjf;
pub mod wfq;

pub use chunked::ChunkedPolicy;
pub use edf::EdfPolicy;
pub use edf_swap::EdfSwapPolicy;
pub use fcfs::FcfsPolicy;
pub use policy::{PassStats, PolicyCtx, PolicyPlan, SchedulingPolicy};
pub use qlm::QlmPolicy;
pub use round_robin::RoundRobinPolicy;
pub use sjf::SjfPolicy;
pub use wfq::WfqPolicy;

use std::sync::Arc;

use crate::coordinator::lso::LsoConfig;
use crate::coordinator::rwt::RwtEstimator;
use crate::coordinator::scheduler::{GlobalScheduler, SchedulerConfig, SolverKind};
use crate::util::WorkerPool;

/// Which serving policy a simulation runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Full QLM with configurable LSO ablations and solver choice.
    Qlm {
        lso: LsoConfig,
        solver: SolverKind,
    },
    /// Earliest-deadline-first over individual requests.
    Edf,
    /// EDF ordering that charges the model-swap cost before placement
    /// (the paper's Fig. 5 oracle).
    EdfSwap,
    /// Vanilla vLLM: FCFS, static model placement.
    VllmFcfs,
    /// Shortest-predicted-output-first over individual requests.
    Sjf,
    /// Priority-class weighted fair queuing over predicted device time.
    Wfq,
    /// SHEPHERD-style: groups + placement, deterministic worst-case
    /// estimates, fixed batches, no eviction.
    Shepherd,
    /// EDF ordering + SLO-aware sliding-window chunked prefill and
    /// decode slices (token-granular iteration scheduling).
    Chunked,
}

impl Policy {
    pub fn qlm() -> Self {
        Policy::Qlm {
            lso: LsoConfig::all(),
            solver: SolverKind::Greedy,
        }
    }

    pub fn qlm_with(lso: LsoConfig) -> Self {
        Policy::Qlm {
            lso,
            solver: SolverKind::Greedy,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Policy::Qlm { lso, .. } => {
                let mut n = "qlm".to_string();
                if !lso.eviction {
                    n.push_str("-noevict");
                }
                if !lso.model_swapping {
                    n.push_str("-noswap");
                }
                if !lso.load_balancing {
                    n.push_str("-nolb");
                }
                if !lso.ordered_pulling {
                    n.push_str("-nopull");
                }
                n
            }
            Policy::Edf => "edf".into(),
            Policy::EdfSwap => "edf-swap".into(),
            Policy::VllmFcfs => "vllm".into(),
            Policy::Sjf => "sjf".into(),
            Policy::Wfq => "wfq".into(),
            Policy::Shepherd => "shepherd".into(),
            Policy::Chunked => "chunked".into(),
        }
    }

    /// Effective LSO set for the policy (baselines disable LSOs).
    pub fn lso(&self) -> LsoConfig {
        match self {
            Policy::Qlm { lso, .. } => *lso,
            Policy::Edf => LsoConfig {
                ordered_pulling: true,
                eviction: false,
                load_balancing: true,
                model_swapping: true, // EDF swaps eagerly — the thrash case
            },
            Policy::Sjf => LsoConfig {
                ordered_pulling: true,
                eviction: false,
                load_balancing: true,
                model_swapping: true,
            },
            // WFQ and the EDF+swap oracle swap (their whole point is
            // pricing the swap), balance load, and pull in order; no
            // eviction — they are ordering baselines, not full QLM.
            Policy::Wfq | Policy::EdfSwap => LsoConfig {
                ordered_pulling: true,
                eviction: false,
                load_balancing: true,
                model_swapping: true,
            },
            Policy::VllmFcfs => LsoConfig {
                ordered_pulling: false,
                eviction: false,
                load_balancing: false,
                model_swapping: false,
            },
            Policy::Shepherd => LsoConfig {
                ordered_pulling: true,
                eviction: false,
                load_balancing: true,
                model_swapping: true,
            },
            // Chunked migrates at slice boundaries through the evict /
            // restore KV path, so eviction stays on for the engine's
            // slice-migration machinery (not for QLM's head-of-queue
            // eviction LSO — the policy never orders evictions itself).
            Policy::Chunked => LsoConfig {
                ordered_pulling: true,
                eviction: true,
                load_balancing: true,
                model_swapping: true,
            },
        }
    }

    /// Does this policy use request groups (vs per-request decisions)?
    pub fn uses_groups(&self) -> bool {
        matches!(self, Policy::Qlm { .. } | Policy::Shepherd)
    }

    /// Does the waiting-time estimate model continuous batching (QLM's
    /// RWT) or assume deterministic worst-case fixed batches (SHEPHERD /
    /// Clockwork-style)?
    pub fn conservative_estimator(&self) -> bool {
        matches!(self, Policy::Shepherd)
    }

    /// Fixed-batch serving (no continuous joining) — SHEPHERD's dynamic
    /// batching operates on whole batches.
    pub fn fixed_batches(&self) -> bool {
        matches!(self, Policy::Shepherd)
    }
}

/// Turn a policy name into the stateful [`SchedulingPolicy`] the engine
/// dispatches through. `sched_cfg` and `estimator` configure the QLM
/// global scheduler; per-request baselines take what they need from the
/// estimator (SJF reads its profile table, WFQ and the EDF+swap oracle
/// price device time through it) and drop the rest. `pool` is the
/// engine's persistent worker pool — handed to the global scheduler so
/// the repricing walk shares the view refresh's parked workers.
/// `chunk_tokens` seeds the chunked policy's base prefill budget
/// (ignored by every other policy).
pub fn build_policy(
    policy: Policy,
    sched_cfg: SchedulerConfig,
    estimator: RwtEstimator,
    pool: Arc<WorkerPool>,
    chunk_tokens: Option<u32>,
) -> Box<dyn SchedulingPolicy> {
    match policy {
        Policy::VllmFcfs => Box::new(FcfsPolicy),
        Policy::Edf => Box::new(EdfPolicy),
        Policy::Chunked => Box::new(ChunkedPolicy::new(
            chunk_tokens.unwrap_or(chunked::DEFAULT_CHUNK_TOKENS),
        )),
        Policy::EdfSwap => Box::new(EdfSwapPolicy::new(estimator)),
        Policy::Sjf => Box::new(SjfPolicy::new(estimator.profiles.clone())),
        Policy::Wfq => Box::new(WfqPolicy::new(estimator)),
        // Load-balancing ablation: groups exist but placement is blind.
        Policy::Qlm { lso, .. } if !lso.load_balancing => Box::new(RoundRobinPolicy),
        // QLM proper and SHEPHERD (whose conservatism lives in the
        // estimator profiles and the fixed-batch agent, not the solver).
        _ => Box::new(QlmPolicy::new(
            GlobalScheduler::with_pool(sched_cfg, estimator, pool),
            policy.lso().model_swapping,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_distinct() {
        let names: Vec<String> = [
            Policy::qlm(),
            Policy::Edf,
            Policy::EdfSwap,
            Policy::VllmFcfs,
            Policy::Sjf,
            Policy::Wfq,
            Policy::Shepherd,
            Policy::Chunked,
        ]
        .iter()
        .map(|p| p.name())
        .collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn wfq_and_edf_swap_are_per_request_swap_aware_policies() {
        for p in [Policy::Wfq, Policy::EdfSwap] {
            assert!(!p.uses_groups(), "{}", p.name());
            assert!(!p.conservative_estimator(), "{}", p.name());
            assert!(!p.fixed_batches(), "{}", p.name());
            let l = p.lso();
            assert!(l.model_swapping, "{} must be able to swap", p.name());
            assert!(!l.eviction, "{} is an ordering baseline", p.name());
        }
        assert_eq!(Policy::Wfq.name(), "wfq");
        assert_eq!(Policy::EdfSwap.name(), "edf-swap");
    }

    #[test]
    fn ablation_names_encode_flags() {
        assert_eq!(
            Policy::qlm_with(LsoConfig::without_eviction()).name(),
            "qlm-noevict"
        );
        assert_eq!(
            Policy::qlm_with(LsoConfig::without_swapping()).name(),
            "qlm-noswap"
        );
    }

    #[test]
    fn vllm_disables_all_smart_lsos() {
        let l = Policy::VllmFcfs.lso();
        assert!(!l.eviction && !l.model_swapping && !l.load_balancing && !l.ordered_pulling);
    }

    #[test]
    fn shepherd_flags() {
        assert!(Policy::Shepherd.uses_groups());
        assert!(Policy::Shepherd.conservative_estimator());
        assert!(Policy::Shepherd.fixed_batches());
        assert!(!Policy::qlm().fixed_batches());
    }

    #[test]
    fn sjf_is_a_per_request_policy() {
        assert!(!Policy::Sjf.uses_groups());
        assert!(!Policy::Sjf.conservative_estimator());
        assert!(!Policy::Sjf.fixed_batches());
        assert_eq!(Policy::Sjf.name(), "sjf");
    }

    #[test]
    fn chunked_is_a_per_request_slice_migrating_policy() {
        assert!(!Policy::Chunked.uses_groups());
        assert!(!Policy::Chunked.conservative_estimator());
        assert!(!Policy::Chunked.fixed_batches());
        assert_eq!(Policy::Chunked.name(), "chunked");
        let l = Policy::Chunked.lso();
        assert!(l.eviction, "slice migration rides the evict/restore path");
        assert!(l.load_balancing);
    }
}
