//! `qlm audit` — the in-repo static-analysis pass that machine-enforces
//! the determinism, concurrency, and architecture invariants the golden
//! gates depend on.
//!
//! QLM's reproducibility claims (run-to-run golden digests, threads ≡
//! serial, `qlm compare` digest equality) only hold because scheduling
//! code obeys invariants that used to live in README prose: BTree-only
//! collections, no wall clock in sim logic, threads and `unsafe`
//! confined to `util/pool.rs`/`util/par.rs`, one pricing path, one
//! comparator. This module is a zero-dependency, comment/string/char-
//! literal-aware lexer ([`lexer`]) plus a rule engine ([`rules`]) that
//! fails the build when one of those invariants is broken. It runs
//! three ways:
//!
//! * `qlm audit` — the CLI (machine-readable output, nonzero exit);
//! * `tests/audit.rs` — an integration test over `CARGO_MANIFEST_DIR`,
//!   so tier-1 `cargo test` itself enforces the invariants;
//! * a dedicated CI job (`.github/workflows/ci.yml`).
//!
//! Violations a human has judged acceptable are waived in place with
//! `// audit:allow(<rule>): <reason>` — the reason is mandatory (a
//! waiver without one is itself a violation) and `qlm audit --list`
//! counts waivers per rule so creep shows up in PR diffs.

pub mod lexer;
mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Every invariant the audit enforces. Rule ids (kebab-case) are the
/// public interface: they appear in waivers, `--explain`, and CI logs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    HashCollections,
    WallClock,
    ThreadConfinement,
    UnsafeConfinement,
    SafetyComment,
    HotPathPanic,
    HotLoopAlloc,
    PricingSeam,
    ImportLayering,
    WaiverHygiene,
}

/// Static metadata for one rule: id, invariant group, one-line summary,
/// and the long `--explain` text.
pub struct RuleInfo {
    pub rule: Rule,
    pub id: &'static str,
    pub group: &'static str,
    pub summary: &'static str,
    pub explain: &'static str,
}

/// The rule table, in reporting order.
pub const RULES: [RuleInfo; 10] = [
    RuleInfo {
        rule: Rule::HashCollections,
        id: "hash-collections",
        group: "determinism",
        summary: "no HashMap/HashSet in sim/, coordinator/, baselines/, capacity/, \
                  workload/, metrics/, figures/, obs/",
        explain: "Scheduling code must use BTreeMap/BTreeSet (or Vec/slab) only. \
                  HashMap and HashSet iterate in RandomState order, which differs per \
                  process: any hash iteration that touches a plan, a float accumulation, \
                  or an event order silently breaks the golden-digest suites, the \
                  threads==serial gates, and `qlm compare` digest equality. The rule \
                  flags the *names* HashMap/HashSet anywhere in the restricted \
                  directories — which include the reporting layers (metrics/, figures/, \
                  obs/), whose rendered tables and JSONL exports must also be \
                  byte-stable — imports included, so a lookup-only map still needs an \
                  explicit waiver arguing why its iteration order can never leak.\n\
                  Fix: switch to BTreeMap/BTreeSet (all QLM key types are Ord), or \
                  waive with `// audit:allow(hash-collections): <why order cannot leak>`.",
    },
    RuleInfo {
        rule: Rule::WallClock,
        id: "wall-clock",
        group: "determinism",
        summary: "no Instant/SystemTime (or ::now() calls) in deterministic code",
        explain: "Simulated time comes from the event clock; real time is only a \
                  measurement. A wall-clock read inside scheduling logic makes plans a \
                  function of host speed and destroys replay. The rule flags the type \
                  names Instant/SystemTime and any `::now(` call in sim/, coordinator/, \
                  baselines/, capacity/, workload/, metrics/, figures/, obs/ (the \
                  reporting layers stamp simulated time only). The sanctioned capture \
                  sites — the scheduler-overhead stopwatch in sim/engine.rs, the \
                  estimator-latency probe in figures/estimator.rs, and the CLI layer in \
                  main.rs — carry waivers; runtime/ measures real hardware and is \
                  outside the rule's scope entirely.\n\
                  Fix: thread the event-clock time in as a parameter, or waive with \
                  `// audit:allow(wall-clock): <why this read cannot affect a plan>`.",
    },
    RuleInfo {
        rule: Rule::ThreadConfinement,
        id: "thread-confinement",
        group: "concurrency",
        summary: "thread::spawn / thread::scope only in util/pool.rs + util/par.rs",
        explain: "All parallelism flows through the persistent WorkerPool \
                  (util/pool.rs) or the scoped baseline primitive (util/par.rs), whose \
                  index-ordered chunking is what makes threaded runs bit-identical to \
                  serial. A stray thread::spawn elsewhere would create a second, \
                  unaudited concurrency seam with its own ordering behavior.\n\
                  Fix: route the parallel pass through WorkerPool::run_chunks_mut (or \
                  util::par_chunks_mut), or waive with \
                  `// audit:allow(thread-confinement): <reason>`.",
    },
    RuleInfo {
        rule: Rule::UnsafeConfinement,
        id: "unsafe-confinement",
        group: "concurrency",
        summary: "`unsafe` only in util/pool.rs",
        explain: "The one unsafe construction in the codebase is the WorkerPool's \
                  borrow-erasing job pointer, whose soundness argument (the submitter \
                  blocks until every chunk drains) is documented, tested, and checked \
                  under Miri/TSan in CI. Keeping `unsafe` confined to that file keeps \
                  the soundness surface reviewable; the crate root also carries \
                  #![deny(unsafe_op_in_unsafe_fn)] so unsafe operations are explicit \
                  even inside unsafe fns.\n\
                  Fix: express the code safely, or — for a new, argued-for site — waive \
                  with `// audit:allow(unsafe-confinement): <reason>` plus a SAFETY: \
                  comment (the safety-comment rule still applies).",
    },
    RuleInfo {
        rule: Rule::SafetyComment,
        id: "safety-comment",
        group: "concurrency",
        summary: "every `unsafe` must carry a `// SAFETY:` comment",
        explain: "Each unsafe block, fn, impl, or fn-pointer type must state its \
                  soundness argument in a `// SAFETY:` comment on the same line or in \
                  the contiguous comment block directly above (the clippy \
                  undocumented_unsafe_blocks convention). An unargued unsafe is \
                  unreviewable.\n\
                  Fix: write the SAFETY: comment; there is rarely a reason to waive \
                  this one.",
    },
    RuleInfo {
        rule: Rule::HotPathPanic,
        id: "hot-path-panic",
        group: "architecture",
        summary: "no panic!/.unwrap()/.expect( in non-test sim/, coordinator/, baselines/",
        explain: "A panic in the scheduling hot path kills the whole serving \
                  coordinator. Production paths must either handle the None/Err arm or \
                  carry a waiver arguing why the invariant cannot break (slab ids \
                  handed out by the same map, NaN-free floats, etc.). #[cfg(test)] \
                  items are exempt — tests should assert loudly.\n\
                  Fix: handle the failure arm (match/if-let/unwrap_or_else), replace \
                  float partial_cmp().unwrap() with total_cmp, or waive with \
                  `// audit:allow(hot-path-panic): <why this cannot fire>`.",
    },
    RuleInfo {
        rule: Rule::HotLoopAlloc,
        id: "hot-loop-alloc",
        group: "architecture",
        summary: "no Vec::new/.to_vec()/.clone()/.collect() inside `audit:hot-loop` \
                  extents in sim/ + coordinator/",
        explain: "The per-pass loops annotated `// audit:hot-loop` (the repricing \
                  walk, the view digest, the timer-wheel drain) run per event or per \
                  scheduler pass at megascale request counts, where a stray \
                  per-iteration allocation dominates the profile (`cargo bench -- \
                  hot_alloc` counts them). The rule is a heuristic: it flags the \
                  allocation-shaped tokens Vec::new / .to_vec() / .clone() / \
                  .collect() on any line inside a marked brace extent in sim/ and \
                  coordinator/. #[cfg(test)] items are exempt.\n\
                  Fix: hoist the allocation out of the loop (reused scratch buffer, \
                  std::mem::take, in-place clear+extend), or — for a judged-\
                  acceptable site — waive with \
                  `// audit:allow(hot-loop-alloc): <why this allocation is fine>`.",
    },
    RuleInfo {
        rule: Rule::PricingSeam,
        id: "pricing-seam",
        group: "architecture",
        summary: "scoring/affinity internals named only inside the sched core",
        explain: "There is exactly one scoring path (sched/pricing.rs: price_group / \
                  append_score / reprice_queue, over rwt.rs::group_service) and one \
                  ordering comparator (sched/plan.rs: affinity_cmp / affinity_order). \
                  Policies and the engine consume them through the GlobalScheduler \
                  facade; naming those internals anywhere else (the facade \
                  coordinator/scheduler.rs excepted) would fork the pricing logic and \
                  let two call sites drift apart — the exact bug class the PR-5 \
                  one-price/one-comparator invariant exists to prevent.\n\
                  Fix: call through GlobalScheduler / pricing's public helpers, or \
                  waive with `// audit:allow(pricing-seam): <reason>`.",
    },
    RuleInfo {
        rule: Rule::ImportLayering,
        id: "import-layering",
        group: "architecture",
        summary: "cross-module `crate::` imports must respect the layer table \
                  (workload/ never imports coordinator/, sim/ never imports figures/, …)",
        explain: "The module graph is layered on purpose: util/ sits below \
                  everything, workload/ produces traces without knowing who consumes \
                  them, coordinator/ schedules without knowing it is being simulated, \
                  and the reporting layers (metrics/, figures/, obs/) sit on top. The \
                  sharded-queue work leans on this — shard routing stays correct only \
                  because nothing below coordinator/ can reach into its internals, and \
                  streamed trace generation only composes because workload/ has no \
                  back-edge into the scheduler it feeds. The rule scans the code view \
                  for `crate::<module>` paths and flags any edge the per-directory \
                  forbidden table names (e.g. workload/ -> coordinator/, sim/ -> \
                  figures/, metrics/ -> sim/). Directories outside the table \
                  (backend/, runtime/, solver/, audit/) and the tests/ tree are \
                  unconstrained.\n\
                  Fix: move the shared type down a layer (usually into backend/ or \
                  util/), invert the dependency, or waive with \
                  `// audit:allow(import-layering): <why this edge is sound>`.",
    },
    RuleInfo {
        rule: Rule::WaiverHygiene,
        id: "waiver-hygiene",
        group: "meta",
        summary: "every audit:allow waiver needs a known rule id and a `: reason`",
        explain: "`// audit:allow(<rule>): <reason>` is the only escape hatch, so the \
                  escape hatch itself is checked: the rule id must exist and the \
                  justification must be non-empty. A malformed waiver is reported and \
                  suppresses nothing, and this rule cannot itself be waived.\n\
                  Fix: spell the rule id exactly as in `qlm audit --list` and write the \
                  reason after `): `.",
    },
];

impl Rule {
    /// The kebab-case id used in waivers, `--explain`, and output.
    pub fn id(self) -> &'static str {
        self.info().id
    }

    /// Look a rule up by its kebab-case id.
    pub fn from_id(id: &str) -> Option<Rule> {
        RULES.iter().find(|r| r.id == id).map(|r| r.rule)
    }

    /// Static metadata for this rule.
    pub fn info(self) -> &'static RuleInfo {
        match RULES.iter().find(|r| r.rule == self) {
            Some(info) => info,
            // RULES covers every variant by construction (unit-tested).
            None => &RULES[0],
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One rule violation at one source line.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: Rule,
    /// Path relative to the audited root, with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// What fired, human-readable.
    pub note: String,
    /// The offending source line, trimmed.
    pub snippet: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}\t{}:{}\t{}\t{}",
            self.rule, self.file, self.line, self.note, self.snippet
        )
    }
}

/// One well-formed `audit:allow` annotation (tracked so `--list` can
/// expose waiver creep).
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
}

/// Everything one audit pass learned about the tree.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub violations: Vec<Violation>,
    pub waivers: Vec<Waiver>,
    /// Files scanned (observability: an empty-tree "pass" is a bug).
    pub files_scanned: usize,
}

/// Scan a single file's source as if it lived at `rel` (path relative
/// to the crate root, `/` separators). This is the per-file entry point
/// `run` uses; the fixture tests call it directly with pretend paths.
pub fn scan_source(rel: &str, source: &str) -> Vec<Violation> {
    rules::scan_lines(rel, source).0
}

/// Like [`scan_source`], but also returns the well-formed waivers.
pub fn scan_source_report(rel: &str, source: &str) -> (Vec<Violation>, Vec<Waiver>) {
    rules::scan_lines(rel, source)
}

/// Audit the crate rooted at `root` (the directory containing `src/`
/// and `tests/`, i.e. `CARGO_MANIFEST_DIR`). Scans `src/**/*.rs` and
/// `tests/**/*.rs`, skipping `tests/audit_fixtures/` (those files are
/// violations on purpose). Deterministic: files are visited in sorted
/// path order.
pub fn run_report(root: &Path) -> io::Result<AuditReport> {
    let mut files = Vec::new();
    for base in ["src", "tests"] {
        let dir = root.join(base);
        if dir.is_dir() {
            collect_rs_files(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut report = AuditReport::default();
    for path in files {
        let rel = match path.strip_prefix(root) {
            Ok(r) => r.to_string_lossy().replace('\\', "/"),
            Err(_) => path.to_string_lossy().replace('\\', "/"),
        };
        if rel.starts_with("tests/audit_fixtures/") {
            continue;
        }
        let source = fs::read_to_string(&path)?;
        let (violations, waivers) = rules::scan_lines(&rel, &source);
        report.violations.extend(violations);
        report.waivers.extend(waivers);
        report.files_scanned += 1;
    }
    Ok(report)
}

/// Audit the crate rooted at `root`; returns only the violations.
pub fn run(root: &Path) -> io::Result<Vec<Violation>> {
    Ok(run_report(root)?.violations)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_table_covers_every_variant_with_unique_ids() {
        let all = [
            Rule::HashCollections,
            Rule::WallClock,
            Rule::ThreadConfinement,
            Rule::UnsafeConfinement,
            Rule::SafetyComment,
            Rule::HotPathPanic,
            Rule::HotLoopAlloc,
            Rule::PricingSeam,
            Rule::ImportLayering,
            Rule::WaiverHygiene,
        ];
        assert_eq!(RULES.len(), all.len());
        for rule in all {
            let info = rule.info();
            assert_eq!(info.rule, rule, "info() must resolve {rule}");
            assert_eq!(Rule::from_id(info.id), Some(rule));
            assert!(!info.summary.is_empty() && !info.explain.is_empty());
        }
        let mut ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), RULES.len(), "rule ids must be unique");
    }

    #[test]
    fn violation_display_is_machine_readable() {
        let v = Violation {
            rule: Rule::WallClock,
            file: "src/sim/engine.rs".to_string(),
            line: 7,
            note: "wall-clock `::now()` call".to_string(),
            snippet: "let t = Instant::now();".to_string(),
        };
        let line = v.to_string();
        assert!(line.starts_with("wall-clock\tsrc/sim/engine.rs:7\t"));
        assert_eq!(line.split('\t').count(), 4);
    }
}
