//! The audit rule engine: path scoping, `#[cfg(test)]` extent
//! detection, `audit:allow` waiver parsing, and the per-line token
//! checks for every [`Rule`].
//!
//! All checks run over the lexer's *code* view ([`super::lexer`]), so
//! comments and string literals can never fire a rule; waivers and
//! `SAFETY:` annotations are read from the *comment* view.

use super::lexer::{has_token, strip, LineInfo};
use super::{Rule, Violation, Waiver};

/// Directories whose scheduling logic must stay deterministic
/// (hash-collections + wall-clock rules).
const DET_DIRS: [&str; 8] = [
    "src/sim/",
    "src/coordinator/",
    "src/baselines/",
    "src/capacity/",
    "src/workload/",
    "src/metrics/",
    "src/figures/",
    "src/obs/",
];

/// The scheduling hot path (hot-path-panic rule).
const HOT_DIRS: [&str; 3] = ["src/sim/", "src/coordinator/", "src/baselines/"];

/// Directories where `// audit:hot-loop` extents are honored
/// (hot-loop-alloc rule): the simulation core and the scheduler.
const ALLOC_DIRS: [&str; 2] = ["src/sim/", "src/coordinator/"];

/// Allocation-shaped tokens the hot-loop-alloc rule flags inside a
/// marked extent. Heuristic by design: `.collect::<` catches the
/// turbofish spelling the plain `.collect(` pattern misses.
const ALLOC_PATTERNS: [&str; 5] =
    ["Vec::new(", ".to_vec()", ".clone()", ".collect(", ".collect::<"];

/// The only files allowed to spawn or scope OS threads.
const THREAD_OK: [&str; 2] = ["src/util/pool.rs", "src/util/par.rs"];

/// The only file allowed to contain `unsafe`.
const UNSAFE_OK: &str = "src/util/pool.rs";

/// The scheduling core: the only place scoring/affinity internals may
/// be named (`src/coordinator/sched/` is a prefix, the rest are files —
/// `rwt.rs` hosts the estimator the scoring path is built on and
/// `scheduler.rs` is the façade that re-exports the seam).
const SEAM_PREFIX: &str = "src/coordinator/sched/";
const SEAM_FILES: [&str; 2] = ["src/coordinator/rwt.rs", "src/coordinator/scheduler.rs"];

/// The layer table (import-layering rule): for each constrained
/// directory, the top-level modules it must never import via a
/// `crate::<module>` path. Directories absent from the table
/// (backend/, runtime/, solver/, audit/, main.rs, tests/) are
/// unconstrained. The table encodes chosen forbidden edges, not a
/// strict total order — e.g. workload/ may size scenarios off sim/
/// fleet shapes, but must never reach into the coordinator it feeds.
const LAYER_EDGES: [(&str, &[&str]); 8] = [
    (
        "src/util/",
        &["workload", "coordinator", "sim", "baselines", "capacity", "metrics", "figures", "obs"],
    ),
    ("src/workload/", &["coordinator", "metrics", "figures", "obs"]),
    ("src/coordinator/", &["sim", "baselines", "capacity", "metrics", "figures", "obs"]),
    ("src/baselines/", &["sim", "capacity", "metrics", "figures", "obs"]),
    ("src/metrics/", &["sim", "baselines", "capacity", "figures", "obs"]),
    ("src/capacity/", &["baselines", "metrics", "figures", "obs"]),
    ("src/sim/", &["figures"]),
    ("src/obs/", &["sim", "capacity", "figures", "metrics"]),
];

/// Identifiers that constitute the scoring/affinity seam.
const SEAM_TOKENS: [&str; 6] = [
    "price_group",
    "append_score",
    "reprice_queue",
    "group_service",
    "affinity_cmp",
    "affinity_order",
];

fn in_any(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

/// The top-level module each `crate::<ident>` path on one code line
/// points at. Token-boundary-checked on the left so `my_crate::x`
/// (a different crate) never matches.
fn crate_targets(code: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut rest = code;
    while let Some(pos) = rest.find("crate::") {
        let bounded = match rest[..pos].bytes().last() {
            None => true,
            Some(b) => !(b.is_ascii_alphanumeric() || b == b'_'),
        };
        let after = &rest[pos + "crate::".len()..];
        let end = after
            .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
            .unwrap_or(after.len());
        if bounded && end > 0 {
            out.push(&after[..end]);
        }
        rest = &after[end..];
    }
    out
}

/// Mark every line that belongs to a `#[cfg(test)]` item (the attribute
/// line through the close of the item's brace block). Operates on the
/// code view, so braces inside literals or comments cannot desync the
/// depth count.
fn test_extents(lines: &[LineInfo]) -> Vec<bool> {
    let mut test = vec![false; lines.len()];
    let mut li = 0;
    while li < lines.len() {
        let squashed: String = lines[li].code.chars().filter(|c| !c.is_whitespace()).collect();
        if !squashed.contains("#[cfg(test)]") {
            li += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut lj = li;
        while lj < lines.len() {
            for c in lines[lj].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            lj += 1;
        }
        let end = lj.min(lines.len() - 1);
        for t in test.iter_mut().take(end + 1).skip(li) {
            *t = true;
        }
        li = lj + 1;
    }
    test
}

/// Mark every line inside an `// audit:hot-loop` brace extent: the
/// marker's own line when it carries code (trailing marker on the loop
/// header), else the next code-carrying line, through the close of that
/// line's brace block. Same comment-aware depth counting as
/// [`test_extents`], so braces in literals or comments cannot desync it.
fn hot_loop_extents(lines: &[LineInfo]) -> Vec<bool> {
    let mut hot = vec![false; lines.len()];
    let mut li = 0;
    while li < lines.len() {
        if !lines[li].comment.contains("audit:hot-loop") {
            li += 1;
            continue;
        }
        let mut start = li;
        while start < lines.len() && lines[start].code.trim().is_empty() {
            start += 1;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut lj = start;
        while lj < lines.len() {
            for c in lines[lj].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            lj += 1;
        }
        let end = lj.min(lines.len() - 1);
        for h in hot.iter_mut().take(end + 1).skip(start) {
            *h = true;
        }
        li = lj + 1;
    }
    hot
}

/// A parsed `audit:allow(<rule>): <reason>` annotation (well-formed or
/// not — hygiene problems are reported as violations by the caller).
enum ParsedWaiver {
    Ok(Rule),
    UnknownRule(String),
    MissingReason(String),
}

/// Find an `audit:allow` annotation in one comment line. Only a
/// kebab-case id between the parens makes the text a waiver at all —
/// prose quoting the syntax with a `<rule>` placeholder is ignored,
/// while a waiver naming a misspelled-but-well-formed rule is still
/// reported by the hygiene rule.
fn parse_waiver(comment: &str) -> Option<ParsedWaiver> {
    let start = comment.find("audit:allow(")?;
    let rest = &comment[start + "audit:allow(".len()..];
    let close = rest.find(')')?;
    let rule_id = &rest[..close];
    let kebab = !rule_id.is_empty()
        && rule_id
            .bytes()
            .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-');
    if !kebab {
        return None;
    }
    let after = &rest[close + 1..];
    let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
    match Rule::from_id(rule_id) {
        None => Some(ParsedWaiver::UnknownRule(rule_id.to_string())),
        Some(_) if reason.is_empty() => Some(ParsedWaiver::MissingReason(rule_id.to_string())),
        Some(rule) => Some(ParsedWaiver::Ok(rule)),
    }
}

/// Scan one file (already split by the lexer) under its repo-relative
/// path, returning violations plus every well-formed waiver (waiver
/// counts feed `qlm audit --list`).
pub(super) fn scan_lines(rel: &str, source: &str) -> (Vec<Violation>, Vec<Waiver>) {
    let lines = strip(source);
    let original: Vec<&str> = source.lines().collect();
    let test = test_extents(&lines);
    let mut violations = Vec::new();
    let mut waivers = Vec::new();

    // Pass 1: collect waivers. A waiver on a code-carrying line covers
    // that line; a waiver on a comment-only line covers the next line
    // that carries code.
    let mut covered: Vec<(Rule, usize)> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let parsed = match parse_waiver(&line.comment) {
            Some(p) => p,
            None => continue,
        };
        match parsed {
            ParsedWaiver::UnknownRule(id) => violations.push(Violation {
                rule: Rule::WaiverHygiene,
                file: rel.to_string(),
                line: idx + 1,
                note: format!("waiver names unknown rule `{id}`"),
                snippet: snippet(&original, idx),
            }),
            ParsedWaiver::MissingReason(id) => violations.push(Violation {
                rule: Rule::WaiverHygiene,
                file: rel.to_string(),
                line: idx + 1,
                note: format!("waiver for `{id}` has no `: <reason>` justification"),
                snippet: snippet(&original, idx),
            }),
            ParsedWaiver::Ok(rule) => {
                let mut target = idx;
                if lines[idx].code.trim().is_empty() {
                    let mut j = idx + 1;
                    while j < lines.len() && lines[j].code.trim().is_empty() {
                        j += 1;
                    }
                    target = j;
                }
                waivers.push(Waiver {
                    rule,
                    file: rel.to_string(),
                    line: idx + 1,
                });
                covered.push((rule, target));
            }
        }
    }
    let waived = |rule: Rule, idx: usize| covered.iter().any(|&(r, t)| r == rule && t == idx);

    let in_det = in_any(rel, &DET_DIRS);
    let in_hot = in_any(rel, &HOT_DIRS);
    let in_alloc = in_any(rel, &ALLOC_DIRS);
    let hot_loops = if in_alloc {
        hot_loop_extents(&lines)
    } else {
        Vec::new()
    };
    let thread_ok = THREAD_OK.contains(&rel);
    let unsafe_ok = rel == UNSAFE_OK;
    let seam_ok = rel.starts_with(SEAM_PREFIX) || SEAM_FILES.contains(&rel);
    let layering = LAYER_EDGES.iter().find(|(dir, _)| rel.starts_with(dir));

    // Pass 2: token rules over the code view.
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        let mut emit = |rule: Rule, note: String| {
            if !waived(rule, idx) {
                violations.push(Violation {
                    rule,
                    file: rel.to_string(),
                    line: idx + 1,
                    note,
                    snippet: snippet(&original, idx),
                });
            }
        };
        if in_det {
            for word in ["HashMap", "HashSet"] {
                if has_token(code, word) {
                    emit(Rule::HashCollections, format!("`{word}` in scheduling code"));
                }
            }
            for word in ["Instant", "SystemTime"] {
                if has_token(code, word) {
                    emit(Rule::WallClock, format!("`{word}` in deterministic code"));
                }
            }
            if code.contains("::now(") {
                emit(Rule::WallClock, "wall-clock `::now()` call".to_string());
            }
        }
        if !thread_ok {
            for word in ["thread::spawn", "thread::scope"] {
                if code.contains(word) {
                    emit(
                        Rule::ThreadConfinement,
                        format!("`{word}` outside util/pool.rs + util/par.rs"),
                    );
                }
            }
        }
        if has_token(code, "unsafe") {
            if !unsafe_ok {
                emit(
                    Rule::UnsafeConfinement,
                    "`unsafe` outside util/pool.rs".to_string(),
                );
            }
            let mut documented = lines[idx].comment.contains("SAFETY:");
            let mut j = idx;
            while !documented && j > 0 {
                j -= 1;
                let above = &lines[j];
                // Contiguous comment block: comment text, no code.
                if above.code.trim().is_empty() && !above.comment.trim().is_empty() {
                    documented = above.comment.contains("SAFETY:");
                    if documented {
                        break;
                    }
                } else {
                    break;
                }
            }
            if !documented {
                emit(
                    Rule::SafetyComment,
                    "`unsafe` without a `// SAFETY:` comment".to_string(),
                );
            }
        }
        if in_hot && !test[idx] {
            for pat in ["panic!", ".unwrap()", ".expect("] {
                if code.contains(pat) {
                    emit(Rule::HotPathPanic, format!("`{pat}` in the scheduling hot path"));
                }
            }
        }
        if in_alloc && !test[idx] && hot_loops.get(idx).copied().unwrap_or(false) {
            for pat in ALLOC_PATTERNS {
                if code.contains(pat) {
                    emit(
                        Rule::HotLoopAlloc,
                        format!("`{pat}` inside a marked hot loop"),
                    );
                }
            }
        }
        if !seam_ok {
            for word in SEAM_TOKENS {
                if has_token(code, word) {
                    emit(
                        Rule::PricingSeam,
                        format!("`{word}` named outside the sched core"),
                    );
                }
            }
        }
        if let Some((dir, forbidden)) = layering {
            for target in crate_targets(code) {
                if forbidden.contains(&target) {
                    emit(
                        Rule::ImportLayering,
                        format!("`crate::{target}` imported from `{dir}`"),
                    );
                }
            }
        }
    }
    (violations, waivers)
}

fn snippet(original: &[&str], idx: usize) -> String {
    original.get(idx).map(|s| s.trim().to_string()).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::super::{scan_source, Rule};

    fn rules_of(rel: &str, src: &str) -> Vec<Rule> {
        scan_source(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let src = "// HashMap in a comment\nlet s = \"Instant::now()\"; /* unsafe */\n";
        assert!(rules_of("src/sim/x.rs", src).is_empty());
    }

    #[test]
    fn det_rules_scope_to_restricted_dirs() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_of("src/sim/x.rs", src), vec![Rule::HashCollections]);
        // The reporting layers joined the restricted set alongside the
        // observability subsystem: their tables and JSONL exports must
        // iterate deterministically too.
        assert_eq!(rules_of("src/metrics/x.rs", src), vec![Rule::HashCollections]);
        assert_eq!(rules_of("src/figures/x.rs", src), vec![Rule::HashCollections]);
        assert_eq!(rules_of("src/obs/x.rs", src), vec![Rule::HashCollections]);
        assert!(rules_of("src/util/x.rs", src).is_empty());
        assert!(rules_of("src/backend/x.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_scopes_to_reporting_layers_too() {
        let src = "let t = std::time::Instant::now();\n";
        for rel in ["src/metrics/x.rs", "src/figures/x.rs", "src/obs/x.rs"] {
            assert_eq!(
                rules_of(rel, src),
                vec![Rule::WallClock, Rule::WallClock],
                "{rel} must be under the wall-clock rule"
            );
        }
        assert!(rules_of("src/runtime/x.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_catches_aliased_now_calls() {
        // The import is caught by name, the aliased call by `::now(`.
        let src = "use std::time::Instant as W;\nlet t = W::now();\n";
        let fired = rules_of("src/sim/x.rs", src);
        assert_eq!(fired, vec![Rule::WallClock, Rule::WallClock]);
    }

    #[test]
    fn waiver_suppresses_only_its_rule_on_its_line() {
        let src = "// audit:allow(hash-collections): lookup-only, never iterated\n\
                   use std::collections::HashMap;\n\
                   use std::collections::HashSet;\n";
        let fired = rules_of("src/sim/x.rs", src);
        assert_eq!(fired, vec![Rule::HashCollections], "second line is not covered");
    }

    #[test]
    fn trailing_waiver_covers_its_own_line() {
        let src = "use std::collections::HashMap; // audit:allow(hash-collections): ok here\n";
        assert!(rules_of("src/sim/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_code_is_exempt_from_hot_path_panic() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() { Some(1).unwrap(); }\n\
                   }\n";
        assert!(rules_of("src/sim/x.rs", src).is_empty());
        let bad = "fn live() { Some(1).unwrap(); }\n";
        assert_eq!(rules_of("src/sim/x.rs", bad), vec![Rule::HotPathPanic]);
    }

    #[test]
    fn unsafe_in_pool_needs_safety_comment_only() {
        let undocumented = "unsafe { work() }\n";
        assert_eq!(
            rules_of("src/util/pool.rs", undocumented),
            vec![Rule::SafetyComment]
        );
        let documented = "// SAFETY: chunk claimed under the lock.\nunsafe { work() }\n";
        assert!(rules_of("src/util/pool.rs", documented).is_empty());
        // Elsewhere both confinement and (if undocumented) SAFETY fire.
        assert_eq!(
            rules_of("src/sim/x.rs", documented),
            vec![Rule::UnsafeConfinement]
        );
    }

    #[test]
    fn seam_tokens_allowed_only_in_the_sched_core() {
        let src = "let p = price_group(&est, g, now);\n";
        assert!(rules_of("src/coordinator/sched/solve.rs", src).is_empty());
        assert!(rules_of("src/coordinator/rwt.rs", src).is_empty());
        assert_eq!(rules_of("src/baselines/x.rs", src), vec![Rule::PricingSeam]);
        assert_eq!(rules_of("src/sim/engine.rs", src), vec![Rule::PricingSeam]);
    }

    #[test]
    fn malformed_waivers_are_violations_and_do_not_suppress() {
        let src = "// audit:allow(hash-collections)\nuse std::collections::HashMap;\n";
        let fired = rules_of("src/sim/x.rs", src);
        assert_eq!(fired, vec![Rule::WaiverHygiene, Rule::HashCollections]);
        let unknown = "// audit:allow(no-such-rule): reason\nlet x = 1;\n";
        assert_eq!(rules_of("src/sim/x.rs", unknown), vec![Rule::WaiverHygiene]);
    }

    #[test]
    fn hot_loop_alloc_fires_only_inside_marked_extents() {
        let src = "fn cold() { let v: Vec<u64> = xs.to_vec(); }\n\
                   // audit:hot-loop\n\
                   for x in xs {\n\
                       let y = x.clone();\n\
                   }\n\
                   let after = ys.to_vec();\n";
        assert_eq!(rules_of("src/sim/x.rs", src), vec![Rule::HotLoopAlloc]);
        // Outside sim/ + coordinator/, the marker is inert.
        assert!(rules_of("src/util/x.rs", src).is_empty());
    }

    #[test]
    fn hot_loop_alloc_trailing_marker_covers_the_loop() {
        let src = "for x in xs { // audit:hot-loop\n\
                       total += x.iter().collect::<Vec<_>>().len();\n\
                   }\n";
        assert_eq!(
            rules_of("src/coordinator/sched/pricing.rs", src),
            vec![Rule::HotLoopAlloc]
        );
    }

    #[test]
    fn hot_loop_alloc_waiver_and_test_exemption() {
        let waived = "// audit:hot-loop\n\
                      for x in xs {\n\
                          // audit:allow(hot-loop-alloc): one-time copy, measured harmless\n\
                          let y = x.to_vec();\n\
                      }\n";
        assert!(rules_of("src/sim/x.rs", waived).is_empty());
        let test_only = "#[cfg(test)]\n\
                         mod tests {\n\
                             fn t() {\n\
                                 // audit:hot-loop\n\
                                 for x in xs {\n\
                                     let y = x.clone();\n\
                                 }\n\
                             }\n\
                         }\n";
        assert!(rules_of("src/sim/x.rs", test_only).is_empty());
    }

    #[test]
    fn import_layering_blocks_forbidden_edges_only() {
        let down = "use crate::coordinator::GlobalQueue;\n";
        assert_eq!(rules_of("src/workload/x.rs", down), vec![Rule::ImportLayering]);
        // sim/ sits above the coordinator, so the same import is fine there.
        assert!(rules_of("src/sim/x.rs", down).is_empty());
        let fig = "use crate::figures::plot_attainment;\n";
        assert_eq!(rules_of("src/sim/x.rs", fig), vec![Rule::ImportLayering]);
        // figures/ is the top layer: it may import anything.
        assert!(rules_of("src/figures/x.rs", "use crate::sim::Simulation;\n").is_empty());
        // Directories outside the table are unconstrained.
        assert!(rules_of("src/backend/x.rs", down).is_empty());
    }

    #[test]
    fn import_layering_needs_a_real_crate_root_path() {
        // `my_crate::coordinator` is a different crate; comments and
        // strings never fire (code view only).
        let src = "use my_crate::coordinator::X;\n\
                   // crate::coordinator named in prose\n\
                   let s = \"crate::coordinator\";\n";
        assert!(rules_of("src/workload/x.rs", src).is_empty());
    }

    #[test]
    fn import_layering_is_waivable() {
        let src = "// audit:allow(import-layering): transitional shim, tracked for removal\n\
                   use crate::coordinator::GlobalQueue;\n";
        assert!(rules_of("src/workload/x.rs", src).is_empty());
    }

    #[test]
    fn thread_primitives_confined_to_pool_and_par() {
        let src = "std::thread::spawn(|| {});\n";
        assert_eq!(rules_of("src/sim/x.rs", src), vec![Rule::ThreadConfinement]);
        assert!(rules_of("src/util/pool.rs", src).is_empty());
        assert!(rules_of("src/util/par.rs", src).is_empty());
    }
}
