//! A minimal, zero-dependency Rust *line* lexer for the audit pass.
//!
//! [`strip`] splits a source file into per-line (code, comment) views:
//! everything inside `//`/`/* */` comments moves to the comment view,
//! and the contents of string/char/byte/raw-string literals are blanked
//! out of the code view (so a doc comment or a log message mentioning
//! `HashMap` or `unsafe` can never trip a rule). Rules then scan the
//! code view for tokens and the comment view for `SAFETY:` and
//! `audit:allow(...)` annotations.
//!
//! The lexer is deliberately *not* a full Rust grammar: it only needs
//! to classify every byte as code / comment / literal-interior. It
//! handles nested block comments, escapes, raw strings with any `#`
//! count, byte literals, and the `'a` lifetime-vs-char-literal
//! ambiguity (a `'` starts a char literal only when it is closed as
//! one: `'\…'` or `'x'`; otherwise it is a lifetime and stays code).

/// One source line split into its code and comment parts. Both strings
/// are byte-for-byte as long as the original line: stripped spans are
/// blanked with spaces so column positions survive.
#[derive(Debug, Clone)]
pub struct LineInfo {
    /// The line with comments and literal interiors blanked out.
    pub code: String,
    /// The line with everything *but* comment text blanked out.
    pub comment: String,
}

enum State {
    Normal,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
    Char,
}

/// True when `b` can appear in an identifier (used to keep `br"`/`r#"`
/// raw-string detection from firing inside identifiers like `for r`).
fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Match a raw-string opener (`r"`, `r#"`, `br##"`, …) at `src[i..]`;
/// returns `(opener_len, hash_count)`.
fn raw_open(src: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if src.get(j) == Some(&b'b') {
        j += 1;
    }
    if src.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while src.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if src.get(j) == Some(&b'"') {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

/// Split `source` into per-line code/comment views. Never fails: bytes
/// that do not fit the grammar are treated as plain code.
pub fn strip(source: &str) -> Vec<LineInfo> {
    let src = source.as_bytes();
    let mut lines = Vec::new();
    let mut code: Vec<u8> = Vec::new();
    let mut comment: Vec<u8> = Vec::new();
    let mut state = State::Normal;
    let mut i = 0;

    macro_rules! endline {
        () => {
            lines.push(LineInfo {
                code: String::from_utf8_lossy(&code).into_owned(),
                comment: String::from_utf8_lossy(&comment).into_owned(),
            });
            code.clear();
            comment.clear();
        };
    }

    while i < src.len() {
        let b = src[i];
        if b == b'\n' {
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            endline!();
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                let raw = if i == 0 || !is_ident(src[i - 1]) {
                    raw_open(src, i)
                } else {
                    None
                };
                if src[i..].starts_with(b"//") {
                    state = State::LineComment;
                    code.extend_from_slice(b"  ");
                    comment.extend_from_slice(b"//");
                    i += 2;
                } else if src[i..].starts_with(b"/*") {
                    state = State::BlockComment(1);
                    code.extend_from_slice(b"  ");
                    comment.extend_from_slice(b"/*");
                    i += 2;
                } else if b == b'"' {
                    state = State::Str;
                    code.push(b'"');
                    comment.push(b' ');
                    i += 1;
                } else if let Some((len, hashes)) = raw {
                    state = State::RawStr(hashes);
                    for _ in 0..len {
                        code.push(b' ');
                        comment.push(b' ');
                    }
                    i += len;
                } else if src[i..].starts_with(b"b\"") {
                    state = State::Str;
                    code.extend_from_slice(b"b\"");
                    comment.extend_from_slice(b"  ");
                    i += 2;
                } else if b == b'\'' {
                    // Char literal iff it closes as one; else lifetime.
                    let is_char = match src.get(i + 1) {
                        Some(b'\\') => true,
                        Some(_) => src.get(i + 2) == Some(&b'\''),
                        None => false,
                    };
                    if is_char {
                        state = State::Char;
                    }
                    code.push(b'\'');
                    comment.push(b' ');
                    i += 1;
                } else {
                    code.push(b);
                    comment.push(b' ');
                    i += 1;
                }
            }
            State::LineComment => {
                code.push(b' ');
                comment.push(b);
                i += 1;
            }
            State::BlockComment(depth) => {
                if src[i..].starts_with(b"/*") {
                    state = State::BlockComment(depth + 1);
                    code.extend_from_slice(b"  ");
                    comment.extend_from_slice(b"/*");
                    i += 2;
                } else if src[i..].starts_with(b"*/") {
                    state = if depth == 1 {
                        State::Normal
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    code.extend_from_slice(b"  ");
                    comment.extend_from_slice(b"*/");
                    i += 2;
                } else {
                    code.push(b' ');
                    comment.push(b);
                    i += 1;
                }
            }
            State::Str => {
                if b == b'\\' {
                    code.extend_from_slice(b"  ");
                    comment.extend_from_slice(b"  ");
                    i += 2;
                } else if b == b'"' {
                    state = State::Normal;
                    code.push(b'"');
                    comment.push(b' ');
                    i += 1;
                } else {
                    code.push(b' ');
                    comment.push(b' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                let close = b == b'"'
                    && src[i + 1..].len() >= hashes
                    && src[i + 1..i + 1 + hashes].iter().all(|&h| h == b'#');
                if close {
                    state = State::Normal;
                    for _ in 0..=hashes {
                        code.push(b' ');
                        comment.push(b' ');
                    }
                    i += 1 + hashes;
                } else {
                    code.push(b' ');
                    comment.push(b' ');
                    i += 1;
                }
            }
            State::Char => {
                if b == b'\\' {
                    code.extend_from_slice(b"  ");
                    comment.extend_from_slice(b"  ");
                    i += 2;
                } else if b == b'\'' {
                    state = State::Normal;
                    code.push(b'\'');
                    comment.push(b' ');
                    i += 1;
                } else {
                    code.push(b' ');
                    comment.push(b' ');
                    i += 1;
                }
            }
        }
    }
    endline!();
    lines
}

/// True when `word` occurs in `line` as a standalone token (not as a
/// substring of a longer identifier).
pub fn has_token(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(pos) = line[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let ok_left = start == 0 || !is_ident(bytes[start - 1]);
        let ok_right = end >= bytes.len() || !is_ident(bytes[end]);
        if ok_left && ok_right {
            return true;
        }
        from = start + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        strip(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_move_to_comment_view() {
        let lines = strip("let x = 1; // HashMap here\n");
        assert!(!lines[0].code.contains("HashMap"));
        assert!(lines[0].comment.contains("HashMap"));
        assert!(lines[0].code.contains("let x = 1;"));
    }

    #[test]
    fn block_comments_nest() {
        let src = "a /* outer /* inner */ still comment */ b\n";
        let code = code_of(src);
        assert!(code[0].contains('a') && code[0].contains('b'));
        assert!(!code[0].contains("inner") && !code[0].contains("still"));
    }

    #[test]
    fn string_interiors_are_blanked() {
        let code = code_of("let s = \"unsafe HashMap // not a comment\"; f();\n");
        assert!(!code[0].contains("unsafe"));
        assert!(!code[0].contains("HashMap"));
        assert!(code[0].contains("f();"));
        let lines = strip("let s = \"// no\"; g();\n");
        assert!(lines[0].code.contains("g();"), "quote must close the string");
    }

    #[test]
    fn escaped_quotes_stay_inside_the_string() {
        let code = code_of(r#"let s = "a\"unsafe\"b"; h();"#);
        assert!(!code[0].contains("unsafe"));
        assert!(code[0].contains("h();"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let code = code_of("let s = r#\"unsafe \"quoted\" HashMap\"#; k();\n");
        assert!(!code[0].contains("unsafe"));
        assert!(!code[0].contains("HashMap"));
        assert!(code[0].contains("k();"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let code = code_of("let c = '\"'; let l: &'static str = x; fn f<'a>() {}\n");
        // The '"' char literal must not open a string that swallows the line.
        assert!(code[0].contains("static"));
        assert!(code[0].contains("fn f<"));
    }

    #[test]
    fn multiline_block_comment_spans_lines() {
        let code = code_of("a\n/* unsafe\nHashMap */\nb\n");
        assert!(code[0].contains('a'));
        assert!(!code[1].contains("unsafe"));
        assert!(!code[2].contains("HashMap"));
        assert!(code[3].contains('b'));
    }

    #[test]
    fn token_matching_respects_identifier_boundaries() {
        assert!(has_token("use std::collections::HashMap;", "HashMap"));
        assert!(!has_token("let my_unsafe_flag = 1;", "unsafe"));
        assert!(!has_token("HashMapLike", "HashMap"));
        assert!(has_token("unsafe { x }", "unsafe"));
    }
}
