//! FleetController: instance lifecycle and the capacity bridge.
//!
//! Owns the serving instances plus everything about their *lifecycle* —
//! liveness, draining, the commission/decommission device-seconds
//! ledger, cold-start provisioning — and is the single point where the
//! engine talks to the capacity subsystem (`capacity::Autoscaler`,
//! `capacity::AdmissionController`). The engine asks it for scale
//! decisions and applies the event-loop side effects (virtual queues,
//! agents, wake events); the controller never touches scheduling state.

use std::collections::BTreeMap;

use crate::backend::{
    GpuKind, Instance, InstanceConfig, InstanceId, ModelCatalog, ModelId, PerfModel, RunningSeq,
};
use crate::baselines::Policy;
use crate::capacity::{AdmissionController, Autoscaler, ClassPressure, ScaleDecision};
use crate::coordinator::rwt::ProfileTable;
use crate::coordinator::scheduler::InstanceView;
use crate::workload::SloClass;

/// Static model placement for policies without model swapping:
/// distribute instances over models proportionally to request share
/// (what an operator running vanilla vLLM would provision). Takes the
/// per-model request counts (from a materialized trace or a streaming
/// profile pass) and runs over the bare instance slice before the
/// controller takes ownership.
pub(crate) fn static_pinning(
    instances: &mut [Instance],
    catalog: &ModelCatalog,
    policy: &Policy,
    counts: &BTreeMap<ModelId, usize>,
) -> BTreeMap<InstanceId, ModelId> {
    let mut pinned = BTreeMap::new();
    if policy.lso().model_swapping {
        return pinned;
    }
    let mut models: Vec<(ModelId, usize)> = counts.iter().map(|(&m, &c)| (m, c)).collect();
    models.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let total: usize = models.iter().map(|(_, c)| c).sum();
    let n_inst = instances.len();
    // Quota per model (≥1), largest first.
    let mut quota: Vec<(ModelId, usize)> = models
        .iter()
        .map(|&(m, c)| {
            let q = (c as f64 / total as f64) * n_inst as f64;
            (m, q.round().max(1.0) as usize)
        })
        .collect();
    // Trim/extend to exactly n_inst.
    let mut assigned: usize = quota.iter().map(|(_, q)| q).sum();
    let mut i = 0;
    let nq = quota.len();
    while assigned > n_inst && nq > 0 {
        // Prefer shrinking an over-provisioned model; if every quota
        // is already 1 (more models than instances), drop the least
        // popular model entirely — static provisioning cannot serve
        // more models than it has instances.
        if let Some(k) = (0..nq).filter(|&k| quota[k].1 > 1).max_by_key(|&k| quota[k].1) {
            quota[k].1 -= 1;
        } else if let Some(k) = (0..nq).rev().find(|&k| quota[k].1 == 1) {
            quota[k].1 = 0;
        } else {
            break;
        }
        assigned -= 1;
    }
    while assigned < n_inst && nq > 0 {
        quota[i % nq].1 += 1;
        assigned += 1;
        i += 1;
    }
    // Pin: each instance gets the next model with remaining quota it
    // can actually serve.
    for inst in instances.iter_mut() {
        let gpu = inst.config.gpu;
        let pick = quota
            .iter_mut()
            .find(|(m, q)| *q > 0 && PerfModel::fits(catalog.get(*m), gpu))
            .map(|e| {
                e.1 -= 1;
                e.0
            })
            .or_else(|| {
                quota
                    .iter()
                    .map(|&(m, _)| m)
                    .find(|&m| PerfModel::fits(catalog.get(m), gpu))
            });
        if let Some(m) = pick {
            pinned.insert(inst.config.id, m);
            let (_ready, displaced) = inst.swap_model(m, 0.0);
            debug_assert!(displaced.is_empty());
        }
    }
    pinned
}

pub(crate) struct FleetController {
    instances: Vec<Instance>,
    /// Dense per-instance liveness, indexed by `InstanceId.0`.
    alive: Vec<bool>,
    /// Scale-down in progress: the instance receives no new work and
    /// leaves the fleet once its running batch drains (no mid-flight
    /// kills).
    draining: Vec<bool>,
    /// When each instance joined the fleet (0 for the starting fleet,
    /// cold-start completion for provisioned ones) / left it — the
    /// device-seconds ledger.
    commissioned_at: Vec<f64>,
    decommissioned_at: Vec<Option<f64>>,
    /// Provisioned instances still in their cold-start window.
    warming: u32,
    autoscaler: Option<Autoscaler>,
    pub admission: AdmissionController,
    /// Waiting (+ evicted) request counts per (class, model, mega),
    /// maintained incrementally at every state transition — the
    /// autoscaler's and admission controller's backlog signal without
    /// any per-pass walk. Mega is in the key because the profile table
    /// is: mega output moments are several times larger, and pricing a
    /// mega backlog with the regular profile would underestimate drain
    /// times exactly when the pressure signal matters most.
    /// `BTreeMap` so pressure sums fold in a deterministic order.
    waiting_by: BTreeMap<(SloClass, ModelId, bool), i64>,
    catalog: ModelCatalog,
}

impl FleetController {
    pub fn new(
        instances: Vec<Instance>,
        catalog: ModelCatalog,
        autoscaler: Option<Autoscaler>,
        admission: AdmissionController,
    ) -> Self {
        let n = instances.len();
        FleetController {
            instances,
            alive: vec![true; n],
            draining: vec![false; n],
            commissioned_at: vec![0.0; n],
            decommissioned_at: vec![None; n],
            warming: 0,
            autoscaler,
            admission,
            waiting_by: BTreeMap::new(),
            catalog,
        }
    }

    /// Total instances ever registered (alive or not) — the dense
    /// per-instance index space.
    pub fn instance_count(&self) -> usize {
        self.instances.len()
    }

    pub fn instances(&self) -> &[Instance] {
        &self.instances
    }

    pub fn inst(&self, id: InstanceId) -> &Instance {
        &self.instances[id.0 as usize]
    }

    pub fn inst_mut(&mut self, id: InstanceId) -> &mut Instance {
        &mut self.instances[id.0 as usize]
    }

    pub fn alive(&self, id: InstanceId) -> bool {
        self.alive[id.0 as usize]
    }

    pub fn is_draining(&self, id: InstanceId) -> bool {
        self.draining[id.0 as usize]
    }

    /// Adjust the incremental waiting counter for one backlog key.
    pub fn note_waiting(&mut self, key: (SloClass, ModelId, bool), delta: i64) {
        *self.waiting_by.entry(key).or_default() += delta;
    }

    /// Per-class backlog pressure from the incremental waiting counters:
    /// predicted drain time = pending output tokens of this class and
    /// every tighter class over the fleet's aggregate Θ — the
    /// RWT-estimator waiting model (Eq. 2) applied fleet-wide.
    ///
    /// `fit_gpu` restricts each class's `hottest_model` to models that
    /// fit that tier, so a scale-up never warms (or is sized for) a
    /// model the provisioned device cannot host.
    pub fn class_pressures(
        &self,
        views: &[InstanceView],
        profiles: &ProfileTable,
        fit_gpu: Option<GpuKind>,
    ) -> Vec<ClassPressure> {
        // Aggregate Θ over active (non-draining) instances: each runs
        // its most capable model at the profile-mean footprint.
        let mut fleet_theta = 0.0;
        for v in views {
            let best = v
                .perf_for
                .iter()
                .map(|(m, p)| {
                    let prof = profiles.get(*m, SloClass::Interactive, false);
                    p.steady_throughput(prof.mean_tokens_per_req())
                })
                .fold(0.0_f64, f64::max);
            fleet_theta += best;
        }
        let mut out = Vec::with_capacity(SloClass::ALL.len());
        let mut cum_tokens = 0.0;
        for class in SloClass::ALL {
            let mut waiting = 0usize;
            let mut tokens = 0.0;
            // Per-model totals (mega + non-mega summed) over hostable
            // models — a model's backlog must not lose the hottest pick
            // because it was split across mega variants.
            let mut per_model: BTreeMap<ModelId, i64> = BTreeMap::new();
            for (&(c, m, mega), &n) in &self.waiting_by {
                if c != class || n <= 0 {
                    continue;
                }
                waiting += n as usize;
                tokens += n as f64 * profiles.get(m, c, mega).mu_out;
                let hostable = fit_gpu
                    .map(|g| PerfModel::fits(self.catalog.get(m), g))
                    .unwrap_or(true);
                if hostable {
                    *per_model.entry(m).or_default() += n;
                }
            }
            // Ascending iteration + strict `>` keeps the lowest model
            // id on ties.
            let mut hottest: Option<(ModelId, i64)> = None;
            for (&m, &n) in &per_model {
                if hottest.map(|(_, hn)| n > hn).unwrap_or(true) {
                    hottest = Some((m, n));
                }
            }
            cum_tokens += tokens;
            let drain_s = if cum_tokens <= 0.0 {
                0.0
            } else if fleet_theta > 0.0 {
                cum_tokens / fleet_theta
            } else {
                f64::INFINITY
            };
            out.push(ClassPressure {
                class,
                waiting,
                drain_s,
                hottest_model: hottest.map(|(m, _)| m),
            });
        }
        out
    }

    /// One capacity-subsystem evaluation, run after every scheduler
    /// pass: update the admission gates and ask the autoscaler for a
    /// decision (the engine applies it — provisioning and draining have
    /// event-loop side effects). Free when the whole subsystem is off —
    /// the pressure walk must not tax runs (or Fig. 20 overhead
    /// numbers) that never asked for capacity management.
    pub fn capacity_tick(
        &mut self,
        now: f64,
        views: &[InstanceView],
        profiles: &ProfileTable,
    ) -> ScaleDecision {
        if self.autoscaler.is_none() && !self.admission.cfg.enabled {
            return ScaleDecision::Hold;
        }
        let tier = self.autoscaler.as_ref().map(|a| a.cfg.gpu);
        let pressures = self.class_pressures(views, profiles, tier);
        let active = (0..self.instances.len())
            .filter(|&i| self.alive[i] && !self.draining[i])
            .count() as u32;
        let draining = (0..self.instances.len())
            .filter(|&i| self.alive[i] && self.draining[i])
            .count() as u32;
        // "Maxed" for admission purposes means growth cannot help: the
        // instance budget is exhausted, or nothing backlogged fits the
        // provisionable tier (hottest_model is tier-filtered) — in
        // either case waiting for more capacity would be waiting for
        // capacity that can never serve the backlog.
        let fleet_maxed = match &self.autoscaler {
            Some(a) => {
                let at_max = active + self.warming + draining >= a.cfg.max_instances;
                let growth_helps = pressures
                    .iter()
                    .any(|p| p.waiting > 0 && p.hottest_model.is_some());
                at_max || !growth_helps
            }
            None => true, // a fixed fleet cannot grow
        };
        let drains: Vec<(SloClass, f64)> = pressures.iter().map(|p| (p.class, p.drain_s)).collect();
        self.admission.update(&drains, fleet_maxed);
        let any_idle = (0..self.instances.len())
            .any(|i| self.alive[i] && !self.draining[i] && self.instances[i].is_idle());
        let warming = self.warming;
        match self.autoscaler.as_mut() {
            Some(a) => a.decide(now, &pressures, active, warming, draining, any_idle),
            None => ScaleDecision::Hold,
        }
    }

    /// Provision one instance (autoscaler scale-up). The cold start is
    /// the weight-staging time of the model the scale-up is for
    /// (storage → CPU, priced by the perf model); the instance joins
    /// the fleet with those weights warm in host memory, so its first
    /// SwapModel LSO pays only the CPU → GPU hop. Returns the new id
    /// and its ready time; the engine grows its own per-instance state
    /// and schedules the Provision event.
    pub fn provision(&mut self, model: ModelId, now: f64) -> Option<(InstanceId, f64)> {
        let gpu = self.autoscaler.as_ref()?.cfg.gpu;
        // A tier that can host nothing in the catalog would add a device
        // that serves no model at all — refuse rather than burn
        // device-hours on it (misconfigured AutoscaleConfig::gpu).
        let serves_any = self
            .catalog
            .ids()
            .into_iter()
            .any(|m| PerfModel::fits(self.catalog.get(m), gpu));
        if !serves_any {
            return None;
        }
        let id = InstanceId(self.instances.len() as u32);
        let mut inst = Instance::new(InstanceConfig::new(id.0, gpu), self.catalog.clone());
        let prompt = crate::backend::perf::PROFILE_MEAN_PROMPT_TOKENS;
        let delay = PerfModel::try_profile(self.catalog.get(model), gpu, prompt)
            .map(|p| p.swap_storage_cpu_s)
            .unwrap_or(30.0);
        inst.registry_mut().set_warm_set(&[model]);
        let ready = now + delay;
        self.instances.push(inst);
        self.alive.push(false);
        self.draining.push(false);
        self.commissioned_at.push(ready);
        self.decommissioned_at.push(None);
        self.warming += 1;
        Some((id, ready))
    }

    /// Cold start finished: the instance goes live.
    pub fn commission(&mut self, id: InstanceId) {
        self.warming = self.warming.saturating_sub(1);
        self.alive[id.0 as usize] = true;
    }

    /// Pick a scale-down victim (idle preferred, then highest id) and
    /// mark it draining: it leaves the scheduler's view set immediately,
    /// keeps stepping its running batch to completion, and is
    /// decommissioned when idle. No request is killed mid-flight.
    pub fn begin_drain(&mut self) -> Option<InstanceId> {
        let victim = (0..self.instances.len())
            .filter(|&i| self.alive[i] && !self.draining[i])
            .max_by_key(|&i| (self.instances[i].is_idle(), i))
            .map(|i| InstanceId(i as u32))?;
        self.draining[victim.0 as usize] = true;
        Some(victim)
    }

    /// A drained instance leaves the fleet for good. Returns false if it
    /// was already gone; the engine handles the broker-side cleanup.
    pub fn decommission(&mut self, id: InstanceId, now: f64) -> bool {
        let idx = id.0 as usize;
        if !self.alive[idx] {
            return false;
        }
        debug_assert!(
            self.instances[idx].is_idle(),
            "decommission requires a drained batch"
        );
        self.alive[idx] = false;
        self.decommissioned_at[idx] = Some(now);
        true
    }

    /// Instance failure (§4 Fault Isolation): the device is gone.
    /// Returns the sequences lost with it (None if it was already
    /// dead); the engine reverts them to Waiting and rebuilds state.
    pub fn fail(&mut self, id: InstanceId, now: f64) -> Option<Vec<RunningSeq>> {
        let idx = id.0 as usize;
        if !self.alive[idx] {
            return None;
        }
        self.alive[idx] = false;
        if self.decommissioned_at[idx].is_none() {
            self.decommissioned_at[idx] = Some(now);
        }
        Some(self.instances[idx].fail())
    }

    /// The tier a future scale-up could still provision, if any — the
    /// rescuability gate for unservable-group retirement (shedding
    /// recoverable work early would throw requests away, the same rule
    /// the admission controller applies at submit time).
    pub fn rescue_tier(&self) -> Option<GpuKind> {
        let a = self.autoscaler.as_ref()?;
        let powered =
            (0..self.instances.len()).filter(|&i| self.alive[i]).count() as u32 + self.warming;
        if powered < a.cfg.max_instances {
            Some(a.cfg.gpu)
        } else {
            None
        }
    }

    /// Device-seconds ledger: each instance is billed from commission
    /// (cold-start completion for provisioned ones) to decommission /
    /// failure / end of run. An instance that never joined — its
    /// Provision event was still pending when the run ended (not
    /// alive, never decommissioned) — is not billed.
    pub fn device_seconds(&self, duration: f64) -> f64 {
        (0..self.instances.len())
            .filter(|&i| self.alive[i] || self.decommissioned_at[i].is_some())
            .map(|i| {
                let start = self.commissioned_at[i].min(duration);
                let end = self.decommissioned_at[i].unwrap_or(duration).min(duration);
                (end - start).max(0.0)
            })
            .sum()
    }

    /// (scale_ups, scale_downs) taken by the autoscaler this run.
    pub fn scale_stats(&self) -> (u64, u64) {
        self.autoscaler
            .as_ref()
            .map(|a| (a.scale_ups, a.scale_downs))
            .unwrap_or((0, 0))
    }

    // ---- telemetry accessors (observability layer; read-only) ----

    /// Waiting (+ evicted) requests per class, folded over the
    /// incremental backlog counters. Every class appears, zero included,
    /// so telemetry rows have a fixed shape.
    pub fn waiting_by_class(&self) -> Vec<(SloClass, i64)> {
        let mut out: Vec<(SloClass, i64)> = SloClass::ALL.iter().map(|&c| (c, 0)).collect();
        for (&(c, _, _), &n) in &self.waiting_by {
            if n > 0 {
                out[c.index()].1 += n;
            }
        }
        out
    }

    /// Waiting (+ evicted) requests targeting `model`, across classes —
    /// the fleet-level queue depth the RWT ledger predicts against.
    pub fn waiting_for_model(&self, model: ModelId) -> u64 {
        self.waiting_by
            .iter()
            .filter(|(&(_, m, _), &n)| m == model && n > 0)
            .map(|(_, &n)| n as u64)
            .sum()
    }

    /// (active, warming, draining) instance counts — the same tallies
    /// `capacity_tick` computes, exposed for the telemetry sampler.
    pub fn occupancy_counts(&self) -> (usize, usize, usize) {
        let active = (0..self.instances.len())
            .filter(|&i| self.alive[i] && !self.draining[i])
            .count();
        let draining = (0..self.instances.len())
            .filter(|&i| self.alive[i] && self.draining[i])
            .count();
        (active, self.warming as usize, draining)
    }

    /// Ids of alive instances, ascending — the telemetry sampler's
    /// iteration domain.
    pub fn alive_ids(&self) -> Vec<InstanceId> {
        (0..self.instances.len())
            .filter(|&i| self.alive[i])
            .map(|i| InstanceId(i as u32))
            .collect()
    }
}
