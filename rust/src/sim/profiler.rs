//! Hardware profiling (§6, Offline Profiling): "requires running the
//! model with a single batch of requests on the specific GPU. Fixed
//! variables ... are obtained by directly logging these metrics from the
//! LLM serving instance."
//!
//! We do exactly that against the simulated instance: load the model,
//! keep the batch topped up with workload-representative requests, and
//! log the steady-state token generation throughput Θ. The measured Θ is
//! attached to [`PerfModel::measured_theta`] so the RWT estimator and the
//! backend share one ground truth — as they do in the real system.

use std::collections::BTreeMap;

use crate::backend::{
    GpuKind, Instance, InstanceConfig, ModelCatalog, ModelId, PerfModel, RunningSeq,
};
use crate::coordinator::rwt::{ProfileTable, WorkloadProfile};
use crate::util::Rng;
use crate::workload::{ArrivalStream, ShareGptSampler, WorkloadSpec};

/// SHEPHERD's deterministic worst-case profile: μ_out := max_out, σ := 0
/// — the DNN-serving estimation assumption Fig. 1 critiques.
pub(crate) fn conservative_profiles(profiles: &ProfileTable) -> ProfileTable {
    let mut out = ProfileTable::default();
    for (m, c, mg) in profiles.keys().collect::<Vec<_>>() {
        let mut p = profiles.get(m, c, mg);
        p.mu_out = p.max_out;
        p.sigma_out = 0.0;
        out.insert(m, c, mg, p);
    }
    out
}

/// Streaming workload profiling: the moments [`ProfileTable::from_trace`]
/// measures, plus the per-model request counts static pinning consumes,
/// computed from two seeded [`ArrivalStream`] replays instead of a
/// materialized trace — O(keys) memory at any request count.
///
/// Bit-identical to `ProfileTable::from_trace(&Trace::generate(spec,
/// seed))`: the replay emits requests in exactly the order the sorted
/// trace stores them, and `util::{mean, stddev}` are sequential-sum
/// formulas, so accumulating in replay order reproduces them (pass 1:
/// Σx and max; pass 2: Σ(x−μ)², preserving the n<2 ⇒ σ=0 convention).
pub(crate) fn profile_spec(
    spec: &WorkloadSpec,
    seed: u64,
) -> (ProfileTable, BTreeMap<ModelId, usize>) {
    type Key = (ModelId, crate::workload::SloClass, bool);
    struct Acc {
        n: usize,
        sum_in: f64,
        sum_out: f64,
        max_out: f64,
        sq_in: f64,
        sq_out: f64,
    }
    let mut acc: BTreeMap<Key, Acc> = BTreeMap::new();
    let mut counts: BTreeMap<ModelId, usize> = BTreeMap::new();
    for r in ArrivalStream::new(spec, seed) {
        *counts.entry(r.model).or_insert(0) += 1;
        let e = acc.entry((r.model, r.class, r.mega)).or_insert(Acc {
            n: 0,
            sum_in: 0.0,
            sum_out: 0.0,
            max_out: 0.0,
            sq_in: 0.0,
            sq_out: 0.0,
        });
        e.n += 1;
        e.sum_in += r.input_tokens as f64;
        e.sum_out += r.output_tokens as f64;
        e.max_out = e.max_out.max(r.output_tokens as f64);
    }
    // Pass 2: centered second moments in the same replay order, exactly
    // as the two-pass `util::variance` computes them over the trace.
    for r in ArrivalStream::new(spec, seed) {
        if let Some(e) = acc.get_mut(&(r.model, r.class, r.mega)) {
            let mu_in = e.sum_in / e.n as f64;
            let mu_out = e.sum_out / e.n as f64;
            let di = r.input_tokens as f64 - mu_in;
            let dout = r.output_tokens as f64 - mu_out;
            e.sq_in += di * di;
            e.sq_out += dout * dout;
        }
    }
    let mut table = ProfileTable::default();
    for ((m, c, mg), e) in &acc {
        let n = e.n as f64;
        // `util::variance` returns 0.0 below two samples.
        let (var_in, var_out) = if e.n < 2 {
            (0.0, 0.0)
        } else {
            (e.sq_in / n, e.sq_out / n)
        };
        table.insert(
            *m,
            *c,
            *mg,
            WorkloadProfile {
                mu_in: e.sum_in / n,
                sigma_in: var_in.sqrt(),
                mu_out: e.sum_out / n,
                sigma_out: var_out.sqrt(),
                max_out: e.max_out,
            },
        );
    }
    (table, counts)
}

/// Cache of profiled Θ per (gpu, model).
#[derive(Debug, Default, Clone)]
pub struct ThetaCache {
    map: BTreeMap<(GpuKind, ModelId), f64>,
}

impl ThetaCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get_or_profile(&mut self, gpu: GpuKind, model: ModelId, catalog: &ModelCatalog) -> f64 {
        *self
            .map
            .entry((gpu, model))
            .or_insert_with(|| profile_theta(model, gpu, catalog, 0xBEEF))
    }

    /// Profiled perf for (model, gpu) with Θ attached; None if the model
    /// doesn't fit.
    pub fn perf(
        &mut self,
        gpu: GpuKind,
        model: ModelId,
        catalog: &ModelCatalog,
        mean_prompt: f64,
    ) -> Option<PerfModel> {
        let mut p = PerfModel::try_profile(catalog.get(model), gpu, mean_prompt)?;
        p.measured_theta = Some(self.get_or_profile(gpu, model, catalog));
        Some(p)
    }
}

/// Run the single-batch profiling workload and return steady-state Θ
/// (tokens/second).
pub fn profile_theta(model: ModelId, gpu: GpuKind, catalog: &ModelCatalog, seed: u64) -> f64 {
    let mut inst = Instance::new(InstanceConfig::new(0, gpu), catalog.clone());
    let (ready, _) = inst.swap_model(model, 0.0);
    let mut now = ready;
    let sampler = ShareGptSampler::default();
    let mut rng = Rng::new(seed);
    let mut next_id = 0u64;

    let mut admit = |inst: &mut Instance, now: f64, rng: &mut Rng, next_id: &mut u64| {
        // Top up the batch (vLLM keeps admitting while the prompt fits and
        // no preempted sequences are pending).
        while inst.swapped_len() == 0 && inst.batch_slots_free() > 0 {
            let (input, output) = sampler.sample(rng);
            if inst.spare_tokens() < input as u64 {
                break;
            }
            let seq = RunningSeq {
                req_id: *next_id,
                model,
                prompt_tokens: input,
                target_output: output,
                generated: 0,
                first_token_at: None,
                arrival_s: now,
                prefilled: 0,
                slice_left: 0,
            };
            if inst.try_admit(seq, now).is_err() {
                break;
            }
            *next_id += 1;
        }
    };

    // Warm up until the batch reaches steady state.
    for _ in 0..300 {
        admit(&mut inst, now, &mut rng, &mut next_id);
        let out = inst.step(now);
        if out.dt <= 0.0 {
            break;
        }
        now += out.dt;
    }
    // Measure.
    let t0 = now;
    let tok0 = inst.stats.tokens_generated;
    for _ in 0..500 {
        admit(&mut inst, now, &mut rng, &mut next_id);
        let out = inst.step(now);
        if out.dt <= 0.0 {
            break;
        }
        now += out.dt;
    }
    let tokens = inst.stats.tokens_generated - tok0;
    let elapsed = now - t0;
    if elapsed <= 0.0 {
        return 1.0;
    }
    tokens as f64 / elapsed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theta_positive_and_plausible() {
        let catalog = ModelCatalog::paper();
        for m in catalog.ids() {
            let theta = profile_theta(m, GpuKind::A100, &catalog, 1);
            assert!(
                (100.0..50_000.0).contains(&theta),
                "{}: theta={theta}",
                catalog.get(m).name
            );
        }
    }

    #[test]
    fn bigger_model_lower_theta() {
        let catalog = ModelCatalog::paper();
        let mistral = profile_theta(ModelId(0), GpuKind::A100, &catalog, 2);
        let llama = profile_theta(ModelId(2), GpuKind::A100, &catalog, 2);
        assert!(mistral > llama, "mistral {mistral} vs llama {llama}");
    }

    #[test]
    fn a10_slower_than_a100() {
        let catalog = ModelCatalog::paper();
        let a100 = profile_theta(ModelId(0), GpuKind::A100, &catalog, 3);
        let a10 = profile_theta(ModelId(0), GpuKind::A10, &catalog, 3);
        assert!(a100 > a10, "a100 {a100} vs a10 {a10}");
    }

    #[test]
    fn cache_returns_same_value() {
        let catalog = ModelCatalog::paper();
        let mut c = ThetaCache::new();
        let a = c.get_or_profile(GpuKind::A100, ModelId(0), &catalog);
        let b = c.get_or_profile(GpuKind::A100, ModelId(0), &catalog);
        assert_eq!(a, b);
        let p = c.perf(GpuKind::A100, ModelId(0), &catalog, 161.0).unwrap();
        assert_eq!(p.measured_theta, Some(a));
    }

    #[test]
    fn profile_spec_matches_from_trace_bit_for_bit() {
        use crate::workload::Trace;
        let spec = crate::workload::WorkloadSpec::w_c(
            vec![ModelId(0), ModelId(1)],
            vec![ModelId(2)],
            40.0,
            2400,
            0.15,
        );
        let trace = Trace::generate(&spec, 21);
        let from_trace = ProfileTable::from_trace(&trace);
        let (streamed, counts) = profile_spec(&spec, 21);
        let keys: Vec<_> = from_trace.keys().collect();
        assert_eq!(keys, streamed.keys().collect::<Vec<_>>());
        assert!(!keys.is_empty());
        for (m, c, mg) in keys {
            let a = from_trace.get(m, c, mg);
            let b = streamed.get(m, c, mg);
            assert_eq!(a.mu_in.to_bits(), b.mu_in.to_bits());
            assert_eq!(a.sigma_in.to_bits(), b.sigma_in.to_bits());
            assert_eq!(a.mu_out.to_bits(), b.mu_out.to_bits());
            assert_eq!(a.sigma_out.to_bits(), b.sigma_out.to_bits());
            assert_eq!(a.max_out.to_bits(), b.max_out.to_bits());
        }
        let mut by_model: BTreeMap<ModelId, usize> = BTreeMap::new();
        for r in &trace.requests {
            *by_model.entry(r.model).or_insert(0) += 1;
        }
        assert_eq!(counts, by_model);
    }

    #[test]
    fn llama_unfit_on_a10_returns_none() {
        let catalog = ModelCatalog::paper();
        let mut llama = catalog.get(ModelId(2)).clone();
        llama.tp_degree = 1;
        let mut cat2 = catalog.clone();
        cat2.models[2] = llama;
        let mut c = ThetaCache::new();
        assert!(c.perf(GpuKind::A10, ModelId(2), &cat2, 161.0).is_none());
    }
}
