//! The view pass: building and refreshing the scheduler's per-instance
//! [`InstanceView`]s.
//!
//! Refreshing is the per-pass hot loop and is embarrassingly parallel —
//! each view reads only its own instance — so [`refresh_all`] fans out
//! over the engine's persistent [`WorkerPool`] (spawned once per
//! `Simulation`, shared with the scheduler's repricing walk — a pass
//! costs one dispatch instead of a scoped spawn per thread). Chunks are
//! split and merged in index order, so the refreshed views are
//! bit-identical to the serial pass whatever the lane count (`cargo
//! bench -- par_views` measures it against the scoped-spawn baseline;
//! the golden suite asserts it end to end).

use std::collections::BTreeMap;

use crate::backend::{Instance, ModelCatalog, ModelId};
use crate::coordinator::request_group::GroupId;
use crate::coordinator::scheduler::InstanceView;
use crate::sim::profiler::ThetaCache;
use crate::util::WorkerPool;

/// Build one instance's scheduler view: `perf_for` is static per
/// (instance, model); only swap times, active model, and the executing
/// group change between passes (see [`refresh_all`]).
pub(crate) fn build_view(
    idx: usize,
    instances: &[Instance],
    catalog: &ModelCatalog,
    pinned_model: &BTreeMap<crate::backend::InstanceId, ModelId>,
    thetas: &mut ThetaCache,
) -> InstanceView {
    let inst = &instances[idx];
    let id = inst.config.id;
    let gpu = inst.config.gpu;
    let mut perf_for = BTreeMap::new();
    let mut swap_time = BTreeMap::new();
    for m in catalog.ids() {
        // Pinned instances only serve their pinned model.
        if let Some(&pm) = pinned_model.get(&id) {
            if pm != m {
                continue;
            }
        }
        let prompt = crate::backend::perf::PROFILE_MEAN_PROMPT_TOKENS;
        if let Some(p) = thetas.perf(gpu, m, catalog, prompt) {
            swap_time.insert(m, inst.registry().swap_in_time_s(m, &p));
            perf_for.insert(m, p);
        }
    }
    InstanceView {
        id,
        active_model: inst.active_model(),
        perf_for,
        swap_time,
        executing: None,
    }
}

/// Refresh one view in place from its live instance.
fn refresh_one(v: &mut InstanceView, instances: &[Instance], group_of: &BTreeMap<u64, GroupId>) {
    let inst = &instances[v.id.0 as usize];
    v.active_model = inst.active_model();
    v.executing = inst
        .running()
        .first()
        .and_then(|s| group_of.get(&s.req_id).copied());
    // Swap-in times depend on each model's current tier.
    for (m, t) in v.swap_time.iter_mut() {
        let p = v.perf_for[m];
        *t = inst.registry().swap_in_time_s(*m, &p);
    }
}

/// Refresh every view for one scheduler pass, fanning out over the
/// persistent pool's lanes when there are enough views to split (the
/// engagement gate matches [`crate::util::par_chunks_mut`], the
/// scoped-spawn baseline the bench compares against; the pool steals
/// over finer chunks — see `util/pool.rs`). Serial and parallel paths
/// produce identical views: the work per view is independent and chunks
/// stay in index order.
pub(crate) fn refresh_all(
    views: &mut [InstanceView],
    instances: &[Instance],
    group_of: &BTreeMap<u64, GroupId>,
    pool: &WorkerPool,
) {
    pool.run_chunks_mut(views, |v| refresh_one(v, instances, group_of));
}

/// The scoped-spawn refresh, kept only as the bench baseline for the
/// pool-vs-scoped comparison (`cargo bench -- par_views`); production
/// passes go through [`refresh_all`].
pub(crate) fn refresh_all_scoped(
    views: &mut [InstanceView],
    instances: &[Instance],
    group_of: &BTreeMap<u64, GroupId>,
    threads: usize,
) {
    crate::util::par_chunks_mut(views, threads, |v| refresh_one(v, instances, group_of));
}

/// Order-stable digest of the refreshed view state (bench/test
/// observability: serial and threaded refreshes must collide).
pub(crate) fn digest(views: &[InstanceView]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |h: &mut u64, x: u64| {
        *h ^= x;
        *h = h.wrapping_mul(0x100000001b3);
    };
    // audit:hot-loop — runs once per pass over every view; the mix
    // closure and BTreeMap walk below must stay allocation-free.
    for v in views {
        mix(&mut h, v.id.0 as u64);
        mix(&mut h, v.active_model.map(|m| m.0 as u64 + 1).unwrap_or(0));
        mix(&mut h, v.executing.map(|g| g.0 + 1).unwrap_or(0));
        // `swap_time` is a BTreeMap: iteration is already ModelId-sorted,
        // so the digest needs no per-view sort scratch.
        for (m, t) in &v.swap_time {
            mix(&mut h, m.0 as u64);
            mix(&mut h, t.to_bits());
        }
    }
    h
}
