//! Fleet construction helpers for the paper's testbed configurations.

use crate::backend::{GpuKind, InstanceConfig};

/// A cluster description: counts per GPU kind.
#[derive(Debug, Clone, Copy)]
pub struct FleetSpec {
    pub a100: u32,
    pub a10: u32,
}

impl FleetSpec {
    /// The paper's full testbed (§8): 50 A100 + 30 A10.
    pub fn paper() -> Self {
        FleetSpec { a100: 50, a10: 30 }
    }

    pub fn total(&self) -> u32 {
        self.a100 + self.a10
    }

    pub fn build(&self) -> Vec<InstanceConfig> {
        let mut out = Vec::new();
        let mut id = 0;
        for _ in 0..self.a100 {
            out.push(InstanceConfig::new(id, GpuKind::A100));
            id += 1;
        }
        for _ in 0..self.a10 {
            out.push(InstanceConfig::new(id, GpuKind::A10));
            id += 1;
        }
        out
    }
}

/// `n` homogeneous A100 instances.
pub fn fleet_a100(n: u32) -> Vec<InstanceConfig> {
    FleetSpec { a100: n, a10: 0 }.build()
}

/// `n` homogeneous instances of an arbitrary tier (the autoscaler's
/// starting fleets are built this way).
pub fn fleet_of(gpu: GpuKind, n: u32) -> Vec<InstanceConfig> {
    (0..n).map(|id| InstanceConfig::new(id, gpu)).collect()
}

/// Materialize a capacity plan's per-tier counts (e.g.
/// [`crate::capacity::CapacityPlan::tiers`]) into a dense-id fleet —
/// the bridge from `qlm plan` output to a runnable simulation.
pub fn fleet_from_tiers(tiers: &[(GpuKind, u32)]) -> Vec<InstanceConfig> {
    let mut out = Vec::new();
    let mut id = 0;
    for &(gpu, n) in tiers {
        for _ in 0..n {
            out.push(InstanceConfig::new(id, gpu));
            id += 1;
        }
    }
    out
}

/// Mixed fleet with `a10_fraction` of `total` instances as A10s
/// (Fig. 15's heterogeneity sweep).
pub fn fleet_mixed(total: u32, a10_fraction: f64) -> Vec<InstanceConfig> {
    let a10 = (total as f64 * a10_fraction).round() as u32;
    FleetSpec {
        a100: total - a10,
        a10,
    }
    .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fleet_size() {
        let f = FleetSpec::paper();
        assert_eq!(f.total(), 80);
        assert_eq!(f.build().len(), 80);
    }

    #[test]
    fn mixed_fraction() {
        let f = fleet_mixed(10, 0.3);
        let a10 = f
            .iter()
            .filter(|c| c.gpu == GpuKind::A10)
            .count();
        assert_eq!(a10, 3);
        assert_eq!(f.len(), 10);
    }

    #[test]
    fn ids_unique() {
        let f = FleetSpec { a100: 5, a10: 5 }.build();
        let mut ids: Vec<u32> = f.iter().map(|c| c.id.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
    }

    #[test]
    fn fleet_from_tiers_is_dense_and_ordered() {
        let f = fleet_from_tiers(&[(GpuKind::A100, 3), (GpuKind::A10, 2)]);
        assert_eq!(f.len(), 5);
        for (i, c) in f.iter().enumerate() {
            assert_eq!(c.id.0 as usize, i, "ids must be dense for the engine");
        }
        assert!(f[..3].iter().all(|c| c.gpu == GpuKind::A100));
        assert!(f[3..].iter().all(|c| c.gpu == GpuKind::A10));
        assert_eq!(fleet_of(GpuKind::A10, 4).len(), 4);
        assert!(fleet_of(GpuKind::A10, 4).iter().all(|c| c.gpu == GpuKind::A10));
    }
}
