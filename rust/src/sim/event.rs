//! EventCore: the simulation's time-ordering layer.
//!
//! Owns the clock, the (time, seq)-ordered event queue, the per-instance
//! wake-deduplication state, and the per-instance iteration-end times.
//! The serving engine reacts to events; EventCore decides *when* they
//! fire — splitting the two keeps queue/dedup invariants in one place
//! and lets every policy / fleet change land without touching the
//! time-ordering logic (the §5 layering: LSO actuation and scheduling
//! sit above a dumb, correct clock).
//!
//! # The timer wheel
//!
//! The event queue is a two-level bucketed **timer wheel**
//! ([`TimerWheel`]) instead of a `BinaryHeap`: a heap pays O(log n) per
//! push/pop with pointer-chasing sift paths, which at the million-event
//! scale of `--scenario megascale` is ~20 cache-hostile levels per
//! operation. The wheel pays O(1) amortized:
//!
//! * **Level 0** — [`L0_BUCKETS`] buckets of [`BUCKET_S`] simulated
//!   seconds each (a 512 s window at the cursor). A push appends to its
//!   bucket; the drain sorts one bucket at a time by `(t, seq)` when the
//!   cursor reaches it.
//! * **Level 1** — [`L1_BUCKETS`] buckets of `L0_BUCKETS × BUCKET_S`
//!   (512 s) each, covering ~24 simulated days. When the cursor enters a
//!   new level-1 bucket its events cascade down into level 0 — each
//!   event moves down at most once.
//! * **Overflow** — events beyond the level-1 window sit in an unsorted
//!   list; when both wheel levels drain empty the window re-bases at the
//!   overflow's earliest bucket and redistributes. (Sim horizons are
//!   hours, so this level exists for correctness, not for the hot path.)
//!
//! **Ordering invariant**: pops are in exactly `BinaryHeap` `(t, seq)`
//! order. Buckets partition time, so cross-bucket order is strict-by-`t`;
//! equal timestamps land in the same bucket and the per-bucket sort
//! breaks the tie by insertion `seq`. An event pushed *behind* the
//! cursor (its bucket already drained) is spliced into the live drain
//! buffer by binary search — exactly where the heap would yield it.
//! `tests/properties.rs` checks the equivalence against a real heap
//! under random workloads, and the golden suite runs whole simulations
//! on both implementations ([`EventCore::new_heap_baseline`] keeps the
//! heap alive as the bench/golden baseline, the way `benches/` keeps the
//! legacy queue).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::backend::InstanceId;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Trace request `i` arrives at the global queue.
    Arrival(usize),
    /// An instance runs one continuous-batching iteration.
    Wake(InstanceId),
    /// Injected instance failure (§4 Fault Tolerance).
    Fail(InstanceId),
    /// A provisioned instance finishes its cold start and joins the
    /// fleet (autoscaler scale-up).
    Provision(InstanceId),
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub t: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Level-0 bucket width in simulated seconds.
const BUCKET_S: f64 = 0.125;
/// Level-0 buckets (cursor window: 4096 × 0.125 s = 512 s).
const L0_BUCKETS: usize = 4096;
/// Level-1 buckets (window: 4096 × 512 s ≈ 24 simulated days).
const L1_BUCKETS: usize = 4096;

/// Absolute level-0 bucket index of time `t`. The cast saturates
/// (negative/NaN → 0, huge → max) and the clamp keeps downstream
/// `bucket × L0_BUCKETS`-style arithmetic far from u64 overflow;
/// clamped events still pop in `(t, seq)` order because the in-bucket
/// sort compares the exact timestamps.
fn bucket_of(t: f64) -> u64 {
    const MAX_B0: u64 = u64::MAX / (L0_BUCKETS as u64 * L1_BUCKETS as u64);
    ((t / BUCKET_S) as u64).min(MAX_B0)
}

/// Two-level bucketed timer wheel (see the module docs for the level
/// geometry, cascade, overflow, and ordering-invariant discussion).
#[derive(Debug)]
pub struct TimerWheel {
    /// Next absolute level-0 bucket the drain will load. Buckets below
    /// it are already drained (or draining via `drain`).
    c0: u64,
    /// The level-1 bucket currently cascaded into level 0.
    b1_cur: u64,
    /// Exclusive end of the level-1 window: live level-1 buckets are in
    /// `(b1_cur, l1_end)`, which spans at most [`L1_BUCKETS`] — the ring
    /// mapping `b1 % L1_BUCKETS` stays collision-free.
    l1_end: u64,
    /// Level-0 ring: slot `b0 % L0_BUCKETS` for absolute bucket `b0` in
    /// the current level-1 bucket's span.
    slots0: Vec<Vec<Event>>,
    /// Level-1 ring: slot `b1 % L1_BUCKETS`.
    slots1: Vec<Vec<Event>>,
    /// Events past the level-1 window, unsorted until a re-base.
    overflow: Vec<Event>,
    /// The bucket being drained, sorted ascending by `(t, seq)`;
    /// `drain[..drain_pos]` is already popped. Reused across buckets.
    drain: Vec<Event>,
    drain_pos: usize,
    /// Events currently resident in `slots0` / `slots1`.
    count_l0: usize,
    count_l1: usize,
    /// Total live events (all levels + overflow + undrained `drain`).
    len: usize,
}

impl TimerWheel {
    fn new() -> Self {
        TimerWheel {
            c0: 0,
            b1_cur: 0,
            l1_end: L1_BUCKETS as u64,
            slots0: vec![Vec::new(); L0_BUCKETS],
            slots1: vec![Vec::new(); L1_BUCKETS],
            overflow: Vec::new(),
            drain: Vec::new(),
            drain_pos: 0,
            count_l0: 0,
            count_l1: 0,
            len: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn push(&mut self, ev: Event) {
        self.len += 1;
        let b0 = bucket_of(ev.t);
        if b0 < self.c0 {
            // Behind the cursor: its bucket is already drained. The heap
            // would pop it next among everything ≥ it, so splice it into
            // the undrained tail of the live drain buffer at its exact
            // (t, seq) position.
            let pos = self.drain[self.drain_pos..].partition_point(|e| *e < ev);
            self.drain.insert(self.drain_pos + pos, ev);
        } else if b0 < (self.b1_cur + 1) * L0_BUCKETS as u64 {
            self.slots0[(b0 % L0_BUCKETS as u64) as usize].push(ev);
            self.count_l0 += 1;
        } else {
            let b1 = b0 / L0_BUCKETS as u64;
            if b1 < self.l1_end {
                self.slots1[(b1 % L1_BUCKETS as u64) as usize].push(ev);
                self.count_l1 += 1;
            } else {
                self.overflow.push(ev);
            }
        }
    }

    fn pop(&mut self) -> Option<Event> {
        if !self.ensure_front() {
            return None;
        }
        let ev = self.drain[self.drain_pos];
        self.drain_pos += 1;
        self.len -= 1;
        Some(ev)
    }

    /// Timestamp of the next event without consuming it. Loads the next
    /// bucket into the drain buffer if needed — transparent to ordering:
    /// later pushes behind the cursor splice into the buffer at their
    /// exact `(t, seq)` position, exactly as they do between pops.
    fn peek_t(&mut self) -> Option<f64> {
        if !self.ensure_front() {
            return None;
        }
        Some(self.drain[self.drain_pos].t)
    }

    /// Advance the drain machinery until the buffer fronts the global
    /// minimum event. False iff the wheel is empty.
    fn ensure_front(&mut self) -> bool {
        // audit:hot-loop — one iteration per event at megascale counts;
        // the drain buffer and slot vectors are reused, never reallocated.
        loop {
            if self.drain_pos < self.drain.len() {
                return true;
            }
            if self.len == 0 {
                return false;
            }
            self.drain.clear();
            self.drain_pos = 0;
            if self.load_next_l0_bucket() {
                continue;
            }
            self.advance_l1();
        }
    }

    /// Load the next non-empty level-0 bucket of the current level-1
    /// span into `drain` (sorted). False when the span is exhausted.
    fn load_next_l0_bucket(&mut self) -> bool {
        let span_end = (self.b1_cur + 1) * L0_BUCKETS as u64;
        if self.count_l0 == 0 {
            self.c0 = span_end;
            return false;
        }
        while self.c0 < span_end {
            let slot = (self.c0 % L0_BUCKETS as u64) as usize;
            self.c0 += 1;
            if !self.slots0[slot].is_empty() {
                std::mem::swap(&mut self.drain, &mut self.slots0[slot]);
                self.count_l0 -= self.drain.len();
                self.drain.sort_unstable();
                return true;
            }
        }
        false
    }

    /// Advance to the next level-1 bucket holding events and cascade it
    /// into level 0. Re-bases the window from overflow when both wheel
    /// levels are empty. Caller guarantees `len > 0`.
    fn advance_l1(&mut self) {
        loop {
            if self.count_l1 == 0 {
                debug_assert_eq!(self.count_l0, 0, "l0 drained before advancing l1");
                self.rebase_overflow();
            }
            self.b1_cur += 1;
            let slot = (self.b1_cur % L1_BUCKETS as u64) as usize;
            self.c0 = self.b1_cur * L0_BUCKETS as u64;
            if self.slots1[slot].is_empty() {
                continue;
            }
            let evs = std::mem::take(&mut self.slots1[slot]);
            self.count_l1 -= evs.len();
            self.count_l0 += evs.len();
            for ev in evs {
                let b0 = bucket_of(ev.t);
                debug_assert_eq!(b0 / L0_BUCKETS as u64, self.b1_cur, "cascade stays in-span");
                self.slots0[(b0 % L0_BUCKETS as u64) as usize].push(ev);
            }
            return;
        }
    }

    /// Both wheel levels are empty but events remain: everything live is
    /// in overflow. Re-base the level-1 window at the overflow's
    /// earliest bucket and redistribute what now fits.
    fn rebase_overflow(&mut self) {
        debug_assert!(!self.overflow.is_empty(), "len > 0 with empty wheel ⇒ overflow");
        let min_b1 = self
            .overflow
            .iter()
            .map(|e| bucket_of(e.t) / L0_BUCKETS as u64)
            .fold(u64::MAX, u64::min);
        // Overflow only ever holds buckets ≥ the old `l1_end` ≥ 1, so
        // the window base below never underflows.
        self.b1_cur = min_b1 - 1;
        self.l1_end = min_b1 + L1_BUCKETS as u64;
        let mut i = 0;
        while i < self.overflow.len() {
            let b1 = bucket_of(self.overflow[i].t) / L0_BUCKETS as u64;
            if b1 < self.l1_end {
                let ev = self.overflow.swap_remove(i);
                self.slots1[(b1 % L1_BUCKETS as u64) as usize].push(ev);
                self.count_l1 += 1;
            } else {
                i += 1;
            }
        }
    }
}

/// The queue behind [`EventCore`]: the timer wheel in production, the
/// `BinaryHeap` it replaced as the bench/golden baseline.
#[derive(Debug)]
enum EventQueue {
    Wheel(TimerWheel),
    Heap(BinaryHeap<Reverse<Event>>),
}

/// Clock + event queue + wake dedup. Instances are identified by dense
/// indices (`InstanceId.0`), matching the engine's per-instance `Vec`s.
#[derive(Debug)]
pub struct EventCore {
    /// Simulated time of the event being processed.
    pub now: f64,
    seq: u64,
    queue: EventQueue,
    /// Per-instance wake deduplication: at most one pending Wake per
    /// instance (avoids event-storm blowup). An earlier wake supersedes
    /// a later pending one; the superseded queue entry cannot be removed
    /// in place and is dropped at pop time instead (see
    /// [`EventCore::take_due_wake`]).
    wake_pending: Vec<Option<f64>>,
    /// End time of each instance's in-flight iteration: a step is an
    /// atomic unit of GPU work; wakes landing inside it are deferred.
    next_free: Vec<f64>,
    /// Wake bookkeeping: honored pops vs superseded (stale) pops.
    wakes_executed: u64,
    wakes_stale_dropped: u64,
}

impl EventCore {
    pub fn new(n_instances: usize) -> Self {
        Self::with_queue(n_instances, EventQueue::Wheel(TimerWheel::new()))
    }

    /// The pre-wheel `BinaryHeap` implementation, kept as the baseline
    /// for `cargo bench -- event_core` and the golden wheel ≡ heap
    /// equivalence runs. Semantics are identical by contract; only the
    /// asymptotics differ.
    #[doc(hidden)]
    pub fn new_heap_baseline(n_instances: usize) -> Self {
        Self::with_queue(n_instances, EventQueue::Heap(BinaryHeap::new()))
    }

    fn with_queue(n_instances: usize, queue: EventQueue) -> Self {
        EventCore {
            now: 0.0,
            seq: 0,
            queue,
            wake_pending: vec![None; n_instances],
            next_free: vec![0.0; n_instances],
            wakes_executed: 0,
            wakes_stale_dropped: 0,
        }
    }

    /// Grow the per-instance state for a newly provisioned instance.
    pub fn add_instance(&mut self) {
        self.wake_pending.push(None);
        self.next_free.push(0.0);
    }

    /// Live events queued (all wheel levels, or the whole heap).
    #[doc(hidden)]
    pub fn queue_len(&self) -> usize {
        match &self.queue {
            EventQueue::Wheel(w) => w.len(),
            EventQueue::Heap(h) => h.len(),
        }
    }

    pub fn push(&mut self, t: f64, kind: EventKind) {
        self.seq += 1;
        let ev = Event {
            t,
            seq: self.seq,
            kind,
        };
        match &mut self.queue {
            EventQueue::Wheel(w) => w.push(ev),
            EventQueue::Heap(h) => h.push(Reverse(ev)),
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        match &mut self.queue {
            EventQueue::Wheel(w) => w.pop(),
            EventQueue::Heap(h) => h.pop().map(|Reverse(ev)| ev),
        }
    }

    /// Timestamp of the next event without consuming it. The streamed
    /// run loop merges trace arrivals against this (`arrival wins on
    /// ties`, reproducing the materialized seq order, where arrivals
    /// are pushed before everything else). `&mut` because the wheel may
    /// advance its cursor into the drain buffer — semantically
    /// transparent (see [`TimerWheel`]).
    pub fn peek_t(&mut self) -> Option<f64> {
        match &mut self.queue {
            EventQueue::Wheel(w) => w.peek_t(),
            EventQueue::Heap(h) => h.peek().map(|Reverse(ev)| ev.t),
        }
    }

    /// Request a wake for `id` at `t`. Callers are responsible for the
    /// liveness check — EventCore only owns the dedup. Coalesces: a
    /// pending earlier-or-equal wake absorbs this one; an *earlier*
    /// wake supersedes a pending later one, whose queue entry stays
    /// behind and is discarded at pop time by [`Self::take_due_wake`].
    pub fn wake(&mut self, id: InstanceId, t: f64) {
        let idx = id.0 as usize;
        if let Some(pending) = self.wake_pending[idx] {
            if pending <= t + 1e-12 {
                return;
            }
        }
        self.wake_pending[idx] = Some(t);
        self.push(t, EventKind::Wake(id));
    }

    /// Pop-side half of the wake dedup: honor a popped Wake only if it
    /// *is* the currently pending wake for the instance. Superseded
    /// entries used to clear `wake_pending` and fire a spurious
    /// iteration anyway, breaking the at-most-one-pending-Wake
    /// invariant (a stale pop would also cancel a legitimately pending
    /// newer wake, duplicating iterations at the old time).
    pub fn take_due_wake(&mut self, id: InstanceId, t: f64) -> bool {
        let idx = id.0 as usize;
        match self.wake_pending[idx] {
            Some(pending) if (pending - t).abs() <= 1e-12 => {
                self.wake_pending[idx] = None;
                self.wakes_executed += 1;
                true
            }
            _ => {
                self.wakes_stale_dropped += 1;
                false
            }
        }
    }

    /// Drop any pending wake for a dead/decommissioned instance.
    pub fn clear_pending(&mut self, id: InstanceId) {
        self.wake_pending[id.0 as usize] = None;
    }

    #[cfg(test)]
    pub fn pending_wake(&self, id: InstanceId) -> Option<f64> {
        self.wake_pending[id.0 as usize]
    }

    /// (honored, stale-dropped) wake pops — observability for the
    /// at-most-one-pending-Wake invariant.
    pub fn wake_stats(&self) -> (u64, u64) {
        (self.wakes_executed, self.wakes_stale_dropped)
    }

    pub fn next_free(&self, id: InstanceId) -> f64 {
        self.next_free[id.0 as usize]
    }

    pub fn set_next_free(&mut self, id: InstanceId, t: f64) {
        self.next_free[id.0 as usize] = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn events_pop_in_time_then_seq_order() {
        let mut core = EventCore::new(1);
        core.push(5.0, EventKind::Arrival(0));
        core.push(1.0, EventKind::Arrival(1));
        core.push(5.0, EventKind::Arrival(2));
        let order: Vec<usize> = std::iter::from_fn(|| core.pop())
            .map(|e| match e.kind {
                EventKind::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 0, 2], "ties break by insertion seq");
    }

    #[test]
    fn stale_superseded_wake_is_dropped() {
        // Out-of-order wake requests: the earlier wake supersedes the
        // pending later one, whose queue entry cannot be cancelled.
        let mut core = EventCore::new(1);
        core.wake(InstanceId(0), 10.0);
        core.wake(InstanceId(0), 5.0);
        let mut honored = 0;
        while let Some(ev) = core.pop() {
            if let EventKind::Wake(id) = ev.kind {
                if core.take_due_wake(id, ev.t) {
                    honored += 1;
                }
            }
        }
        assert_eq!(honored, 1, "only the superseding wake may fire");
        assert_eq!(core.wake_stats(), (1, 1), "the stale t=10 pop is dropped");
        assert_eq!(core.pending_wake(InstanceId(0)), None);
    }

    #[test]
    fn later_wake_coalesces_into_pending_earlier_one() {
        let mut core = EventCore::new(1);
        core.wake(InstanceId(0), 2.0);
        core.wake(InstanceId(0), 7.0); // absorbed
        let mut pops = 0;
        while core.pop().is_some() {
            pops += 1;
        }
        assert_eq!(pops, 1, "the later wake must not enqueue an event");
    }

    #[test]
    fn cascade_preserves_order_across_level_one_buckets() {
        // Times spanning several level-1 buckets (512 s each) plus a
        // duplicate timestamp right at a bucket boundary: the cascade
        // and per-bucket sort must reproduce global (t, seq) order.
        let mut core = EventCore::new(1);
        let times = [1536.0, 0.1, 512.0, 512.0, 3000.0, 511.999, 513.0];
        for (i, &t) in times.iter().enumerate() {
            core.push(t, EventKind::Arrival(i));
        }
        let got: Vec<usize> = std::iter::from_fn(|| core.pop())
            .map(|e| match e.kind {
                EventKind::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(got, vec![1, 5, 2, 3, 6, 0, 4]);
    }

    #[test]
    fn overflow_rebase_preserves_order() {
        // Events beyond the level-1 window (~2.1e6 s) force the
        // overflow path and a window re-base once the wheel drains.
        let mut core = EventCore::new(1);
        let times = [5e6, 1.0, 3e6, 7e9, 3e6];
        for (i, &t) in times.iter().enumerate() {
            core.push(t, EventKind::Arrival(i));
        }
        let got: Vec<usize> = std::iter::from_fn(|| core.pop())
            .map(|e| match e.kind {
                EventKind::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(got, vec![1, 2, 4, 0, 3]);
    }

    #[test]
    fn late_push_behind_the_cursor_pops_next() {
        // A push whose bucket already drained must come out exactly
        // where a heap would yield it: immediately, before everything
        // later, and in (t, seq) order among other late pushes.
        let mut core = EventCore::new(1);
        core.push(100.0, EventKind::Arrival(0));
        core.push(600.0, EventKind::Arrival(1));
        assert!(matches!(core.pop().map(|e| e.kind), Some(EventKind::Arrival(0))));
        core.push(50.0, EventKind::Arrival(2));
        core.push(10.0, EventKind::Arrival(3));
        let got: Vec<usize> = std::iter::from_fn(|| core.pop())
            .map(|e| match e.kind {
                EventKind::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(got, vec![3, 2, 1], "late pushes pop before queued future work");
    }

    #[test]
    fn peek_is_transparent_to_pop_order() {
        // A push earlier than an already-peeked event must still pop
        // first: the peek's bucket load leaves the behind-cursor splice
        // path intact. Checked on both queue implementations.
        for core in [EventCore::new(1), EventCore::new_heap_baseline(1)] {
            let mut core = core;
            core.push(100.0, EventKind::Arrival(0));
            core.push(600.0, EventKind::Arrival(1));
            assert_eq!(core.peek_t(), Some(100.0));
            assert_eq!(core.peek_t(), Some(100.0), "peek must not consume");
            assert!(matches!(core.pop().map(|e| e.kind), Some(EventKind::Arrival(0))));
            assert_eq!(core.peek_t(), Some(600.0));
            core.push(50.0, EventKind::Arrival(2));
            assert_eq!(core.peek_t(), Some(50.0), "earlier late push fronts the queue");
            let got: Vec<usize> = std::iter::from_fn(|| core.pop())
                .map(|e| match e.kind {
                    EventKind::Arrival(i) => i,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(got, vec![2, 1]);
            assert_eq!(core.peek_t(), None);
        }
    }

    #[test]
    fn wheel_matches_heap_on_random_workloads() {
        // Interleaved random pushes and pops against the retained heap
        // baseline — the full property sweep (duplicate timestamps,
        // stale wakes) lives in tests/properties.rs.
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let mut wheel = EventCore::new(4);
            let mut heap = EventCore::new_heap_baseline(4);
            let mut popped: Vec<(u64, u64)> = Vec::new();
            let mut floor = 0.0_f64;
            for _ in 0..400 {
                if rng.f64() < 0.6 {
                    // Pushes at/after the latest pop, like the engine.
                    let t = floor + rng.f64() * 900.0;
                    let i = rng.usize(1000);
                    wheel.push(t, EventKind::Arrival(i));
                    heap.push(t, EventKind::Arrival(i));
                } else {
                    let a = wheel.pop();
                    let b = heap.pop();
                    match (a, b) {
                        (Some(x), Some(y)) => {
                            assert_eq!((x.t, x.seq, x.kind), (y.t, y.seq, y.kind), "seed {seed}");
                            floor = x.t;
                            popped.push((x.seq, x.t.to_bits()));
                        }
                        (None, None) => {}
                        (a, b) => panic!("seed {seed}: wheel {a:?} vs heap {b:?}"),
                    }
                }
            }
            loop {
                match (wheel.pop(), heap.pop()) {
                    (Some(x), Some(y)) => {
                        assert_eq!((x.t, x.seq), (y.t, y.seq), "seed {seed}: tail");
                    }
                    (None, None) => break,
                    (a, b) => panic!("seed {seed}: tail {a:?} vs {b:?}"),
                }
            }
            assert_eq!(wheel.queue_len(), 0);
        }
    }
}
