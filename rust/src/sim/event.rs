//! EventCore: the simulation's time-ordering layer.
//!
//! Owns the clock, the (time, seq)-ordered event heap, the per-instance
//! wake-deduplication state, and the per-instance iteration-end times.
//! The serving engine reacts to events; EventCore decides *when* they
//! fire — splitting the two keeps heap/dedup invariants in one place
//! and lets every policy / fleet change land without touching the
//! time-ordering logic (the §5 layering: LSO actuation and scheduling
//! sit above a dumb, correct clock).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::backend::InstanceId;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum EventKind {
    /// Trace request `i` arrives at the global queue.
    Arrival(usize),
    /// An instance runs one continuous-batching iteration.
    Wake(InstanceId),
    /// Injected instance failure (§4 Fault Tolerance).
    Fail(InstanceId),
    /// A provisioned instance finishes its cold start and joins the
    /// fleet (autoscaler scale-up).
    Provision(InstanceId),
}

#[derive(Debug, Clone, Copy)]
pub(crate) struct Event {
    pub t: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Clock + event heap + wake dedup. Instances are identified by dense
/// indices (`InstanceId.0`), matching the engine's per-instance `Vec`s.
pub(crate) struct EventCore {
    /// Simulated time of the event being processed.
    pub now: f64,
    seq: u64,
    heap: BinaryHeap<Reverse<Event>>,
    /// Per-instance wake deduplication: at most one pending Wake per
    /// instance (avoids event-storm blowup). An earlier wake supersedes
    /// a later pending one; the superseded heap entry cannot be removed
    /// from the `BinaryHeap` and is dropped at pop time instead (see
    /// [`EventCore::take_due_wake`]).
    wake_pending: Vec<Option<f64>>,
    /// End time of each instance's in-flight iteration: a step is an
    /// atomic unit of GPU work; wakes landing inside it are deferred.
    next_free: Vec<f64>,
    /// Wake bookkeeping: honored pops vs superseded (stale) pops.
    wakes_executed: u64,
    wakes_stale_dropped: u64,
}

impl EventCore {
    pub fn new(n_instances: usize) -> Self {
        EventCore {
            now: 0.0,
            seq: 0,
            heap: BinaryHeap::new(),
            wake_pending: vec![None; n_instances],
            next_free: vec![0.0; n_instances],
            wakes_executed: 0,
            wakes_stale_dropped: 0,
        }
    }

    /// Grow the per-instance state for a newly provisioned instance.
    pub fn add_instance(&mut self) {
        self.wake_pending.push(None);
        self.next_free.push(0.0);
    }

    pub fn push(&mut self, t: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event {
            t,
            seq: self.seq,
            kind,
        }));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(ev)| ev)
    }

    /// Request a wake for `id` at `t`. Callers are responsible for the
    /// liveness check — EventCore only owns the dedup. Coalesces: a
    /// pending earlier-or-equal wake absorbs this one; an *earlier*
    /// wake supersedes a pending later one, whose heap entry stays
    /// behind and is discarded at pop time by [`Self::take_due_wake`].
    pub fn wake(&mut self, id: InstanceId, t: f64) {
        let idx = id.0 as usize;
        if let Some(pending) = self.wake_pending[idx] {
            if pending <= t + 1e-12 {
                return;
            }
        }
        self.wake_pending[idx] = Some(t);
        self.push(t, EventKind::Wake(id));
    }

    /// Pop-side half of the wake dedup: honor a popped Wake only if it
    /// *is* the currently pending wake for the instance. Superseded
    /// entries used to clear `wake_pending` and fire a spurious
    /// iteration anyway, breaking the at-most-one-pending-Wake
    /// invariant (a stale pop would also cancel a legitimately pending
    /// newer wake, duplicating iterations at the old time).
    pub fn take_due_wake(&mut self, id: InstanceId, t: f64) -> bool {
        let idx = id.0 as usize;
        match self.wake_pending[idx] {
            Some(pending) if (pending - t).abs() <= 1e-12 => {
                self.wake_pending[idx] = None;
                self.wakes_executed += 1;
                true
            }
            _ => {
                self.wakes_stale_dropped += 1;
                false
            }
        }
    }

    /// Drop any pending wake for a dead/decommissioned instance.
    pub fn clear_pending(&mut self, id: InstanceId) {
        self.wake_pending[id.0 as usize] = None;
    }

    #[cfg(test)]
    pub fn pending_wake(&self, id: InstanceId) -> Option<f64> {
        self.wake_pending[id.0 as usize]
    }

    /// (honored, stale-dropped) wake pops — observability for the
    /// at-most-one-pending-Wake invariant.
    pub fn wake_stats(&self) -> (u64, u64) {
        (self.wakes_executed, self.wakes_stale_dropped)
    }

    pub fn next_free(&self, id: InstanceId) -> f64 {
        self.next_free[id.0 as usize]
    }

    pub fn set_next_free(&mut self, id: InstanceId, t: f64) {
        self.next_free[id.0 as usize] = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_then_seq_order() {
        let mut core = EventCore::new(1);
        core.push(5.0, EventKind::Arrival(0));
        core.push(1.0, EventKind::Arrival(1));
        core.push(5.0, EventKind::Arrival(2));
        let order: Vec<usize> = std::iter::from_fn(|| core.pop())
            .map(|e| match e.kind {
                EventKind::Arrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 0, 2], "ties break by insertion seq");
    }

    #[test]
    fn stale_superseded_wake_is_dropped() {
        // Out-of-order wake requests: the earlier wake supersedes the
        // pending later one, whose heap entry cannot be cancelled.
        let mut core = EventCore::new(1);
        core.wake(InstanceId(0), 10.0);
        core.wake(InstanceId(0), 5.0);
        let mut honored = 0;
        while let Some(ev) = core.pop() {
            if let EventKind::Wake(id) = ev.kind {
                if core.take_due_wake(id, ev.t) {
                    honored += 1;
                }
            }
        }
        assert_eq!(honored, 1, "only the superseding wake may fire");
        assert_eq!(core.wake_stats(), (1, 1), "the stale t=10 pop is dropped");
        assert_eq!(core.pending_wake(InstanceId(0)), None);
    }

    #[test]
    fn later_wake_coalesces_into_pending_earlier_one() {
        let mut core = EventCore::new(1);
        core.wake(InstanceId(0), 2.0);
        core.wake(InstanceId(0), 7.0); // absorbed
        let mut pops = 0;
        while core.pop().is_some() {
            pops += 1;
        }
        assert_eq!(pops, 1, "the later wake must not enqueue an event");
    }
}
